"""Shared contention-injection harness for the adaptive and fleet
benchmarks.

:class:`TaxedEngine` is a ``ServingEngine`` whose every segment
execution first calls ``tax(placement)`` — the benchmark's synthetic
co-tenant hook (a busy-wait stand-in for a stolen core).  The wrap
happens in ``_build_pipeline`` so every pipeline the engine ever
builds — including ones hot-swapped in by remaps — runs under the
same contention; escaping it requires actually moving work off the
contended processor, which is the thing both benchmarks measure.
``adapt_bench`` passes a single-placement tax, ``fleet_bench`` binds
the tax to a tenant whose rate depends on the co-runners' shares.
"""

from __future__ import annotations

import time
from typing import Callable

from repro.serving import ServingEngine


def busy_wait(seconds: float) -> None:
    """Burn the CPU for `seconds` (not sleep: a sleeping co-tenant
    yields the core back, a real one does not)."""
    if seconds <= 0.0:
        return
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        pass


class TaxedEngine(ServingEngine):
    """ServingEngine paying ``tax(placement)`` before every segment."""

    def __init__(self, *args, tax: Callable[[str], None], **kwargs):
        self._tax = tax
        super().__init__(*args, **kwargs)

    def _build_pipeline(self, config):
        pipe = super()._build_pipeline(config)

        def taxed(seg, fn):
            def run(x):
                self._tax(seg.placement)
                return fn(x)

            return run

        pipe.segment_fns = [
            (seg, taxed(seg, fn)) for seg, fn in pipe.segment_fns
        ]
        return pipe
