"""Learned-estimator benchmark: predictor-seeded DP on an unprofiled
model, plus interference-law calibration accuracy.

**Latency predictor.**  Three training widths of the fashion-MNIST BNN
are profiled (analytic time source — deterministic in any container)
through a ``ProfileStore``, which records estimator training rows as a
side effect of every real profile run.  ``store.predictor()`` fits the
per-group log-linear regression, and ``predict_table`` synthesizes a
complete ProfileTable for an **unseen, wider** target model — with
zero profiling passes on the target, counted by invocation.  The
predicted table seeds the standard DP mapper; the resulting mapping is
then *re-priced on the target's real (fully profiled) table* and
compared against the fully-profiled DP optimum and the uniform
baselines.

Hard assertions: the predicted path invokes the profiler zero times;
the predicted table is marked ``provenance="predicted"`` and yields a
valid mapping; re-priced on the real table, the predictor-seeded
mapping costs <= ``max_ratio`` (default 1.5x) of the fully-profiled DP
optimum.

**Interference fit.**  A ledger trace with a planted linear
interference law (the same synthetic generator the tests use, at
nonzero noise) is fitted back; the recovered gamma must land within
10% relative error.

Rows are functional (``us=0`` sentinel): the gates and the derived
ratios are the result, not wall time.
"""

from __future__ import annotations

import tempfile

import jax

from repro.bnn import build_model
from repro.bnn.models import pack_params
from repro.core.mapper import map_efficient_configuration, price_mapping
from repro.core.parallel_config import CPU, FULL_GPU
from repro.core.profiler import profile_bnn_model
from repro.estimator import InterferenceFit
from repro.store import ProfileStore


def _planted_ledger(gamma: float, *, steps: int, noise: float, seed: int):
    """Ledger trace embodying ``1 + gamma * co_share`` (shared with
    ``tests/fixtures.py``; duplicated inline because benchmarks do not
    import from the test tree)."""
    import random

    from repro.core.mapper import DEVICE, HOST
    from repro.fleet import DeviceTimeLedger

    occupancies = {"t0": (0.6, 0.9), "t1": (0.25, 0.55), "t2": (0.9, 0.15)}
    rng = random.Random(seed)
    ledger = DeviceTimeLedger(window=steps + 2)
    shares = {
        t: (h / (h + d), d / (h + d)) for t, (h, d) in occupancies.items()
    }
    co = {
        t: (
            sum(s[0] for u, s in shares.items() if u != t),
            sum(s[1] for u, s in shares.items() if u != t),
        )
        for t in occupancies
    }
    expected = {
        t: (h / (1.0 + gamma * co[t][0]), d / (1.0 + gamma * co[t][1]))
        for t, (h, d) in occupancies.items()
    }
    for _ in range(steps):
        for t, (h, d) in occupancies.items():
            jit = 1.0 + rng.uniform(-noise, noise)
            ledger.record(t, HOST, h * jit)
            ledger.record(t, DEVICE, d * jit)
            ledger.close_step(t)
    return ledger, expected


def run(
    train_scales=(0.25, 0.375, 0.5),
    target_scale: float = 0.75,
    batch: int = 4,
    repeats: int = 1,
    max_ratio: float = 1.5,
    planted_gamma: float = 1.0,
    fit_noise: float = 0.15,
):
    batches = (1, batch)

    def profiler(repeat_count):
        def fn(model, packed, *, batch_sizes):
            return profile_bnn_model(
                model, packed, batch_sizes=batch_sizes,
                repeats=repeat_count, time_source="analytic",
            )
        return fn

    with tempfile.TemporaryDirectory() as root:
        store = ProfileStore(root)
        # -- train: each real profile run feeds the store's row set --
        for s in train_scales:
            m = build_model("fashion_mnist", scale=s)
            packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
            _, loaded = store.get_or_profile(
                m, packed, profiler(repeats), batch_sizes=batches
            )
            assert not loaded
        pred = store.predictor()
        assert pred is not None and pred.n_rows > 0

        # -- predict: zero profiling passes on the target ------------
        target = build_model("fashion_mnist", scale=target_scale)
        target_packed = pack_params(
            target.specs, target.init(jax.random.PRNGKey(0))
        )
        calls: list = []

        def counted(model, packed, *, batch_sizes):
            calls.append(model.name)
            return profiler(repeats)(model, packed, batch_sizes=batch_sizes)

        predicted = pred.predict_table(target, batches)
        assert predicted.provenance == "predicted"
        seeded = map_efficient_configuration(
            predicted, batch_sizes=(batch,), policy="dp"
        )
        assert len(seeded.layer_configs) == len(target.specs)
        assert calls == [], "predicted path must not profile"

        # -- truth: one real profiling pass, then re-price -----------
        truth_table = counted(
            target, target_packed, batch_sizes=batches
        )
        n_target_profiles = len(calls)
        truth = map_efficient_configuration(
            truth_table, batch_sizes=(batch,), policy="dp"
        )
        repriced = price_mapping(
            truth_table, batch, seeded.layer_configs
        )
        ratio = (
            repriced.expected_time_per_example
            / truth.expected_time_per_example
        )
        assert ratio <= max_ratio, (
            f"predictor-seeded mapping re-prices at {ratio:.2f}x the "
            f"fully-profiled DP (bound {max_ratio}x)"
        )
        uniform = {
            name: price_mapping(
                truth_table, batch, (cfg,) * len(target.specs)
            ).expected_time_per_example
            for name, cfg in (("cpu", CPU), ("gpu", FULL_GPU))
        }

    # -- interference-law calibration --------------------------------
    ledger, expected = _planted_ledger(
        planted_gamma, steps=32, noise=fit_noise, seed=7
    )
    law = InterferenceFit.from_ledger(ledger, expected).fit()
    gamma_err = abs(law.gamma - planted_gamma) / planted_gamma
    assert gamma_err <= 0.10, (
        f"fitted gamma {law.gamma:.3f} misses planted "
        f"{planted_gamma} by {gamma_err:.1%}"
    )

    cov = pred.coverage()
    return [
        (
            f"estimator/fashion_mnist/s{target_scale}/b{batch}/"
            "seeded_vs_profiled",
            0.0,
            f"reprice_ratio={ratio:.3f}x;"
            f"bound={max_ratio}x;"
            f"target_profiles={n_target_profiles};"
            f"seeded_pred_us="
            f"{seeded.expected_time_per_example * 1e6:.2f};"
            f"truth_dp_us={truth.expected_time_per_example * 1e6:.2f};"
            f"uniform_cpu_us={uniform['cpu'] * 1e6:.2f};"
            f"uniform_gpu_us={uniform['gpu'] * 1e6:.2f};"
            f"train_rows={pred.n_rows};"
            f"groups_fitted={len([k for k, v in cov.items() if v])}",
        ),
        (
            f"estimator/interference/gamma{planted_gamma}/"
            f"noise{fit_noise}",
            0.0,
            f"fitted_gamma={law.gamma:.3f};"
            f"rel_err={gamma_err:.3f};"
            f"n_obs={law.n_obs};"
            f"knots={len(law.knots)};"
            f"residual={law.residual:.4f}",
        ),
    ]
