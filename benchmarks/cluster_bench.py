"""Cluster serving benchmark: aggregate throughput vs host count,
noisy-tenant isolation, and a journaled elastic scale-up under surge
(docs/ARCHITECTURE.md §13).

Four tenants (same family, increasing widths) are profiled once over
the near-tied ``CPU``/``XYZ`` placement pair, then served under three
topologies — 1, 2 and 4 simulated hosts — through the cluster tier:
contention-priced placement (:func:`repro.cluster.place_tenants`),
per-host routers and ledgers, least-loaded dispatch.  Hosts model
*separate machines*: each host's serving phase is measured in its own
wall-clock window, the cluster makespan is the **max** host phase (not
the sum), and cross-host contention is structurally zero.  Within a
host, co-residents tax each other the same way ``fleet_bench``'s
synthetic co-tenant does — a busy-wait per segment execution sized by
the co-residents' occupancy share of that segment's processor — so
consolidation pays the contention the interference model prices, and
spreading across hosts genuinely removes it.

Hard assertions:

* every response, every tenant, every topology bit-exact against the
  per-model packed reference;
* aggregate throughput scales: >= 1.7x at 2 hosts and >= 3.0x at
  4 hosts vs 1 host (the parallel-machines win plus the vanished
  intra-host tax);
* noisy-tenant isolation: a tenant flooding its own host inflates its
  own p99 by an order of magnitude but cannot inflate the p99 of a
  victim tenant on another host (cross-host p99 ratio stays ~1; the
  paired measurement retries up to 3x — a breach is persistent,
  small-sample p99 noise is not);
* under sustained surge, the elastic controller journals at least one
  ``scale_up`` :class:`~repro.cluster.ScaleRecord`, and post-scale
  traffic still verifies bit-exact.

The row is functional (``us=0`` sentinel): the throughput ratios and
isolation/elastic evidence ride in ``derived``; the assertions above
are the gate.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.contention import TaxedEngine, busy_wait
from repro import api
from repro.bnn import build_model
from repro.bnn.models import forward_packed, pack_params, prepare_input_packed
from repro.cluster import Cluster, latency_quantile
from repro.core.mapper import HOST
from repro.core.parallel_config import CPU, FULL_GPU

SPACE = (CPU, FULL_GPU)


class ClusterContention:
    """Per-host synthetic co-tenants: each segment execution of a
    tenant pays a busy-wait sized by its *same-host* co-residents'
    share of that segment's processor.  Hosts are separate machines —
    a tenant never taxes (or is taxed by) another host."""

    def __init__(self, tax_s: float):
        self.tax_s = tax_s
        # host_id -> {tenant: (host_share, device_share)}
        self.hosts: dict = {}

    def bind(self, cluster) -> None:
        self.hosts = {
            h.host_id: {
                name: h.router.tenant(name).engine.config
                .placement_shares()
                for name in h.tenant_names()
            }
            for h in cluster.hosts
        }

    def apply(self, tenant: str, placement: str) -> None:
        idx = 0 if placement == HOST else 1
        for residents in self.hosts.values():
            if tenant in residents:
                co = sum(
                    s[idx] for n, s in residents.items() if n != tenant
                )
                busy_wait(self.tax_s * co)
                return


def _make_traffic(tenants, batch, rounds, seed=500):
    """Deterministic per-round traffic + bit-exact references."""
    traffic: dict = {}
    refs: dict = {}
    for name, tp in tenants.items():
        m, packed = tp.model, tp.packed
        traffic[name], refs[name] = [], []
        for i in range(rounds + 1):
            x01 = jax.random.uniform(
                jax.random.PRNGKey(seed + i),
                (batch, *m.input_hw, m.in_channels),
            )
            xw = np.asarray(prepare_input_packed(x01))
            traffic[name].append([xw[j] for j in range(batch)])
            refs[name].append(
                np.asarray(forward_packed(m.specs, packed, xw))
            )
    return traffic, refs


def _host_phase(cluster, host, traffic, rounds, *, start_round=1,
                burst=None):
    """Serve `rounds` rounds of this host's residents in one wall
    window (the host is its own machine).  Returns (wall_s, reqs)."""
    residents = host.tenant_names()
    reqs: dict = {name: [] for name in residents}
    t0 = time.perf_counter()
    for i in range(start_round, start_round + rounds):
        for name in residents:
            n_batches = (burst or {}).get(name, 1)
            for b in range(n_batches):
                round_i = (i + b) % len(traffic[name])
                reqs[name].extend(
                    (round_i, j, cluster.submit(name, x))
                    for j, x in enumerate(traffic[name][round_i])
                )
        host.step(force=True)
    host.drain()
    wall = time.perf_counter() - t0
    return wall, reqs


def _assert_exact(reqs, refs):
    for name, entries in reqs.items():
        for round_i, j, r in entries:
            assert r is not None
            got = r.wait(timeout=60.0)
            assert np.array_equal(got, refs[name][round_i][j]), (
                f"{name} round {round_i} item {j} != reference"
            )


def _warm(cluster, traffic, refs):
    """One untimed round per host (XLA compiles)."""
    for host in cluster.hosts:
        reqs = {
            name: [
                (0, j, cluster.submit(name, x))
                for j, x in enumerate(traffic[name][0])
            ]
            for name in host.tenant_names()
        }
        host.drain()
        _assert_exact(reqs, refs)


def run(
    scale: float = 0.4,
    batch: int = 4,
    rounds: int = 6,
    repeats: int = 1,
    profile_repeats: int = 1,
    gamma: float = 2.0,
    tax_s: float = 4e-3,
    burst_factor: int = 6,
):
    del repeats  # the topology sweep is the experiment
    names = ("t25", "t50", "t75", "t100")
    rel = (1.0, 1.25, 1.5, 1.75)
    tenants: dict = {}
    for name, r in zip(names, rel):
        m = build_model("fashion_mnist", scale=scale * r)
        packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
        # analytic profiling: deterministic, load-independent tables, so
        # the contention-priced placement never tips on profiling noise
        # (the throughput sweep itself is measured wall time)
        table = api.profile_model(
            m, packed, batch_sizes=(batch,), configs=SPACE,
            repeats=profile_repeats, time_source="analytic",
        )
        tenants[name] = api.TenantPlan(
            name=name, model=m, packed=packed, table=table,
            config=api.map_model(table, configs=SPACE),
        )
    traffic, refs = _make_traffic(tenants, batch, rounds + 1)
    total_reqs = len(names) * rounds * batch

    contention = ClusterContention(tax_s)

    def factory(tp, config, **kwargs):
        return TaxedEngine(
            tp.model, tp.packed, config,
            tax=lambda placement, t=tp.name: contention.apply(
                t, placement
            ),
            **kwargs,
        )

    # -- topology sweep: 1 vs 2 vs 4 hosts ---------------------------
    throughput: dict = {}
    placements: dict = {}
    cluster2 = None
    for n_hosts in (1, 2, 4):
        cluster = Cluster(
            tuple(tenants.values()), n_hosts=n_hosts, gamma=gamma,
            configs=SPACE, batch_sizes=(batch,),
            engine_factory=factory,
        )
        contention.bind(cluster)
        _warm(cluster, traffic, refs)
        walls = []
        for host in cluster.hosts:
            wall, reqs = _host_phase(cluster, host, traffic, rounds)
            _assert_exact(reqs, refs)
            walls.append(wall)
        makespan = max(walls)
        throughput[n_hosts] = total_reqs / makespan
        placements[n_hosts] = "|".join(
            ",".join(a.tenant_names) for a in cluster.plan.assignments
        )
        if n_hosts == 2:
            cluster2 = cluster

    r2 = throughput[2] / throughput[1]
    r4 = throughput[4] / throughput[1]
    assert r2 >= 1.7, (
        f"2-host aggregate throughput only {r2:.2f}x of 1 host "
        f"(placements {placements})"
    )
    assert r4 >= 3.0, (
        f"4-host aggregate throughput only {r4:.2f}x of 1 host "
        f"(placements {placements})"
    )

    # -- noisy-tenant isolation (2-host cluster, engines warm) -------
    # noisy/victim are each host's *heaviest* resident: their step
    # times dominate their host's phase, so backlog inflation (noisy)
    # and its absence (victim) are measured with the best signal over
    # container timer noise
    def heaviest(host):
        return max(
            host.tenant_names(),
            key=lambda n: tenants[n].config.expected_time_per_example,
        )

    noisy = heaviest(cluster2.hosts[0])
    victim = heaviest(cluster2.hosts[1])

    def victim_p99(burst):
        p99 = {}
        for host in cluster2.hosts:
            _, reqs = _host_phase(
                cluster2, host, traffic, rounds, burst=burst
            )
            _assert_exact(reqs, refs)
            for name, entries in reqs.items():
                if name in (noisy, victim):
                    p99[name] = latency_quantile(
                        [r.latency_s for _, _, r in entries], 0.99
                    )
        return p99

    # a real isolation breach is persistent; a p99-of-16-samples blip
    # on a loaded container is not — retry the paired measurement up
    # to 3x and gate on the best attempt (a breach fails all three)
    for _ in range(3):
        quiet = victim_p99(None)
        loud = victim_p99({noisy: burst_factor})
        noisy_ratio = loud[noisy] / max(quiet[noisy], 1e-9)
        victim_ratio = loud[victim] / max(quiet[victim], 1e-9)
        if noisy_ratio >= 2.0 and victim_ratio <= 1.5:
            break
    assert noisy_ratio >= 2.0, (
        f"the {burst_factor}x burst did not even hurt the noisy "
        f"tenant itself ({noisy_ratio:.2f}x) — no contention to "
        "isolate"
    )
    assert victim_ratio <= 1.5, (
        f"noisy tenant {noisy} inflated cross-host victim {victim} "
        f"p99 by {victim_ratio:.2f}x (isolation breach; "
        f"noisy's own p99 rose {noisy_ratio:.2f}x)"
    )

    # -- elastic scale-up under surge --------------------------------
    elastic_cluster = Cluster(
        tuple(tenants.values()), n_hosts=2, gamma=gamma,
        configs=SPACE, batch_sizes=(batch,),
        elastic={"high_water": 0.6, "low_water": 0.01, "sustain": 2,
                 "max_hosts": 4},
    )
    surge_reqs: dict = {name: [] for name in names}
    for i in range(1, 5):
        for name in names:
            surge_reqs[name].extend(
                (i, j, elastic_cluster.submit(name, x))
                for j, x in enumerate(traffic[name][i])
            )
        elastic_cluster.step(force=True)
    elastic_cluster.drain()
    _assert_exact(surge_reqs, refs)
    journal = elastic_cluster.elastic.journal
    ups = [r for r in journal if r.action == "scale_up"]
    assert ups, (
        "sustained surge produced no journaled scale_up "
        f"(journal: {[r.action for r in journal]})"
    )

    return [(
        f"cluster/4x_fashion_mnist/b{batch}/scaling",
        0.0,
        f"tput_2h_vs_1h={r2:.2f}x;"
        f"tput_4h_vs_1h={r4:.2f}x;"
        f"tput_1h_rps={throughput[1]:.0f};"
        f"tput_2h_rps={throughput[2]:.0f};"
        f"tput_4h_rps={throughput[4]:.0f};"
        f"noisy_self_p99={noisy_ratio:.1f}x;"
        f"victim_cross_p99={victim_ratio:.2f}x;"
        f"scale_ups={len(ups)};"
        f"journal={'|'.join(r.action for r in journal)};"
        f"hosts_after_surge={len(elastic_cluster.active_hosts())};"
        f"placement_2h={placements[2]};"
        f"gamma={gamma};tax_ms={tax_s * 1e3:.1f}",
    )]
