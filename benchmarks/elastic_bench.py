"""Elastic serving benchmark: SLO-driven width degradation vs
shedding under a synthetic admission surge (``repro.elastic``).

One fashion-MNIST BNN is turned into a nested-width subnet family
(fractions ``(1.0, 0.5, 0.25)`` — every narrower level a prefix view
of the base packed tensors, no weight copies), each level is planned
through the ordinary profile→map chain, and the same surge traffic is
pushed through two routers:

* **baseline** — a fixed-width ``ServingEngine`` behind a
  ``FleetRouter`` with a per-request deadline: admission control
  (backlog × profiled step estimate vs deadline) sheds everything the
  full-width step cannot absorb;
* **elastic** — an ``ElasticEngine`` behind the same router with a
  ``QualityController`` attached: sustained shed pressure hot-swaps
  the tenant one level narrower at a batch boundary, the narrower
  step admits more of the surge, and calm traffic restores full
  width under hysteresis.

Both phases run the admission math on *profiled* expected step times
(``live_min_samples`` is set unreachably high), so the shed counts are
deterministic functions of the planned configurations, not of this
container's wall clock — the measured quantity is the mechanism.

Hard assertions:

* every level's served outputs are **bit-exact** against that level's
  own packed reference forward (checked pinned per level *and* live
  on every surge/calm response, at whatever level the controller had
  selected when the round was admitted);
* the elastic run sheds **at most half** of what the fixed-width
  baseline sheds over the same surge (``shed_elastic <= 0.5 *
  shed_baseline``);
* full width is **recovered** after the surge (level back to 0 within
  the calm rounds) and both the degrade and the restore transitions
  are journaled ``QualityRecord``\\ s;
* the ``quality_floor`` is never violated: no observed level and no
  journaled transition ever exceeds it.

The row is functional (``us=0`` sentinel): shed ratios and the
transition trace ride in ``derived``; the asserts are the gate.
"""

from __future__ import annotations

import math

import jax
import numpy as np

from repro.bnn import build_model
from repro.bnn.models import forward_packed, pack_params, prepare_input_packed
from repro.elastic import ElasticEngine, ElasticSpec, SubnetFamily, plan_family
from repro.fleet import FleetRouter, QualityController
from repro.serving import ServingEngine

# admission must run on the profiled estimate for the whole bench:
# live telemetry would make shed counts container-noise-dependent
NEVER_LIVE = 10**9


def _router(engine, *, deadline_s, quality=None) -> FleetRouter:
    router = FleetRouter(quality=quality)
    router.add_tenant(
        "fm", engine, deadline_s=deadline_s,
        live_min_samples=NEVER_LIVE,
    )
    return router


def _run_phase(
    router, traffic, refs, *, surge_rounds, surge_per_round,
    calm_rounds, batch,
):
    """Drive surge then calm rounds; returns (shed_surge, levels_seen).

    Every completed response is asserted bit-exact against the
    reference outputs of the level that was serving when its round was
    admitted (level 0 for a plain engine)."""
    tenant = router.tenant("fm")
    engine = tenant.engine
    shed_at_surge_end = 0
    levels_seen = []
    for rnd in range(surge_rounds + calm_rounds):
        surge = rnd < surge_rounds
        n = surge_per_round * batch if surge else batch
        level = getattr(engine, "level", 0)
        levels_seen.append(level)
        reqs = [r for r in (router.submit("fm", x) for x in traffic[:n])
                if r is not None]
        router.step(force=True)
        for j, r in enumerate(reqs):
            out = r.wait(timeout=30.0)
            assert np.array_equal(out, refs[level][j % batch]), (
                f"round {rnd}: response {j} at level {level} is not "
                "bit-exact against that level's reference"
            )
        if surge:
            shed_at_surge_end = tenant.rejected
    return shed_at_surge_end, levels_seen


def run(
    scale: float = 1.0,
    batch: int = 4,
    repeats: int = 1,
    profile_repeats: int = 1,
    fractions=(1.0, 0.5, 0.25),
    quality_floor: int = 2,
    slack: float = 3.5,
    surge_rounds: int = 10,
    surge_per_round: int = 6,
    calm_rounds: int = 8,
    degrade_after: int = 2,
    restore_after: int = 3,
):
    del repeats  # the shed comparison is one deterministic co-run
    m = build_model("fashion_mnist", scale=scale)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    family = SubnetFamily.build(m, packed, ElasticSpec(fractions=fractions))
    plan = plan_family(
        family, batch_sizes=(batch,), repeats=profile_repeats, policy="dp"
    )

    est = [c.expected_time_per_example * batch for c in plan.configs]
    assert est[1] < est[0], (
        f"narrow level is not cheaper than full width ({est[1]:.2e}s vs "
        f"{est[0]:.2e}s); width degradation cannot absorb a surge here"
    )
    deadline_s = slack * est[0]

    # one fixed input batch; per-level packed reference outputs
    x01 = jax.random.uniform(
        jax.random.PRNGKey(7), (batch, *m.input_hw, m.in_channels)
    )
    xw = np.asarray(prepare_input_packed(x01))
    traffic = [xw[j % batch] for j in range(surge_per_round * batch)]
    refs = [
        np.asarray(forward_packed(lvl.model.specs, lvl.packed, xw))
        for lvl in family
    ]

    engine_kwargs = dict(allowed_batch_sizes=(batch,), max_wait_s=0.0)

    # -- pinned bit-exactness gate: every level vs its own reference --
    pinned = ElasticEngine(plan, **engine_kwargs)
    pinned.warm()
    for k in range(pinned.n_levels):
        assert pinned.set_level(k)
        reqs = [pinned.submit(x) for x in traffic[:batch]]
        pinned.step(force=True)
        for j, r in enumerate(reqs):
            assert np.array_equal(r.wait(timeout=30.0), refs[k][j]), (
                f"pinned level {k}: response {j} is not bit-exact"
            )
    assert pinned.set_level(0)

    # -- baseline: fixed full width, deadline sheds the surge ---------
    base_router = _router(
        ServingEngine(m, packed, plan.configs[0], **engine_kwargs),
        deadline_s=deadline_s,
    )
    shed_baseline, _ = _run_phase(
        base_router, traffic, refs, surge_rounds=surge_rounds,
        surge_per_round=surge_per_round, calm_rounds=calm_rounds,
        batch=batch,
    )
    assert shed_baseline > 0, (
        "the surge never tripped admission control at full width; "
        "raise surge_per_round or tighten slack"
    )

    # -- elastic: same traffic, quality controller attached -----------
    engine = ElasticEngine(
        plan, quality_floor=quality_floor, **engine_kwargs
    )
    engine.warm()
    quality = QualityController(
        degrade_after=degrade_after, restore_after=restore_after
    )
    router = _router(engine, deadline_s=deadline_s, quality=quality)
    shed_elastic, levels_seen = _run_phase(
        router, traffic, refs, surge_rounds=surge_rounds,
        surge_per_round=surge_per_round, calm_rounds=calm_rounds,
        batch=batch,
    )

    assert shed_elastic <= 0.5 * shed_baseline, (
        f"elastic shed {shed_elastic} requests vs baseline "
        f"{shed_baseline}; width degradation absorbed less than half "
        "the surge"
    )
    actions = [r.action for r in quality.journal]
    assert "degrade" in actions, "surge never triggered a degrade"
    assert "restore" in actions, "calm rounds never restored width"
    assert engine.level == 0, (
        f"full width not recovered after the surge (level "
        f"{engine.level} after {calm_rounds} calm rounds)"
    )
    assert max(levels_seen) <= quality_floor and all(
        r.to_level <= quality_floor for r in quality.journal
    ), "quality_floor violated"
    assert engine.level_switches >= 2 and engine.degraded_share > 0.0

    stats = router.stats()["fm"]
    trace = ">".join(
        f"{r.action[0].upper()}{r.to_level}" for r in quality.journal
    )
    return [(
        f"elastic/fashion_mnist/b{batch}/surge_shed",
        0.0,
        f"shed_ratio={shed_elastic / shed_baseline:.2f};"
        f"shed_elastic={shed_elastic};shed_baseline={shed_baseline};"
        f"levels={len(plan)};floor={quality_floor};"
        f"deadline_ms={deadline_s * 1e3:.2f};"
        f"est_ratio_l1={est[1] / est[0]:.2f};"
        f"deepest_level={max(levels_seen)};"
        f"switches={engine.level_switches};"
        f"degraded_share={stats['degraded_share']:.2f};"
        f"admitted={stats['admitted']};journal={trace};"
        f"surge_rounds={surge_rounds}x{surge_per_round}b;"
        f"calm_rounds={calm_rounds}",
    )]


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")
