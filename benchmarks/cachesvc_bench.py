"""Cache-service benchmark: shared-backend warm starts and the
background exploration loop (docs/ARCHITECTURE.md §14).

Two functional gates, both ``us=0`` sentinel rows (timings and
hit-rates ride in ``derived``; the assertions are the gate):

``cachesvc/warm_start_hit_rate``
    One model is planned cold through :func:`repro.api.plan_single`
    over a shared ``mem://`` backend, then re-planned ``warm_iters``
    times.  Every warm plan must be served entirely from the cache:
    the backend's miss counter is frozen after the cold pass (a miss
    would mean re-profiling on the serving path), the aggregate hit
    rate must clear 0.8, and every warm plan must reproduce the cold
    plan's mapping exactly.

``cachesvc/explore_stale_recovery``
    The PR 4 residual, end to end: an analytically profiled table is
    copied with one placement's kernel rows uniformly inflated (the
    planted-stale regime — the mapper routes around the inflated
    placement, so telemetry alone can never correct it).  One
    :func:`repro.cachesvc.jobs.explore_once` pass re-measures the
    stale frontier off the hot path (``measure_fn`` returns the
    uninflated truth), folds the ratios back through
    ``fold_observed``, and must persist a strictly better mapping.
    Because the inflation is uniform, the fold is exact and the
    persisted mapping must equal the ground-truth mapping computed on
    the uninflated table — the explore loop fully recovers from the
    staleness.  Exactly one measurement per frontier row, zero
    profiler involvement.
"""

from __future__ import annotations

import time

import jax

from repro import api
from repro.bnn import build_model
from repro.bnn.models import pack_params
from repro.cachesvc.jobs import execution_counts, explore_once
from repro.core.mapper import (
    DEVICE,
    HOST,
    map_efficient_configuration,
    placement_of,
)
from repro.core.profiler import ProfileTable
from repro.store import ProfileStore


def _inflate(table: ProfileTable, placement: str, factor: float,
             batch: int) -> ProfileTable:
    """A stale copy of `table`: kernel rows of `placement` uniformly
    slower by `factor`, totals rebuilt as kernel + unchanged
    boundary."""
    times, kernels = {batch: []}, {batch: []}
    for layer in range(len(table.layer_labels)):
        trow, krow = {}, {}
        for cfg in table.configs_for(batch, layer):
            t = table.times[batch][layer][cfg]
            k = table.kernel_time(batch, layer, cfg)
            if placement_of(cfg) == placement:
                trow[cfg] = k * factor + (t - k)
                krow[cfg] = k * factor
            else:
                trow[cfg], krow[cfg] = t, k
        times[batch].append(trow)
        kernels[batch].append(krow)
    return ProfileTable(
        table.model_name, (batch,), table.layer_labels, times,
        kernel_times=kernels, h2d_times=table.h2d_times,
        d2h_times=table.d2h_times,
    )


def run(
    scale: float = 0.4,
    batch: int = 4,
    warm_iters: int = 8,
    repeats: int = 1,
    profile_repeats: int = 1,
    stale_factor: float = 50.0,
):
    del repeats  # both rows are functional, not timing-swept
    m = build_model("fashion_mnist", scale=scale)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))

    # -- warm starts through a shared backend ------------------------
    store = ProfileStore("mem://")           # fresh anonymous backend
    t0 = time.perf_counter()
    cold = api.plan_single(
        m, packed, batch_sizes=(batch,), store=store,
        time_source="analytic", repeats=profile_repeats,
    )
    cold_s = time.perf_counter() - t0
    misses_after_cold = store.stats()["misses"]
    warm_s = []
    for _ in range(warm_iters):
        t0 = time.perf_counter()
        warm = api.plan_single(
            m, packed, batch_sizes=(batch,), store=store,
            time_source="analytic", repeats=profile_repeats,
        )
        warm_s.append(time.perf_counter() - t0)
        assert warm.config.layer_configs == cold.config.layer_configs
    stats = store.stats()
    assert stats["misses"] == misses_after_cold, (
        "a warm plan missed the cache (re-profiled on the serving "
        f"path): {stats}"
    )
    hit_rate = stats["hits"] / (stats["hits"] + stats["misses"])
    assert hit_rate >= 0.8, f"warm-start hit rate only {hit_rate:.2f}"
    rows = [(
        "cachesvc/warm_start_hit_rate",
        0.0,
        f"hit_rate={hit_rate:.2f};cold_ms={cold_s * 1e3:.1f};"
        f"warm_ms={min(warm_s) * 1e3:.2f};warm_iters={warm_iters}",
    )]

    # -- explore recovers a planted-stale mapping --------------------
    true = api.profile_model(
        m, packed, batch_sizes=(batch,), repeats=profile_repeats,
        time_source="analytic",
    )
    truth = map_efficient_configuration(
        true, policy="dp", batch_sizes=(batch,)
    )
    # inflate whichever placement's staleness actually distorts the
    # mapping (50x always pushes the truth's own placements off)
    for placement in (DEVICE, HOST):
        stale = _inflate(true, placement, stale_factor, batch)
        old = map_efficient_configuration(
            stale, policy="dp", batch_sizes=(batch,)
        )
        if old.layer_configs != truth.layer_configs:
            break
    assert old.layer_configs != truth.layer_configs

    xstore = ProfileStore("mem://")
    xstore.save_mapping(old)
    counts = execution_counts(old, steps=32)
    measured = []

    def measure_fn(layer, config, b):
        measured.append((layer, config))
        return true.kernel_time(b, layer, config)

    out = explore_once(
        xstore, m, stale, batch=batch, counts=counts,
        measure_fn=measure_fn,
    )
    assert out["explored"] == len(measured) > 0
    assert out["improved"] is True
    assert out["new_expected_s"] < out["old_expected_s"]
    refreshed = xstore.load_mapping(m, policy="dp", batch=batch)
    assert refreshed.layer_configs != old.layer_configs
    assert refreshed.layer_configs == truth.layer_configs, (
        "explore did not recover the ground-truth mapping"
    )
    rows.append((
        "cachesvc/explore_stale_recovery",
        0.0,
        f"explored={out['explored']};"
        f"old_us={out['old_expected_s'] * 1e6:.1f};"
        f"new_us={out['new_expected_s'] * 1e6:.1f};"
        f"recovered_truth=True;stale_factor={stale_factor:g}",
    ))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.3f},{derived}")
