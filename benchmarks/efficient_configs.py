"""Paper Tables IV/V (efficient configuration per layer) and Table VI
(minimum inference time + proper batch size), for both mapping
policies: the paper's greedy Algorithm 1 and the transfer-aware DP
(fused-executor cost model) — reported side by side against the
uniform baselines."""

from __future__ import annotations

import jax

from repro.bnn import build_model
from repro.bnn.models import pack_params
from repro.core.mapper import best_uniform, map_efficient_configuration
from repro.core.profiler import profile_bnn_model


def run(scale: float = 0.5, batch_sizes=(1, 4, 16), repeats: int = 2):
    rows = []
    for name in ("fashion_mnist", "cifar10"):
        m = build_model(name, scale=scale)
        packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
        table = profile_bnn_model(
            m, packed, batch_sizes=batch_sizes, repeats=repeats
        )
        ec_greedy = map_efficient_configuration(table, policy="greedy")
        ec_dp = map_efficient_configuration(table, policy="dp")
        for ec in (ec_greedy, ec_dp):
            # Table IV/V row: per-layer chosen configs
            mapping = " ".join(
                f"{label.split(':')[1]}={c}"
                for label, c in zip(ec.layer_labels, ec.layer_configs)
            )
            print(f"# TableIV/V {name} [{ec.policy}]: {mapping}")
        rows.append(
            (f"tableVI/{name}/HEP-greedy@b{ec_greedy.proper_batch_size}",
             ec_greedy.expected_time_per_example * 1e6,
             "speedup_vs_dp="
             f"{ec_greedy.expected_time_per_example / ec_dp.expected_time_per_example:.2f}x")
        )
        rows.append(
            (f"tableVI/{name}/HEP-dp@b{ec_dp.proper_batch_size}",
             ec_dp.expected_time_per_example * 1e6, "")
        )
        for base in ("CPU", "X", "XYZ"):
            b, t = best_uniform(table, base)
            rows.append(
                (f"tableVI/{name}/uniform-{base}@b{b}", t * 1e6,
                 f"speedup_vs_dp={t / ec_dp.expected_time_per_example:.2f}x")
            )
    return rows
