"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun JSONs.

    PYTHONPATH=src python -m benchmarks.report > results/roofline.md
"""

from __future__ import annotations

import sys

from benchmarks.roofline import load_cells


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def fmt_s(s):
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.0f}us"


def main():
    cells = load_cells()
    by = {}
    for r in cells:
        by[(r["arch"], r["shape"], r["multi_pod"])] = r

    print("| arch | shape | mesh | compute | memory | collective | "
          "dominant | MODEL_FLOPs | useful | peak GiB/dev | coll GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mp), r in sorted(by.items()):
        rf = r["roofline"]
        print(
            f"| {arch} | {shape} | {'2x16x16' if mp else '16x16'} "
            f"| {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
            f"| {fmt_s(rf['collective_s'])} | **{rf['dominant']}** "
            f"| {rf['model_flops']:.2e} | {rf['useful_ratio']:.2f} "
            f"| {fmt_bytes(r['memory']['peak_bytes_per_device'])} "
            f"| {fmt_bytes(r['collectives']['per_device_bytes'])} |"
        )

    n_ok = len(cells)
    print(f"\n{n_ok} cells ok.", file=sys.stderr)


if __name__ == "__main__":
    main()
