"""Benchmark harness — one module per paper table/figure, plus the
beyond-paper serving path.  Suite-by-suite details: EXPERIMENTS.md.

Prints ``name,us_per_call,derived`` CSV. Mapping to the paper:
  profile_layers     -> Fig. 4 (per-layer x per-implementation matrix)
  efficient_configs  -> Tables IV/V (mappings) + Table VI (min times),
                        DP vs greedy vs uniform baselines side by side
  batch_sweep        -> Fig. 5 (+ Fig. 1 CPU-vs-parallel gap)
  kernel_bench       -> §II-C compute substrate micro-bench, plus the
                        autotuned (open registry space) vs fixed-8
                        end-to-end DP expected-time comparison
  roofline           -> EXPERIMENTS.md §Roofline (reads results/dryrun)
  segment_bench      -> beyond-paper: fused device-segment dispatch
                        (plan IR + segment-scope kernel variants) vs
                        per-layer launch, bit-exact + speedup
  serve_bench        -> beyond-paper: segment-pipelined vs serial
                        serving (EfficientConfiguration.segments() ->
                        repro.serving), throughput + p50/p99
  adapt_bench        -> beyond-paper: drift-triggered remapping under
                        injected contention (repro.adapt) — frozen vs
                        adaptive latency, recovery ratio
  fleet_bench        -> beyond-paper: two-model co-serving
                        (repro.fleet) — joint contention-aware mapping
                        vs both-solo-all-GPU, measured co-run makespan
  cluster_bench      -> beyond-paper: multi-host cluster tier
                        (repro.cluster) — aggregate throughput vs host
                        count, noisy-tenant isolation, journaled
                        elastic scale-up
  cachesvc_bench     -> beyond-paper: shared cache service
                        (repro.cachesvc) — warm-start hit rate through
                        a shared backend, background explore loop
                        recovering a planted-stale mapping
  elastic_bench      -> beyond-paper: elastic nested-width subnets
                        (repro.elastic) — SLO-driven width degradation
                        vs fixed-width shedding under surge, bit-exact
                        per level, journaled degrade/restore
  estimator_bench    -> beyond-paper: learned latency estimator
                        (repro.estimator) — predictor-seeded DP on an
                        unprofiled model (zero profiling passes) vs
                        fully-profiled DP, plus planted-gamma
                        interference-law recovery

The CI regression gate over the tiny-size variants of kernel_bench,
serve_bench, adapt_bench, fleet_bench and cluster_bench lives in
``benchmarks/bench_smoke.py``.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        adapt_bench, batch_sweep, cachesvc_bench, cluster_bench,
        efficient_configs, elastic_bench, estimator_bench, fleet_bench,
        kernel_bench, profile_layers, roofline, segment_bench,
        serve_bench,
    )

    from benchmarks.bench_smoke import SMOKE_KWARGS

    quick = "--quick" in sys.argv
    suites = [
        # --quick reuses the bench-smoke gate's tiny settings so CI and
        # local quick runs measure the same workload
        ("kernel_bench", kernel_bench.run,
         SMOKE_KWARGS["kernel_bench"] if quick else {}),
        ("roofline", roofline.run, {}),
        ("efficient_configs", efficient_configs.run,
         {"scale": 0.25, "batch_sizes": (1, 4), "repeats": 1}
         if quick else {}),
        ("batch_sweep", batch_sweep.run,
         {"scale": 0.25, "batch_sizes": (1, 4), "repeats": 1}
         if quick else {}),
        ("profile_layers", profile_layers.run,
         {"scale": 0.25, "batch_sizes": (1,), "repeats": 1}
         if quick else {}),
        ("segment_bench", segment_bench.run,
         SMOKE_KWARGS["segment_bench"] if quick else {}),
        ("serve_bench", serve_bench.run,
         SMOKE_KWARGS["serve_bench"] if quick else {}),
        ("adapt_bench", adapt_bench.run,
         SMOKE_KWARGS["adapt_bench"] if quick else {}),
        ("fleet_bench", fleet_bench.run,
         SMOKE_KWARGS["fleet_bench"] if quick else {}),
        ("cluster_bench", cluster_bench.run,
         SMOKE_KWARGS["cluster_bench"] if quick else {}),
        ("cachesvc_bench", cachesvc_bench.run,
         SMOKE_KWARGS["cachesvc_bench"] if quick else {}),
        ("elastic_bench", elastic_bench.run,
         SMOKE_KWARGS["elastic_bench"] if quick else {}),
        # not in bench_smoke: the gates inside the suite are the gate
        ("estimator_bench", estimator_bench.run,
         {"train_scales": (0.25, 0.375), "target_scale": 0.5}
         if quick else {}),
    ]
    print("name,us_per_call,derived")
    for name, fn, kwargs in suites:
        t0 = time.time()
        try:
            rows = fn(**kwargs)
        except Exception as e:  # a failing suite must not hide others
            print(f"{name}/SUITE-ERROR,0,{e!r}")
            continue
        for rname, us, derived in rows:
            print(f"{rname},{us:.2f},{derived}")
        print(f"# suite {name} took {time.time()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
