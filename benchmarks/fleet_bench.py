"""Fleet co-serving benchmark: joint mapping vs both-solo-all-GPU for
two models sharing one platform.

Two BNNs (same family, different widths) are profiled over the paper's
near-tied placement pair — sequential ``CPU`` vs fully-parallel
``XYZ`` — exactly the regime where co-serving placement matters: each
model's *solo* optimum is the device, but two tenants timeslicing the
device are jointly slower than splitting across processors.  Two fleet
assignments are compared **on the same profile tables**:

* **all-GPU** — each tenant's best all-device mapping
  (``map_all_device``): what two independent HEP-BNN
  deployments would co-locate;
* **joint** — ``map_fleet``'s coordinate-descent assignment under the
  contention-inflation model (provably <= all-GPU under that model —
  asserted here and property-tested in ``tests/test_fleet.py``).

Both assignments are then *executed* as a real co-run: two
``ServingEngine``s behind a ``FleetRouter`` + ``DeviceTimeLedger``,
round-robin traffic, every response asserted bit-exact against the
per-model packed reference.  Contention is injected the same way
``adapt_bench`` injects it — a busy-wait tax per segment execution,
scaled by the *co-runners'* occupancy share of that segment's
processor under the assignment being run (a synthetic co-tenant
stealing exactly the time the interference model says it steals; the
tax dominates container noise).  Under all-GPU both tenants tax each
other's every device segment; under the joint split the cross-shares
collapse and the tax disappears — the measured makespan win is the
mechanism, not a lucky wall clock.

Between the two measured phases, the all-GPU co-run's ledger
calibrates the interference law (``repro.estimator.InterferenceFit``:
measured occupancy over the profiled solo stage times, at the metered
co-runner share).  When the fitted law has signal, the joint phase
re-plans under it — so the co-run executed is the one the *calibrated*
model chose, and ``map_fleet``'s never-worse guarantee is asserted
under the fitted law too.

Hard assertions: bit-exact outputs for both tenants under both
assignments; predicted joint makespan <= predicted all-GPU makespan
(the ``map_fleet`` guarantee, under the assumed gamma and again under
the fitted law); the joint plan actually separates the tenants (this
container's CPU/XYZ near-tie makes the escape profitable); and the
measured joint co-run makespan beats the measured all-GPU co-run.  ``joint_vs_allgpu`` (measured) and
``pred_ratio`` (model) are the headline numbers in ``derived``; the
row is functional (``us=0`` sentinel) since absolute co-run wall time
on a shared box is noise — the gates above are the gate.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.bnn import build_model
from repro.bnn.models import forward_packed, pack_params, prepare_input_packed
from benchmarks.contention import TaxedEngine, busy_wait
from repro.core.mapper import HOST
from repro.core.parallel_config import CPU, FULL_GPU
from repro.core.profiler import profile_bnn_model
from repro.estimator import InterferenceFit
from repro.fleet import (
    DeviceTimeLedger,
    FleetRouter,
    map_all_device,
    joint_makespan,
    map_fleet,
)

# the near-tied placement pair (see benchmarks/adapt_bench.py)
SPACE = (CPU, FULL_GPU)


class FleetContention:
    """The synthetic co-tenant: per-tenant busy-wait tax on every
    segment execution, sized by the *other* tenants' occupancy share
    of that segment's processor under the assignment being run."""

    def __init__(self, tax_s: float):
        self.tax_s = tax_s
        # tenant -> (host_share, device_share) for the current phase
        self.shares: dict = {}

    def set_assignment(self, configs: dict) -> None:
        self.shares = {
            name: cfg.placement_shares() for name, cfg in configs.items()
        }

    def co_share(self, tenant: str, placement: str) -> float:
        idx = 0 if placement == HOST else 1
        return sum(
            s[idx] for name, s in self.shares.items() if name != tenant
        )

    def apply(self, tenant: str, placement: str) -> None:
        busy_wait(self.tax_s * self.co_share(tenant, placement))


def _co_run(tenants, configs, contention, traffic, refs, rounds):
    """Serve `rounds` batches per tenant through one router; returns
    (makespan_s, ledger).  Asserts every response bit-exact."""
    contention.set_assignment(configs)
    ledger = DeviceTimeLedger()
    router = FleetRouter(ledger=ledger)
    for name, (model, packed, table) in tenants.items():
        router.add_tenant(name, TaxedEngine(
            model, packed, configs[name],
            allowed_batch_sizes=table.batch_sizes,
            tax=lambda placement, t=name: contention.apply(t, placement),
            observer=ledger.observer(name),
        ))
    # warm-up round (XLA compiles) outside the timed window
    warm = {
        name: [router.tenant(name).engine.submit(x)
               for x in traffic[name][0]]
        for name in tenants
    }
    router.drain()
    for name, reqs in warm.items():
        for j, r in enumerate(reqs):
            assert np.array_equal(r.wait(timeout=30.0), refs[name][0][j])

    t0 = time.perf_counter()
    reqs: dict = {name: [] for name in tenants}
    for i in range(1, rounds + 1):
        for name in tenants:
            reqs[name].extend(
                router.tenant(name).engine.submit(x)
                for x in traffic[name][i]
            )
        router.step(force=True)
    router.drain()
    makespan = time.perf_counter() - t0
    for name in tenants:
        per_batch = len(traffic[name][0])
        for j, r in enumerate(reqs[name]):
            ref = refs[name][1 + j // per_batch][j % per_batch]
            assert np.array_equal(r.wait(timeout=30.0), ref), (
                f"{name} response {j} != reference"
            )
    return makespan, ledger


def run(
    scale: float = 0.5,
    batch: int = 4,
    rounds: int = 8,
    repeats: int = 1,
    profile_repeats: int = 2,
    gamma: float = 2.0,
    tax_s: float = 6e-3,
):
    del repeats  # one co-run is the experiment; kept for harness symmetry
    names = ("narrow", "wide")
    scales = (scale, scale * 1.5)
    tenants: dict = {}
    tables = []
    for name, s in zip(names, scales):
        m = build_model("fashion_mnist", scale=s)
        packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
        table = profile_bnn_model(
            m, packed, batch_sizes=(batch,), configs=SPACE,
            repeats=profile_repeats,
        )
        tenants[name] = (m, packed, table)
        tables.append(table)

    # the two fleet assignments, priced on the same tables
    all_gpu = {
        name: map_all_device(t, batch_sizes=(batch,))
        for name, t in zip(names, tables)
    }
    plan = map_fleet(
        tables, names=names, configs=SPACE, batch_sizes=(batch,),
        gamma=gamma,
    )
    joint = dict(zip(names, plan.configs))

    pred_allgpu = joint_makespan(
        tables, [all_gpu[n] for n in names], gamma=gamma
    )
    pred_joint = plan.joint_makespan_s
    assert pred_joint <= pred_allgpu + 1e-12, (
        "map_fleet violated its never-worse-than-all-GPU guarantee"
    )
    placements = {
        name: "".join(
            "H" if c == CPU else "D" for c in joint[name].layer_configs
        )
        for name in names
    }
    assert any(
        c == CPU for name in names for c in joint[name].layer_configs
    ), (
        "joint plan kept both tenants all-device — the CPU/XYZ "
        f"near-tie does not hold here (placements {placements})"
    )

    # deterministic per-round traffic + references, shared by phases
    traffic: dict = {}
    refs: dict = {}
    for name, s in zip(names, scales):
        m, packed, _ = tenants[name]
        traffic[name], refs[name] = [], []
        for i in range(rounds + 1):
            x01 = jax.random.uniform(
                jax.random.PRNGKey(500 + i),
                (batch, *m.input_hw, m.in_channels),
            )
            xw = np.asarray(prepare_input_packed(x01))
            traffic[name].append([xw[j] for j in range(batch)])
            refs[name].append(
                np.asarray(forward_packed(m.specs, packed, xw))
            )

    contention = FleetContention(tax_s)
    allgpu_s, allgpu_ledger = _co_run(
        tenants, all_gpu, contention, traffic, refs, rounds
    )

    # calibrate the interference law from the all-GPU co-run's own
    # ledger: solo per-step expectations are the profiled stage times
    # at the serving batch, measured occupancy over them is the
    # observed inflation at the metered co-runner share
    expected_step = {
        name: tuple(s * batch for s in all_gpu[name].stage_times())
        for name in names
    }
    fit = InterferenceFit.from_ledger(allgpu_ledger, expected_step)
    law = fit.fit()
    if law.gamma > 0.0:
        # re-plan under the fitted law; the never-worse guarantee must
        # hold under it exactly as under the assumed gamma
        plan = map_fleet(
            tables, names=names, configs=SPACE, batch_sizes=(batch,),
            law=law,
        )
        pred_allgpu = joint_makespan(
            tables, [all_gpu[n] for n in names], law=law
        )
        pred_joint = plan.joint_makespan_s
        assert pred_joint <= pred_allgpu + 1e-12, (
            "map_fleet violated never-worse-than-all-GPU under the "
            "fitted law"
        )
        fitted_joint = dict(zip(names, plan.configs))
        if any(
            c == CPU for n in names for c in fitted_joint[n].layer_configs
        ):
            # the fitted law also separates the tenants: the measured
            # joint run below executes the *calibrated* plan
            joint = fitted_joint
            placements = {
                name: "".join(
                    "H" if c == CPU else "D"
                    for c in joint[name].layer_configs
                )
                for name in names
            }

    joint_s, ledger = _co_run(
        tenants, joint, contention, traffic, refs, rounds
    )
    assert joint_s < allgpu_s, (
        f"joint co-run ({joint_s * 1e3:.1f}ms) not faster than "
        f"all-GPU co-run ({allgpu_s * 1e3:.1f}ms)"
    )

    metered = ledger.shares()
    shares = ";".join(
        f"{n}_dev_share={metered[n][1]:.2f}" for n in names
    )
    return [(
        f"fleet/2x_fashion_mnist/b{batch}/joint_vs_allgpu",
        0.0,
        f"joint_vs_allgpu={joint_s / allgpu_s:.2f}x;"
        f"pred_ratio={pred_joint / pred_allgpu:.2f}x;"
        f"joint_ms={joint_s * 1e3:.1f};"
        f"allgpu_ms={allgpu_s * 1e3:.1f};"
        f"pred_joint_us={pred_joint * 1e6:.1f};"
        f"pred_allgpu_us={pred_allgpu * 1e6:.1f};"
        f"placements={'|'.join(placements[n] for n in names)};"
        f"rounds_x2={rounds};"
        f"descent_rounds={plan.rounds};"
        f"converged={plan.converged};"
        f"fitted_gamma={law.gamma:.2f};"
        f"fit_obs={law.n_obs};"
        f"fit_knots={len(law.knots)};"
        f"gamma={gamma};tax_ms={tax_s * 1e3:.1f};{shares}",
    )]
