"""xnor/popcount kernel micro-benchmarks, plus the autotune headline:
end-to-end expected time of the DP mapping over the **open** registry
space vs the paper's fixed-8 space, on the same measured profile.

The micro rows time individual variants on paper-sized GEMM shapes;
the ``kernel/autotune/...`` rows profile a whole model through
``autotune_bnn_model`` (registry sweep with warm-up pruning) and map
it twice — full space vs ``configs=CONFIGS`` — so ``vs_fixed8`` is an
apples-to-apples report of what widening the config space buys.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.bnn import build_model
from repro.bnn.models import pack_params
from repro.core.mapper import map_efficient_configuration
from repro.core.parallel_config import CONFIGS
from repro.core.profiler import autotune_bnn_model
from repro.kernels.ops import xnor_gemm

# (label, B, P, Kw, N): CIFAR C256 block + FC
CASES = (
    ("conv_c256", 8, 256, 72, 256),
    ("fc1024", 32, 1, 128, 1024),
)


def _bench(fn, n=3):
    fn().block_until_ready()
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _micro_rows():
    rows = []
    key = jax.random.PRNGKey(0)
    for label, b, p, kw, n in CASES:
        a = jax.random.randint(key, (b, p, kw), -2**31, 2**31 - 1,
                               dtype=jnp.int32)
        w = jax.random.randint(jax.random.fold_in(key, 1), (n, kw),
                               -2**31, 2**31 - 1, dtype=jnp.int32)
        t_ref = _bench(lambda: xnor_gemm(a, w, k_true=kw * 32,
                                         backend="ref"))
        rows.append((f"kernel/{label}/ref", t_ref * 1e6, ""))
        for asp in (("X",), ("Y", "Z"), ("X", "Y", "Z")):
            t = _bench(lambda asp=asp: xnor_gemm(
                a, w, k_true=kw * 32, aspects=asp, backend="variant"))
            rows.append(
                (f"kernel/{label}/{''.join(asp)}", t * 1e6,
                 f"vs_ref={t_ref / t:.2f}x")
            )
    return rows


def _autotune_rows(scale, batch_sizes, repeats):
    rows = []
    m = build_model("fashion_mnist", scale=scale)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = autotune_bnn_model(
        m, packed, batch_sizes=batch_sizes, repeats=repeats
    )
    dp_full = map_efficient_configuration(table, policy="dp")
    dp_fixed = map_efficient_configuration(
        table, policy="dp", configs=CONFIGS
    )
    t_full = dp_full.expected_time_per_example
    t_fixed = dp_fixed.expected_time_per_example
    extended = sorted(
        {c for c in dp_full.layer_configs if c not in CONFIGS}
    )
    space = sum(len(cs) for cs in dp_full.config_space)
    rows.append(
        (f"kernel/autotune/{m.name}/fixed8_dp@b"
         f"{dp_fixed.proper_batch_size}",
         t_fixed * 1e6, f"space={8 * len(m.specs)}")
    )
    rows.append(
        (f"kernel/autotune/{m.name}/autotuned_dp@b"
         f"{dp_full.proper_batch_size}",
         t_full * 1e6,
         f"vs_fixed8={t_fixed / t_full:.2f}x;space={space};"
         f"extended_picks={','.join(extended) if extended else 'none'}")
    )
    return rows


def run(scale: float = 0.5, batch_sizes=(1, 8), repeats: int = 2):
    return _micro_rows() + _autotune_rows(scale, batch_sizes, repeats)
