"""xnor/popcount kernel micro-benchmarks: measured XLA-variant times on
the host platform for paper-sized layers (the framework's compute
substrate)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.ops import xnor_gemm

# (label, B, P, Kw, N): CIFAR C256 block + FC
CASES = (
    ("conv_c256", 8, 256, 72, 256),
    ("fc1024", 32, 1, 128, 1024),
)


def _bench(fn, n=3):
    fn().block_until_ready()
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for label, b, p, kw, n in CASES:
        a = jax.random.randint(key, (b, p, kw), -2**31, 2**31 - 1,
                               dtype=jnp.int32)
        w = jax.random.randint(jax.random.fold_in(key, 1), (n, kw),
                               -2**31, 2**31 - 1, dtype=jnp.int32)
        t_ref = _bench(lambda: xnor_gemm(a, w, k_true=kw * 32,
                                         backend="ref"))
        rows.append((f"kernel/{label}/ref", t_ref * 1e6, ""))
        for asp in (("X",), ("Y", "Z"), ("X", "Y", "Z")):
            t = _bench(lambda asp=asp: xnor_gemm(
                a, w, k_true=kw * 32, aspects=asp, backend="variant"))
            rows.append(
                (f"kernel/{label}/{''.join(asp)}", t * 1e6,
                 f"vs_ref={t_ref / t:.2f}x")
            )
    return rows
