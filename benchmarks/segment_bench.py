"""Fused-segment benchmark: one whole device-resident segment executed
as a single fused dispatch (segment-scope kernel variants,
``repro.kernels.segment_fused``) versus the per-layer launch the
pre-plan driver used — one jitted executable per layer, with a
blocking sync after each.

The workload is ``fashion_mnist`` under the mapping the HEP-BNN search
itself tends to find on this container: the first conv (patch
extraction over the unpacked input image — the one genuinely
compute-heavy layer at bench scale) on the host, everything after it
on the device.  That leaves one device-resident segment spanning
layers ``1..N`` — nine layers whose per-layer execution pays a
dispatch + host sync at every boundary, while the fused variants keep
activations as int32 bitplane words resident on the device and pay one
dispatch for the whole segment.  At batch 1 (the latency-critical
serving case) the per-layer launch tax dominates this segment, which
is exactly the regime segment fusion targets; at larger batches the
GEMM work amortizes the tax and the two paths converge.

For each batch size and each applicable segment-scope variant
(``seg_xla`` always; ``seg_pallas`` when the segment fits the
interpret work cap / VMEM budget), the bench asserts the fused output
bit-exact against the per-layer chain (and against the model's
reference ``forward_packed``), then times best-of-``repeats``.

Rows (``us_per_call`` is us per **example**):

    segment/<model>/b<B>/span<s>:<e>/per_layer    baseline launch
    segment/<model>/b<B>/span<s>:<e>/<variant>    fused, derived
                                                  carries speedup
    segment/<model>/fused_bitexact                functional row
                                                  (us=0 sentinel)

The functional row is the CI coverage gate: its presence proves the
bit-exactness asserts ran; ``derived`` reports the best measured
speedup.  Timing rows are regression-gated like every other suite
(``benchmarks/bench_smoke.py``).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.bnn import build_model
from repro.bnn.models import forward_packed, pack_params, prepare_input_packed
from repro.core.mapped_model import _layer_fns
from repro.core.mapper import price_mapping
from repro.core.parallel_config import CPU, FULL_GPU
from repro.core.plan import build_plan, device_spans
from repro.core.profiler import profile_bnn_model
from repro.kernels.registry import (
    DEFAULT_REGISTRY,
    current_platform,
    segment_shape_of,
)


def _timeit(fn, x, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(x)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run(
    scale: float = 0.5,
    batch_sizes=(1, 4),
    repeats: int = 3,
    profile_repeats: int = 1,
    min_speedup: float | None = None,
):
    """``min_speedup`` asserts the best fused-vs-per-layer ratio (the
    acceptance check is >= 1.5x at batch 1 on this container); ``None``
    reports without asserting — timings on a loaded box are advisory."""
    m = build_model("fashion_mnist", scale=scale)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = profile_bnn_model(
        m, packed, batch_sizes=batch_sizes, repeats=profile_repeats
    )
    # first conv on the host, the rest device-resident: one multi-layer
    # device segment (module docstring)
    mapping = (CPU,) + tuple(FULL_GPU for _ in m.specs[1:])
    platform = current_platform()
    device = jax.devices()[0]

    rows = []
    best_speedup = 0.0
    variants_seen: set = set()
    for b in batch_sizes:
        ec = price_mapping(table, b, mapping)
        plan = build_plan(ec, mode="segments")
        (start, stop) = device_spans(ec)[0]
        assert (start, stop) == (1, len(m.specs)), "expected one segment"
        node = next(n for n in plan.nodes if n.on_device)

        x = prepare_input_packed(
            jax.random.uniform(
                jax.random.PRNGKey(1), (b, *m.input_hw, m.in_channels)
            )
        )
        want = np.asarray(forward_packed(m.specs, packed, x))

        # per-layer launch: one jitted executable per layer, blocking
        # sync at every boundary — the pre-plan execution structure
        layer_fns = [jax.jit(f) for f in _layer_fns(m, packed, ec)]
        xd = jax.device_put(
            np.asarray(layer_fns[0](np.asarray(x))), device
        )                                    # host layer 0's output, H2D

        def per_layer(xd, _fns=tuple(layer_fns[start:stop])):
            for f in _fns:
                xd = f(xd)
                jax.block_until_ready(xd)
            return xd

        assert np.array_equal(want, np.asarray(per_layer(xd)))  # warmup
        t_layer = _timeit(per_layer, xd, repeats)
        span = f"span{start}:{stop}"
        rows.append(
            (
                f"segment/{m.name}/b{b}/{span}/per_layer",
                t_layer / b * 1e6,
                f"layers={stop - start}",
            )
        )

        shape = segment_shape_of(m.specs[start:stop], packed[start:stop], b)
        for v in DEFAULT_REGISTRY.applicable_segments(shape, platform):
            fn = v.builder(
                tuple(m.specs[start:stop]),
                list(packed[start:stop]),
                node.in_encoding,
            )
            got = np.asarray(fn(xd))
            assert np.array_equal(want, got), (
                f"fused {v.name} != per-layer output"
            )
            t_fused = _timeit(fn, xd, repeats)
            speedup = t_layer / t_fused
            best_speedup = max(best_speedup, speedup)
            variants_seen.add(v.name)
            rows.append(
                (
                    f"segment/{m.name}/b{b}/{span}/{v.name}",
                    t_fused / b * 1e6,
                    f"speedup={speedup:.2f}x",
                )
            )
    assert variants_seen, "no segment-scope variant was applicable"
    if min_speedup is not None:
        assert best_speedup >= min_speedup, (
            f"best fused speedup {best_speedup:.2f}x < {min_speedup}x"
        )
    rows.append(
        (
            f"segment/{m.name}/fused_bitexact",
            0.0,
            f"variants={','.join(sorted(variants_seen))};"
            f"best_speedup={best_speedup:.2f}x",
        )
    )
    return rows
