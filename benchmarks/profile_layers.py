"""Paper Fig. 4 analogue: the per-layer x per-implementation timing
matrix the mapping algorithm consumes."""

from __future__ import annotations

import jax

from repro.bnn import build_model
from repro.bnn.models import pack_params
from repro.core.parallel_config import CONFIGS
from repro.core.profiler import profile_bnn_model


def run(scale: float = 0.5, batch_sizes=(1, 8), repeats: int = 2):
    rows = []
    for name in ("fashion_mnist", "cifar10"):
        m = build_model(name, scale=scale)
        packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
        table = profile_bnn_model(
            m, packed, batch_sizes=batch_sizes, repeats=repeats
        )
        b = batch_sizes[-1]
        for i, label in enumerate(table.layer_labels):
            row = table.times[b][i]
            for cfg in CONFIGS:
                rows.append(
                    (f"profile/{name}/{label}/{cfg}@b{b}",
                     row[cfg] * 1e6, "")
                )
    return rows
