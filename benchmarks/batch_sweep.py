"""Paper Fig. 5 analogue: execution time vs batch size for sequential
CPU, naive Data-only GPU (X), fully-parallel GPU (XYZ) and the HEP
efficient configuration. Also covers Fig. 1 (CPU vs parallel gap)."""

from __future__ import annotations

import jax

from repro.bnn import build_model
from repro.bnn.models import pack_params
from repro.core.mapper import map_efficient_configuration, uniform_total
from repro.core.profiler import profile_bnn_model


def run(scale: float = 0.5, batch_sizes=(1, 4, 16), repeats: int = 2):
    rows = []
    for name in ("fashion_mnist", "cifar10"):
        m = build_model(name, scale=scale)
        packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
        table = profile_bnn_model(
            m, packed, batch_sizes=batch_sizes, repeats=repeats
        )
        ec = map_efficient_configuration(table)
        for b in batch_sizes:
            hep_b = sum(
                min(table.times[b][i].values())
                for i in range(len(table.layer_labels))
            )
            for label, t in (
                ("CPU", uniform_total(table, "CPU", b)),
                ("naiveX", uniform_total(table, "X", b)),
                ("fullXYZ", uniform_total(table, "XYZ", b)),
                ("HEP", hep_b),
            ):
                rows.append((f"fig5/{name}/{label}@b{b}", t * 1e6, ""))
        rows.append(
            (f"fig5/{name}/HEP-proper@b{ec.proper_batch_size}",
             ec.expected_time_per_example * 1e6, "")
        )
    return rows
