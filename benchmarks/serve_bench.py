"""Serving-path benchmark: segment-pipelined vs serial execution of a
mixed host/device mapping, across batch sizes.

For each batch size, a burst of micro-batches (all arriving at t0) is
run (a) serially — one micro-batch at a time, blocking at every
segment boundary — and (b) through ``SegmentPipeline.run_pipelined``,
which overlaps the host segments of micro-batch *i+1* with the device
segments of micro-batch *i*.  Reports examples/s-equivalent throughput
(``us_per_call`` is us **per example**) and p50/p99 time-in-system per
micro-batch, plus the cost model's predicted pipeline speedup
(``EfficientConfiguration.pipelined_expected_time``).  Outputs are
asserted bit-exact between the two paths.

The mapping is the DP's if it is genuinely mixed (contains both host
and device segments); otherwise the canonical mixed split — GEMM
layers (conv/fc) on the device, elementwise layers on the host — is
forced via ``price_mapping`` so the pipeline always has
two stages to overlap.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.bnn import build_model
from repro.bnn.models import pack_params, prepare_input_packed
from repro.core.mapper import (
    map_efficient_configuration,
    price_mapping,
    segments_of,
)
from repro.core.profiler import profile_bnn_model
from repro.serving import SegmentPipeline, canonical_mixed_mapping


def _mixed_mapping(model, ec_dp):
    segs = segments_of(ec_dp.layer_configs)
    if len(segs) >= 2:
        return ec_dp.layer_configs
    return canonical_mixed_mapping(model)


def _percentiles(completions_s):
    lat_ms = np.asarray(completions_s) * 1e3
    return (
        f"p50_ms={np.percentile(lat_ms, 50):.2f};"
        f"p99_ms={np.percentile(lat_ms, 99):.2f}"
    )


def run(
    scale: float = 0.5,
    batch_sizes=(1, 4, 16),
    repeats: int = 3,
    n_microbatches: int = 8,
    profile_repeats: int = 2,
):
    m = build_model("fashion_mnist", scale=scale)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = profile_bnn_model(
        m, packed, batch_sizes=batch_sizes, repeats=profile_repeats
    )
    mapping = _mixed_mapping(
        m, map_efficient_configuration(table, policy="dp")
    )

    rows = []
    for b in batch_sizes:
        ec = price_mapping(table, b, mapping)
        pipe = SegmentPipeline(m, packed, ec)
        inputs = [
            prepare_input_packed(
                jax.random.uniform(
                    jax.random.PRNGKey(i),
                    (b, *m.input_hw, m.in_channels),
                )
            )
            for i in range(n_microbatches)
        ]
        n_examples = n_microbatches * b

        # warmup / compile both paths, and capture the reference output
        ref = [pipe.run_serial(x) for x in inputs]
        got = pipe.run_pipelined(inputs)
        for r, g in zip(ref, got):
            assert np.array_equal(r, g), "pipelined != serial output"

        best_serial, serial_done = float("inf"), None
        best_piped, piped_done = float("inf"), None
        for _ in range(repeats):
            done = []
            t0 = time.perf_counter()
            for x in inputs:
                pipe.run_serial(x)
                done.append(time.perf_counter() - t0)
            total = time.perf_counter() - t0
            if total < best_serial:
                best_serial, serial_done = total, done

            done = [0.0] * n_microbatches
            t0 = time.perf_counter()
            pipe.run_pipelined(
                inputs,
                on_complete=lambda i, out, t0=t0, done=done: done.__setitem__(
                    i, time.perf_counter() - t0
                ),
            )
            total = time.perf_counter() - t0
            if total < best_piped:
                best_piped, piped_done = total, done

        speedup = best_serial / best_piped
        est = ec.expected_time_per_example / ec.pipelined_expected_time(
            n_microbatches
        )
        rows.append(
            (
                f"serve/{m.name}/b{b}/serial",
                best_serial / n_examples * 1e6,
                _percentiles(serial_done),
            )
        )
        rows.append(
            (
                f"serve/{m.name}/b{b}/pipelined",
                best_piped / n_examples * 1e6,
                _percentiles(piped_done)
                + f";speedup={speedup:.2f}x;model_est={est:.2f}x",
            )
        )
    return rows
