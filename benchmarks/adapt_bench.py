"""Adaptive-runtime benchmark: inject synthetic contention on the
processor the serving mapping leans on, and compare a frozen mapping
against the drift-remapping engine.

The search space is the paper's Fig. 5 baseline pair — sequential
``CPU`` vs fully-parallel ``XYZ`` — because on this container those
two placements are near-tied end to end, which is exactly the regime
where adaptation matters: when the alternative processor is close, a
contended optimum *should* be abandoned, and the recovered latency
lands within a few percent of the pre-contention optimum.  (With the
full variant space the device side dominates this host outright and a
"recovered" mapping would just be the device mapping — still correct,
but a trivial demonstration.)

Phases per batch size, both engines starting from the same DP mapping
over that space:

1. **calibrate** — uncontended serving with telemetry on.  Live
   pipeline wall times differ systematically from the profiler's
   isolated per-layer times (dispatch, sync, conversion overheads), so
   the controller's first folds *calibrate* the table to live behavior
   — the detector goes quiet once predictions match what the pipeline
   actually does.  Runs until the journal is stable (no new entry for
   a few batches, bounded by ``calibrate_max``).
2. **pre** — the uncontended steady state: the pre-contention optimum
   recovery is judged against.
3. **contention on** — every segment placed on the *dominant*
   processor of the calibrated mapping now pays a busy-wait tax (a
   stand-in co-tenant burning that processor; the other placement is
   unaffected).  The *frozen* engine keeps its mapping and stays
   degraded.  The *adaptive* engine's telemetry sees those segments
   blow past predictions; after the hysteresis clears, the controller
   folds the observations in, re-runs the DP (which routes the
   affected layers onto the uncontended processor), and hot-swaps.
4. **steady** — the adaptive engine's recovered steady state: the
   median of the last ``steady_k`` batches, measured only once the
   last hot swap is at least a full window behind (bounded by
   ``settle_max`` extra batches) — a window straddling a swap would
   mix compile stalls and half-migrated mappings into "steady".

The tax must dominate profiling noise: telemetry can only correct the
rows of placements that actually *execute*, so the DP's opinion of the
uncontended alternative rests on its profiled rows alone — a tax
comparable to best-of-N profiling jitter could leave the corrected
table still (wrongly) preferring the contended side.  The default
``tax_s`` is an order of magnitude above per-segment times at bench
scale, so the fold always flips the comparison.

Assertions (hard, every run): all adaptive-engine responses — before,
during, and after remaps — are bit-exact against the serial packed
reference; the controller performs at least one contended remap within
``converge_batches`` batches of contention onset; and the recovered
steady state holds the line against the frozen engine (loose 1.5x
bound on a spike-robust percentile estimator — per-segment runtime
overheads are not in the cost model, so "slightly above frozen on a
noisy box" is not a broken loop; the typical result is ~0.3x).
Whether remapping went quiet within the settle budget is reported
(``quiet=``), not asserted: on a genuinely still-shifting box the
detector *should* keep firing.
``recovery=`` in the derived column is the headline: recovered /
pre-contention latency (target <= 1.15x, reported rather than
hard-gated — wall clocks on shared CI boxes are too noisy to fail a
build on); ``frozen=`` is what not adapting costs.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.adapt import DriftDetector, RemapController, SegmentTelemetry
from repro.bnn import build_model
from repro.bnn.models import forward_packed, pack_params, prepare_input_packed
from repro.core.mapper import map_efficient_configuration
from repro.core.parallel_config import CPU, FULL_GPU
from repro.core.profiler import profile_bnn_model

from benchmarks.contention import TaxedEngine, busy_wait

# the near-tied placement pair the experiment searches over (paper
# Fig. 5's sequential-CPU and fully-parallel baselines)
SPACE = (CPU, FULL_GPU)


class Contention:
    """A switchable busy-wait tax per segment execution on one
    placement — the synthetic co-tenant.  Busy-waiting (not sleeping)
    models a core actually stolen from that processor.  Injected via
    ``benchmarks.contention.TaxedEngine`` (shared with
    ``fleet_bench``), whose ``_build_pipeline`` wrap makes every
    pipeline the engine ever builds — including ones hot-swapped in
    by remaps — pay the tax; escaping it requires actually moving
    work off the contended processor, which is the thing being
    measured."""

    def __init__(self):
        self.placement: str | None = None     # mapper HOST/DEVICE value
        self.tax_s = 0.0

    def apply(self, placement: str):
        if placement == self.placement:
            busy_wait(self.tax_s)


class _Traffic:
    """Deterministic stream of (packed batch, reference outputs); both
    engines replay identical phases from identical offsets."""

    def __init__(self, model, packed, batch):
        self.model, self.packed, self.batch = model, packed, batch
        self._cache: dict = {}

    def at(self, i: int):
        if i not in self._cache:
            m = self.model
            x01 = jax.random.uniform(
                jax.random.PRNGKey(100 + i),
                (self.batch, *m.input_hw, m.in_channels),
            )
            xw = np.asarray(prepare_input_packed(x01))
            ref = np.asarray(forward_packed(m.specs, self.packed, xw))
            self._cache[i] = (xw, ref)
        return self._cache[i]


def _serve(engine, traffic, start, n, step=None):
    """Serve batches [start, start+n) through one forced step each;
    asserts bit-exactness, returns per-batch wall seconds."""
    step = step if step is not None else engine.step
    lat = []
    for i in range(start, start + n):
        xw, ref = traffic.at(i)
        reqs = [engine.submit(xw[j]) for j in range(xw.shape[0])]
        t0 = time.perf_counter()
        step(force=True)
        lat.append(time.perf_counter() - t0)
        for j, req in enumerate(reqs):
            got = req.wait(timeout=30.0)
            assert np.array_equal(got, ref[j]), "output != reference"
    return lat


def run(
    scale: float = 0.5,
    batch_sizes=(4,),
    repeats: int = 1,
    profile_repeats: int = 2,
    calibrate_min: int = 4,
    calibrate_max: int = 20,
    pre_batches: int = 6,
    contended_batches: int = 30,
    converge_batches: int = 24,
    steady_k: int = 5,
    settle_max: int = 16,
    tax_s: float = 8e-3,
):
    del repeats  # one pass is the experiment; kept for harness symmetry
    m = build_model("fashion_mnist", scale=scale)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = profile_bnn_model(
        m, packed, batch_sizes=tuple(batch_sizes), repeats=profile_repeats
    )

    rows = []
    for b in batch_sizes:
        ec0 = map_efficient_configuration(
            table, configs=SPACE, policy="dp", batch_sizes=(b,)
        )
        traffic = _Traffic(m, packed, b)
        contention = Contention()
        telemetry = SegmentTelemetry(alpha=0.5, window=32, sample_every=1)
        adaptive = TaxedEngine(
            m, packed, ec0,
            allowed_batch_sizes=table.batch_sizes, tax=contention.apply,
            telemetry=telemetry,
        )
        # rel_threshold matters: a fixed per-segment tax folded into
        # per-layer rows can leave a shrunken contended segment whose
        # observed/predicted ratio sits just above 1.5x — the detector
        # must keep firing until the DP walks it off entirely
        controller = RemapController(
            adaptive, table, configs=SPACE,
            detector=DriftDetector(
                rel_threshold=0.6, min_samples=3, direction="both"
            ),
        )

        # phase 1: calibrate until the journal is stable
        i = 0
        _serve(adaptive, traffic, i, calibrate_min, step=controller.step)
        i += calibrate_min
        quiet = 0
        while quiet < 3 and i - calibrate_min < calibrate_max:
            n_before = len(controller.journal)
            _serve(adaptive, traffic, i, 1, step=controller.step)
            i += 1
            quiet = quiet + 1 if len(controller.journal) == n_before else 0
        calibration_remaps = len(controller.journal)

        # the frozen engine serves the *calibrated* optimum — the
        # strongest non-adaptive baseline, not the raw-profile mapping
        frozen = TaxedEngine(
            m, packed, adaptive.config,
            allowed_batch_sizes=table.batch_sizes, tax=contention.apply,
        )
        _serve(frozen, traffic, 0, 2)    # compile

        # phase 2: the uncontended optimum
        frozen_pre = _serve(frozen, traffic, i, pre_batches)
        adaptive_pre = _serve(adaptive, traffic, i, pre_batches,
                              step=controller.step)
        i += pre_batches
        pre_s = float(np.median(adaptive_pre))
        pre_frozen_s = float(np.median(frozen_pre))

        # phase 3: contend the placement the calibrated mapping leans
        # on; frozen stays put, adaptive walks off it
        host_share, device_share = adaptive.config.stage_times()
        from repro.core.mapper import DEVICE, HOST

        contention.placement = (
            DEVICE if device_share >= host_share else HOST
        )
        contention.tax_s = tax_s
        telemetry.reset()          # clean floor baseline for the phase
        onset_step = adaptive.steps
        frozen_lat = _serve(frozen, traffic, i, contended_batches)
        adaptive_lat = _serve(adaptive, traffic, i, contended_batches,
                              step=controller.step)
        i += contended_batches
        # settle: keep serving (bounded) until the last swap is a full
        # steady window behind, so the measurement holds no compile
        # stalls or half-migrated mappings
        settled = 0
        while settled < settle_max and controller.journal and (
            adaptive.steps - controller.journal[-1].at_step <= steady_k
        ):
            adaptive_lat += _serve(adaptive, traffic, i, 1,
                                   step=controller.step)
            i += 1
            settled += 1
        contended = [
            r for r in controller.journal if r.at_step > onset_step
        ]
        assert contended, (
            f"no remap within {contended_batches} contended batches"
        )
        first_remap = contended[0].at_step - onset_step
        assert first_remap <= converge_batches, (
            f"first contended remap took {first_remap} batches "
            f"(budget {converge_batches})"
        )
        assert adaptive.swaps == len(controller.journal)
        quiet = (
            adaptive.steps - controller.journal[-1].at_step > steady_k
        )

        frozen_s = float(np.median(frozen_lat))
        # steady-state estimator robust to swap-compile spikes and OS
        # jitter: the 25th percentile of the last 2k batches tracks
        # the recovered floor even when late remaps (a genuinely
        # still-shifting box keeps the detector firing — that is it
        # working) drop recompile stalls into the window
        steady_s = float(
            np.percentile(adaptive_lat[-2 * steady_k:], 25)
        )
        # the adapted mapping must at least hold the line against the
        # frozen one.  The bound is deliberately loose (1.5x):
        # per-segment Python/sync overheads are not in the cost model,
        # so a converged mapping can sit a little above frozen on a
        # noisy box without the loop being broken — the demonstration
        # number is `vs_frozen` below, typically ~0.3x here.
        assert steady_s < frozen_s * 1.5, (
            "adaptive steady state much worse than frozen "
            f"({steady_s * 1e3:.2f}ms vs {frozen_s * 1e3:.2f}ms)"
        )

        per_ex = 1e6 / b
        contended_left = sum(
            s.placement == contention.placement
            for s in adaptive.config.segments()
        )
        # a FUNCTIONAL row: us=0 marks it not-timing-gated.  The hard
        # asserts above are the gate (bit-exactness, convergence, the
        # 1.5x frozen bound); the steady-state wall time itself is
        # bimodal on a loaded box — full escape vs a legitimate
        # partial stall when the uncontended side's profiled rows are
        # noise-inflated — so gating it at a fixed tolerance would
        # flake.  All measurements ride in `derived`.
        rows.append((
            f"adapt/{m.name}/b{b}/contended_adaptive",
            0.0,
            f"steady_us={steady_s * per_ex:.1f};"
            f"recovery={steady_s / pre_s:.2f}x;"
            f"pre_us={pre_s * per_ex:.1f};"
            f"frozen_pre_us={pre_frozen_s * per_ex:.1f};"
            f"contended_frozen_us={frozen_s * per_ex:.1f};"
            f"frozen_degraded={frozen_s / pre_frozen_s:.2f}x;"
            f"vs_frozen={steady_s / frozen_s:.2f}x;"
            f"tax_ms={tax_s * 1e3:.1f};"
            f"contending={contention.placement};"
            f"remaps={len(contended)};"
            f"first_remap_batches={first_remap};"
            f"quiet={quiet};"
            f"contended_segments_left={contended_left};"
            f"calibration_remaps={calibration_remaps}",
        ))
    return rows
