"""§Roofline: the three-term table per (arch x shape x mesh) from the
dry-run artifacts in results/dryrun (run the dry-run first; this bench
renders + derives, it does not compile)."""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def load_cells():
    cells = []
    if not RESULTS.exists():
        return cells
    for fp in sorted(RESULTS.glob("*__*.json")):
        r = json.loads(fp.read_text())
        if r.get("status") == "ok":
            cells.append(r)
    return cells


def run():
    rows = []
    for r in load_cells():
        rf = r.get("roofline")
        if not rf:
            continue
        tag = (f"{r['arch']}/{r['shape']}/"
               f"{'pod2' if r['multi_pod'] else 'pod1'}")
        step_s = max(rf["compute_s"], rf["memory_s"]) + rf["collective_s"]
        rows.append(
            (f"roofline/{tag}", step_s * 1e6,
             f"dom={rf['dominant']};useful={rf['useful_ratio']:.2f};"
             f"peakGiB={r['memory']['peak_bytes_per_device']/2**30:.1f}")
        )
    if not rows:
        rows.append(("roofline/NO-DRYRUN-RESULTS", 0.0,
                     "run repro.launch.dryrun first"))
    return rows
