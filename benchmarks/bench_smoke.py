"""CI bench-smoke: tiny-size benchmark run + regression gate.

Runs ``kernel_bench``, ``segment_bench``, ``serve_bench``,
``adapt_bench``, ``fleet_bench``, ``cluster_bench``,
``cachesvc_bench`` and ``elastic_bench`` at CI-sized settings (model
``scale=0.25``, batches ``(1, 4)``, one timing repeat), writes the
results as JSON (the
``BENCH_pr.json`` artifact the CI job uploads), and — with
``--check`` — fails when any metric regressed by more than the
tolerance against a committed baseline (``benchmarks/baseline.json``).

The adapt and fleet rows double as functional gates: ``adapt_bench``
*asserts* that the remap controller converges (first contended remap
within its batch budget, recovered steady state beating the frozen
mapping, all outputs bit-exact) and ``fleet_bench`` asserts the joint
mapping's never-worse-than-all-GPU guarantee plus a measured two-model
co-run makespan win, bit-exact per tenant — so a broken loop fails the
job outright, before any timing comparison.  ``cluster_bench`` asserts
multi-host throughput scaling (>= 1.7x at 2 hosts, >= 3x at 4),
cross-host noisy-tenant isolation, and a journaled elastic scale-up
under surge.  ``cachesvc_bench`` asserts the shared cache's
warm-start hit rate (zero re-profiling on the serving path) and that
the background explore loop recovers the ground-truth mapping from a
planted-stale profile.  ``segment_bench`` asserts
every applicable fused segment-scope variant bit-exact against the
per-layer launch.  ``elastic_bench`` asserts the elastic subnet tier:
bit-exact outputs at every width level, the quality controller
halving (at least) the surge shed of a fixed-width baseline, full
width recovered and journaled after the surge, and the quality floor
never violated.  Their ``us=0`` sentinel rows are coverage-gated
(missing from a PR run fails) but not timing-gated.

Gate semantics:

* a metric regresses when ``pr_us > baseline_us * (1 + tolerance)``;
  tolerance defaults to 0.25 (25%), override with ``--tolerance`` or
  the ``BENCH_SMOKE_TOLERANCE`` env var;
* a metric present in the baseline but missing from the PR run is a
  failure (coverage loss); new metrics are reported but pass — commit
  a refreshed baseline (``--write-baseline``) to start gating them;
* timings are machine-dependent: the gate is meaningful on the
  homogeneous CI runner pool it was baselined on.  A PR that
  legitimately shifts numbers (or changes runner class) refreshes the
  baseline in the same PR.

Usage::

    python -m benchmarks.bench_smoke --out BENCH_pr.json --check
    python -m benchmarks.bench_smoke --write-baseline   # refresh
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
from pathlib import Path

DEFAULT_TOLERANCE = 0.25
BASELINE_PATH = Path(__file__).parent / "baseline.json"

# one place defines "tiny": both the PR run and the committed baseline
# must come from the same settings or the comparison is meaningless
SMOKE_KWARGS = {
    "kernel_bench": {"scale": 0.25, "batch_sizes": (1, 4), "repeats": 1},
    "segment_bench": {
        "scale": 0.25,
        "batch_sizes": (1,),
        "repeats": 1,
        "profile_repeats": 1,
    },
    "serve_bench": {
        "scale": 0.25,
        "batch_sizes": (1, 4),
        "repeats": 1,
        "n_microbatches": 4,
        "profile_repeats": 1,
    },
    "adapt_bench": {
        "scale": 0.25,
        "batch_sizes": (4,),
        "repeats": 1,
        "profile_repeats": 2,
        "calibrate_min": 4,
        "calibrate_max": 16,
        "pre_batches": 5,
        "contended_batches": 24,
        "converge_batches": 16,
        "steady_k": 4,
    },
    "fleet_bench": {
        "scale": 0.25,
        "batch": 4,
        "rounds": 6,
        "repeats": 1,
        "profile_repeats": 1,
    },
    "cluster_bench": {
        "scale": 0.25,
        "batch": 4,
        "rounds": 4,
        "repeats": 1,
        "profile_repeats": 1,
    },
    "cachesvc_bench": {
        "scale": 0.25,
        "batch": 4,
        "warm_iters": 8,
        "repeats": 1,
        "profile_repeats": 1,
    },
    # full width is required: conv channels only narrow when the base
    # is wider than the 32-lane pack-width clamp
    "elastic_bench": {
        "scale": 1.0,
        "batch": 4,
        "repeats": 1,
        "profile_repeats": 1,
        "surge_rounds": 10,
        "calm_rounds": 8,
    },
}


def collect() -> dict:
    """{metric_name: {"us": float, "derived": str}} over the suites."""
    from benchmarks import (
        adapt_bench, cachesvc_bench, cluster_bench, elastic_bench,
        fleet_bench, kernel_bench, segment_bench, serve_bench,
    )

    metrics: dict = {}
    for name, fn in (
        ("kernel_bench", kernel_bench.run),
        ("segment_bench", segment_bench.run),
        ("serve_bench", serve_bench.run),
        ("adapt_bench", adapt_bench.run),
        ("fleet_bench", fleet_bench.run),
        ("cluster_bench", cluster_bench.run),
        ("cachesvc_bench", cachesvc_bench.run),
        ("elastic_bench", elastic_bench.run),
    ):
        for rname, us, derived in fn(**SMOKE_KWARGS[name]):
            metrics[rname] = {"us": round(float(us), 3), "derived": derived}
    return metrics


def payload(metrics: dict) -> dict:
    return {
        "schema": 1,
        "settings": {
            k: {kk: list(v) if isinstance(v, tuple) else v
                for kk, v in kw.items()}
            for k, kw in SMOKE_KWARGS.items()
        },
        "host": {
            "python": platform.python_version(),
            "machine": platform.machine(),
        },
        "metrics": metrics,
    }


def gate(pr_doc: dict, base_doc: dict, tolerance: float) -> tuple:
    """(failures, notes) for a full PR payload vs a baseline payload.

    Refuses to compare timings measured under different workload
    settings — a changed ``SMOKE_KWARGS`` without a baseline refresh
    would otherwise gate apples against oranges (inflated failures, or
    masked regressions).
    """
    if pr_doc.get("settings") != base_doc.get("settings"):
        return (
            [
                "bench settings changed vs baseline "
                f"(baseline: {base_doc.get('settings')}, PR: "
                f"{pr_doc.get('settings')}); refresh the baseline "
                "(--write-baseline) in this PR"
            ],
            [],
        )
    return compare(
        pr_doc.get("metrics", {}), base_doc.get("metrics", {}), tolerance
    )


def compare(pr: dict, baseline: dict, tolerance: float) -> tuple:
    """(failures, notes) comparing metric dicts name -> {"us": ...}."""
    failures, notes = [], []
    for name, base in sorted(baseline.items()):
        got = pr.get(name)
        if got is None:
            failures.append(f"{name}: in baseline but missing from PR run")
            continue
        base_us, pr_us = base["us"], got["us"]
        if base_us <= 0:
            # functional row (us=0 sentinel): presence is gated above,
            # correctness is asserted inside its suite, timings ride
            # in `derived` — nothing to compare
            notes.append(f"{name}: functional row (not timing-gated)")
            continue
        ratio = pr_us / base_us
        line = f"{name}: {base_us:.1f}us -> {pr_us:.1f}us ({ratio:.2f}x)"
        if pr_us > base_us * (1.0 + tolerance):
            failures.append(
                f"{line} exceeds +{tolerance:.0%} tolerance"
            )
        else:
            notes.append(line)
    for name in sorted(set(pr) - set(baseline)):
        notes.append(f"{name}: new metric (not gated; refresh baseline)")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", type=Path, default=None,
                    help="write the PR run JSON here (e.g. BENCH_pr.json)")
    ap.add_argument("--baseline", type=Path, default=BASELINE_PATH)
    ap.add_argument("--check", action="store_true",
                    help="fail on regression vs the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh the committed baseline from this run")
    ap.add_argument(
        "--tolerance", type=float,
        default=float(os.environ.get("BENCH_SMOKE_TOLERANCE",
                                     DEFAULT_TOLERANCE)),
        help="allowed relative regression (default 0.25 = 25%%)")
    args = ap.parse_args(argv)

    metrics = collect()
    doc = payload(metrics)
    if args.out is not None:
        args.out.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"wrote {args.out} ({len(metrics)} metrics)")
    if args.write_baseline:
        args.baseline.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"refreshed baseline {args.baseline}")
        return 0
    if not args.check:
        for name, m in sorted(metrics.items()):
            print(f"{name},{m['us']:.2f},{m['derived']}")
        return 0

    if not args.baseline.exists():
        print(f"no baseline at {args.baseline}; "
              "run --write-baseline and commit it")
        return 1
    base_doc = json.loads(args.baseline.read_text())
    failures, notes = gate(doc, base_doc, args.tolerance)
    for line in notes:
        print(f"ok   {line}")
    for line in failures:
        print(f"FAIL {line}")
    print(
        f"bench-smoke: {len(notes)} ok, {len(failures)} regressed "
        f"(tolerance +{args.tolerance:.0%})"
    )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
