"""Serving runtime: segment extraction vs DP boundary attribution,
batcher coalescing/padding invariants, and pipelined bit-exactness
versus the serial and fused executors."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax

from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.core.cost_model import pipeline_makespan
from repro.core.mapped_model import build_mapped_model
from repro.core.mapper import (
    DEVICE,
    HOST,
    configuration_from_mapping,
    map_efficient_configuration,
    placement_of,
    segments_of,
)
from repro.core.parallel_config import ASPECT_CONFIGS, CONFIGS, CPU
from repro.core.profiler import ProfileTable
from repro.serving import (
    MicroBatcher,
    ServingEngine,
    SegmentPipeline,
    canonical_mixed_mapping,
    pad_to,
)


# ---------------------------------------------------------------------------
# segment extraction
# ---------------------------------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_segments_partition_layers_and_are_maximal(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 12))
    cfgs = tuple(CONFIGS[i] for i in rng.integers(0, len(CONFIGS), n))
    segs = segments_of(cfgs)
    # exact ordered partition of the layer range
    assert segs[0].start == 0 and segs[-1].stop == n
    for a, b in zip(segs, segs[1:]):
        assert a.stop == b.start
        assert a.placement != b.placement          # maximality
    # placement and configs consistent with the input
    rebuilt = []
    for s in segs:
        assert s.placement in (HOST, DEVICE)
        for c in s.configs:
            assert placement_of(c) == s.placement
        rebuilt.extend(s.configs)
    assert tuple(rebuilt) == cfgs


def _random_split_table(rng, n_layers=6, batches=(1, 2)):
    kernel, times, h2d, d2h = {}, {}, {}, {}
    for b in batches:
        kernel[b], times[b], h2d[b], d2h[b] = [], [], [], []
        for _ in range(n_layers):
            krow = {c: float(rng.uniform(1e-6, 1e-3)) for c in CONFIGS}
            up = float(rng.uniform(1e-6, 5e-4))
            down = float(rng.uniform(1e-6, 5e-4))
            times[b].append({
                c: krow[c] if c == CPU else krow[c] + up + down
                for c in CONFIGS
            })
            kernel[b].append(krow)
            h2d[b].append(up)
            d2h[b].append(down)
    return ProfileTable(
        "synthetic", tuple(batches),
        tuple(f"L{i+1}:C64" for i in range(n_layers)), times,
        kernel_times=kernel, h2d_times=h2d, d2h_times=d2h,
    )


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_segments_match_dp_boundary_attribution(seed):
    """The DP charges boundary cost exactly where segments() places a
    host<->device crossing: h2d on the first layer of each device
    segment, d2h on its last."""
    table = _random_split_table(np.random.default_rng(seed))
    ec = map_efficient_configuration(table, policy="dp")
    b = ec.proper_batch_size
    expected = [0.0] * len(ec.layer_configs)
    for seg in ec.segments():
        if seg.on_device:
            expected[seg.start] += table.h2d(b, seg.start)
            expected[seg.stop - 1] += table.d2h(b, seg.stop - 1)
    assert ec.per_layer_boundary_times == pytest.approx(expected)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_configuration_from_mapping_prices_placement_changes_only(seed):
    rng = np.random.default_rng(seed)
    table = _random_split_table(rng)
    mapping = tuple(
        CONFIGS[i] for i in rng.integers(0, len(CONFIGS), 6)
    )
    ec = configuration_from_mapping(table, 1, mapping)
    assert ec.layer_configs == mapping
    assert ec.expected_time_per_example == pytest.approx(
        sum(ec.per_layer_times)
    )
    # interior layers of a segment carry zero boundary
    for seg in ec.segments():
        for i in range(seg.start + 1, seg.stop - 1):
            assert ec.per_layer_boundary_times[i] == 0.0
        if not seg.on_device:
            for i in range(seg.start, seg.stop):
                assert ec.per_layer_boundary_times[i] == 0.0


def test_configuration_from_mapping_validates():
    table = _random_split_table(np.random.default_rng(0))
    with pytest.raises(ValueError, match="not profiled"):
        configuration_from_mapping(table, 64, ("CPU",) * 6)
    with pytest.raises(ValueError, match="covers"):
        configuration_from_mapping(table, 1, ("CPU",) * 3)


# ---------------------------------------------------------------------------
# pipeline cost estimate
# ---------------------------------------------------------------------------


def test_pipeline_makespan_formula():
    assert pipeline_makespan(2.0, 3.0, 0) == 0.0
    assert pipeline_makespan(2.0, 3.0, 1) == pytest.approx(5.0)
    # steady state: one micro-batch per max(stage) after fill
    assert pipeline_makespan(2.0, 3.0, 5) == pytest.approx(5.0 + 4 * 3.0)


def test_stage_times_drop_interior_boundaries_for_greedy():
    """A greedy configuration charges a full roundtrip on every device
    layer, but the segment executor crosses the boundary only at
    segment edges — stage_times must price the latter."""
    table = _random_split_table(np.random.default_rng(21), n_layers=5)
    mapping = ("XYZ", "XYZ", "XYZ", "CPU", "X")
    b = 1
    kernels = tuple(
        table.kernel_time(b, i, c) for i, c in enumerate(mapping)
    )
    # greedy-style attribution: full h2d+d2h on every non-CPU layer
    from repro.core.mapper import EfficientConfiguration

    boundaries = tuple(
        0.0 if c == CPU else table.h2d(b, i) + table.d2h(b, i)
        for i, c in enumerate(mapping)
    )
    ec = EfficientConfiguration(
        model_name="m", proper_batch_size=b,
        layer_labels=table.layer_labels, layer_configs=mapping,
        expected_time_per_example=sum(kernels) + sum(boundaries),
        per_layer_times=tuple(
            k + bd for k, bd in zip(kernels, boundaries)
        ),
        policy="greedy",
        per_layer_kernel_times=kernels,
        per_layer_boundary_times=boundaries,
    )
    host, device = ec.stage_times()
    assert host == pytest.approx(kernels[3])
    # device segment [0..2]: interior layer 1's roundtrip elided,
    # edge layers 0/2 and singleton segment [4] keep theirs
    assert device == pytest.approx(
        kernels[0] + kernels[1] + kernels[2] + kernels[4]
        + boundaries[0] + boundaries[2] + boundaries[4]
    )
    assert host + device < ec.expected_time_per_example


def test_pipelined_expected_time_limits():
    table = _random_split_table(np.random.default_rng(11))
    ec = map_efficient_configuration(table, policy="dp")
    host, device = ec.stage_times()
    assert host + device == pytest.approx(ec.expected_time_per_example)
    # n=1 degenerates to the serial expectation
    assert ec.pipelined_expected_time(1) == pytest.approx(
        ec.expected_time_per_example
    )
    # large n approaches the bottleneck-stage rate, and never beats it
    est = ec.pipelined_expected_time(1000)
    assert est == pytest.approx(max(host, device), rel=1e-2)
    assert est >= max(host, device)


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_pad_to_minimal_allowed():
    assert pad_to(3, (1, 2, 4, 8)) == 4
    assert pad_to(4, (1, 2, 4, 8)) == 4
    assert pad_to(5, None) == 5
    with pytest.raises(ValueError):
        pad_to(0, (1, 2))
    with pytest.raises(ValueError):
        pad_to(1, ())                     # empty != unconstrained
    with pytest.raises(ValueError):
        MicroBatcher(max_batch=1, allowed_batch_sizes=())
    with pytest.raises(ValueError):
        pad_to(9, (1, 2, 4, 8))


def test_batcher_waits_then_flushes_partial_batch():
    clock = FakeClock()
    mb = MicroBatcher(
        max_batch=4, max_wait_s=1e-3,
        allowed_batch_sizes=(1, 2, 4), clock=clock,
    )
    xs = [np.full((2, 2), i, np.int32) for i in range(3)]
    for x in xs:
        mb.submit(x)
    assert not mb.ready()                 # partial and young
    assert mb.next_batch() is None
    clock.t = 2e-3                        # oldest request ages out
    assert mb.ready()
    batch = mb.next_batch()
    assert batch.n_real == 3
    assert batch.padded_size == 4         # padded to a profiled size
    assert np.array_equal(batch.x[:3], np.stack(xs))   # FIFO order
    assert np.all(batch.x[3:] == 0)       # zero pad rows
    assert mb.pending() == 0


def test_batcher_full_batch_is_immediately_ready():
    clock = FakeClock()
    mb = MicroBatcher(max_batch=2, max_wait_s=10.0, clock=clock)
    r1 = mb.submit(np.zeros(3, np.int32))
    r2 = mb.submit(np.ones(3, np.int32))
    assert mb.ready()                     # full despite zero wait
    batch = mb.next_batch()
    assert batch.requests == (r1, r2)
    assert batch.n_real == batch.padded_size == 2


def test_batcher_splits_overflow_into_fifo_batches():
    clock = FakeClock()
    mb = MicroBatcher(
        max_batch=4, max_wait_s=0.0,
        allowed_batch_sizes=(2, 4), clock=clock,
    )
    for i in range(6):
        mb.submit(np.full(1, i, np.int32))
    batches = mb.drain()
    assert [b.n_real for b in batches] == [4, 2]
    assert [b.padded_size for b in batches] == [4, 2]
    got = [int(r.x[0]) for b in batches for r in b.requests]
    assert got == list(range(6))


def test_batcher_rejects_unprofiled_max_batch():
    with pytest.raises(ValueError, match="profiled"):
        MicroBatcher(max_batch=16, allowed_batch_sizes=(1, 2, 4))


# ---------------------------------------------------------------------------
# pipelined execution: bit-exact vs serial, fused, and reference
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_mapped():
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = ProfileTable(
        m.name, (4,),
        tuple(f"L{s.idx}:{s.notation}" for s in m.specs),
        times={4: [
            {c: 1e-4 for c in CONFIGS}
            for _ in m.specs
        ]},
        kernel_times={4: [
            {c: 1e-4 for c in CONFIGS} for _ in m.specs
        ]},
        h2d_times={4: [1e-5] * len(m.specs)},
        d2h_times={4: [1e-5] * len(m.specs)},
    )
    # canonical mixed split: GEMM layers on device, elementwise on host
    ec = configuration_from_mapping(table, 4, canonical_mixed_mapping(m))
    return m, packed, table, ec


def test_mixed_mapping_has_multiple_segments(small_mapped):
    _, _, _, ec = small_mapped
    segs = ec.segments()
    assert len(segs) >= 3
    assert any(s.on_device for s in segs)
    assert any(not s.on_device for s in segs)


def test_pipelined_bit_exact_vs_serial_fused_and_reference(small_mapped):
    m, packed, _, ec = small_mapped
    pipe = SegmentPipeline(m, packed, ec)
    fused = build_mapped_model(m, packed, ec)
    inputs = [
        prepare_input_packed(
            jax.random.uniform(jax.random.PRNGKey(i), (4, 28, 28, 1))
        )
        for i in range(5)
    ]
    piped = pipe.run_pipelined(inputs)
    for x, got in zip(inputs, piped):
        ref = np.asarray(forward_packed(m.specs, packed, x))
        assert np.array_equal(got, ref)
        assert np.array_equal(pipe.run_serial(x), ref)
        assert np.array_equal(np.asarray(fused(x)), ref)


def test_pipelined_empty_and_single_stream(small_mapped):
    m, packed, _, ec = small_mapped
    pipe = SegmentPipeline(m, packed, ec)
    assert pipe.run_pipelined([]) == []
    x = prepare_input_packed(
        jax.random.uniform(jax.random.PRNGKey(9), (4, 28, 28, 1))
    )
    (out,) = pipe.run_pipelined([x])
    assert np.array_equal(out, pipe.run_serial(x))


def test_pipelined_completion_callback_order(small_mapped):
    m, packed, _, ec = small_mapped
    pipe = SegmentPipeline(m, packed, ec)
    inputs = [
        prepare_input_packed(
            jax.random.uniform(jax.random.PRNGKey(i), (4, 28, 28, 1))
        )
        for i in range(4)
    ]
    seen = []
    outs = pipe.run_pipelined(
        inputs, on_complete=lambda i, out: seen.append(i)
    )
    assert seen == list(range(4))         # micro-batches retire in order
    assert len(outs) == 4


def test_engine_end_to_end_with_padding(small_mapped):
    m, packed, table, ec = small_mapped
    clock = FakeClock()
    engine = ServingEngine(
        m, packed, ec,
        allowed_batch_sizes=table.batch_sizes,
        clock=clock,
    )
    assert engine.batcher.max_batch == ec.proper_batch_size == 4
    x01 = jax.random.uniform(jax.random.PRNGKey(3), (6, 28, 28, 1))
    xw = np.asarray(prepare_input_packed(x01))
    reqs = [engine.submit(xw[i]) for i in range(6)]
    clock.t = 1.0
    done = engine.step(force=True)        # 6 requests -> batches of 4+2->4
    assert done == 6 and engine.served == 6
    ref = np.asarray(
        forward_packed(m.specs, packed, prepare_input_packed(x01))
    )
    for i, r in enumerate(reqs):
        assert np.array_equal(r.wait(timeout=1.0), ref[i])
        assert r.latency_s == pytest.approx(1.0)
    assert engine.step() == 0             # queue drained


def test_engine_fails_requests_instead_of_dropping_them(small_mapped):
    """If execution raises after requests were popped off the queue,
    waiters must get the error, not hang to TimeoutError."""
    m, packed, table, ec = small_mapped
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(),
    )
    bad = engine.submit(np.zeros((3, 3, 1), np.int32))  # wrong shape
    with pytest.raises(BaseException):
        engine.step(force=True)
    with pytest.raises(BaseException) as err:
        bad.wait(timeout=0.1)
    assert not isinstance(err.value, TimeoutError)
    assert engine.batcher.pending() == 0    # nothing silently requeued


# ---------------------------------------------------------------------------
# thread-safety: concurrent submit, single stepper (the fleet router's
# dispatch pattern)
# ---------------------------------------------------------------------------


def test_batcher_concurrent_submit_keeps_fifo_and_loses_nothing():
    """N threads hammering submit() against a draining thread: every
    request is popped exactly once and queue order equals submit_t
    order (the clock is read under the lock)."""
    import threading

    batcher = MicroBatcher(max_batch=4, max_wait_s=0.0)
    n_threads, per_thread = 8, 40
    submitted = [[] for _ in range(n_threads)]

    def client(k):
        for i in range(per_thread):
            submitted[k].append(
                batcher.submit(np.full((2,), k * per_thread + i, np.int32))
            )

    threads = [
        threading.Thread(target=client, args=(k,))
        for k in range(n_threads)
    ]
    popped = []
    for t in threads:
        t.start()
    # drain concurrently with the submitters
    while any(t.is_alive() for t in threads) or batcher.pending():
        popped.extend(batcher.drain(force=True))
    for t in threads:
        t.join()
    popped.extend(batcher.drain(force=True))

    reqs = [r for mb in popped for r in mb.requests]
    assert len(reqs) == n_threads * per_thread
    assert len(set(map(id, reqs))) == len(reqs)       # no duplicates
    stamps = [r.submit_t for r in reqs]
    assert stamps == sorted(stamps)                   # FIFO by clock
    assert {id(r) for r in reqs} == {
        id(r) for batch in submitted for r in batch
    }


def test_engine_concurrent_submit_bit_exact(small_mapped):
    """The router's contract: many client threads submit into one
    engine while a single dispatch thread steps.  Every request
    completes exactly once, bit-exact against the reference."""
    import threading

    m, packed, table, ec = small_mapped
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
    )
    n_threads, per_thread = 4, 6
    x01 = jax.random.uniform(
        jax.random.PRNGKey(11), (n_threads * per_thread, 28, 28, 1)
    )
    xw = np.asarray(prepare_input_packed(x01))
    ref = np.asarray(forward_packed(m.specs, packed, xw))
    results: list = [None] * (n_threads * per_thread)

    def client(k):
        for i in range(per_thread):
            j = k * per_thread + i
            results[j] = (j, engine.submit(xw[j]))

    threads = [
        threading.Thread(target=client, args=(k,))
        for k in range(n_threads)
    ]
    for t in threads:
        t.start()
    served = 0
    while any(t.is_alive() for t in threads):
        served += engine.step(force=True)
    for t in threads:
        t.join()
    served += engine.step(force=True)

    assert served == n_threads * per_thread == engine.served
    for j, req in results:
        assert np.array_equal(req.wait(timeout=5.0), ref[j])


def test_engine_always_on_observer_fires_every_step(small_mapped):
    """The `observer` kwarg (the fleet ledger's feed) sees every
    (step, segment) — unlike sampled telemetry — and composes with a
    telemetry observer when both are present."""
    from repro.adapt import SegmentTelemetry

    m, packed, table, ec = small_mapped
    seen = []
    telemetry = SegmentTelemetry(sample_every=2, warmup=1)
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        telemetry=telemetry,
        observer=lambda s, seg, secs, b: seen.append((s, seg.placement)),
    )
    xw = np.asarray(prepare_input_packed(
        jax.random.uniform(jax.random.PRNGKey(7), (4, 28, 28, 1))
    ))
    n_steps = 4
    for _ in range(n_steps):
        for i in range(4):
            engine.submit(xw[i])
        engine.step(force=True)
    n_segs = len(ec.segments())
    assert len(seen) == n_steps * n_segs      # every step observed
    assert [s for s, _ in seen] == list(range(n_segs)) * n_steps
    # the sampled telemetry still got its (fewer) samples through the tee
    assert 0 < sum(s.count for s in telemetry.stats().values()) < len(seen)


def test_engine_uniform_placement_still_serves(small_mapped):
    """All-device and all-host mappings degenerate to one segment; the
    pipeline must still be correct (no overlap, same outputs)."""
    m, packed, table, _ = small_mapped
    x = prepare_input_packed(
        jax.random.uniform(jax.random.PRNGKey(5), (4, 28, 28, 1))
    )
    ref = np.asarray(forward_packed(m.specs, packed, x))
    for cfg in (CPU, ASPECT_CONFIGS[-1]):
        ec = configuration_from_mapping(table, 4, (cfg,) * len(m.specs))
        assert len(ec.segments()) == 1
        pipe = SegmentPipeline(m, packed, ec)
        (out,) = pipe.run_pipelined([x])
        assert np.array_equal(out, ref)
