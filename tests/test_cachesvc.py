"""Cache service: deduped/retried/journaled work queue (all backoff
under virtual time — zero real sleeps), worker pool, and the three
job kinds.  The explore tests pin the PR 4 residual closure: a
planted-stale profile row is re-measured off the hot path, folded
back through ``fold_observed``, and produces a strictly better
persisted mapping with zero profiling on the serving path.
"""

import time

import pytest

import jax

from repro import api
from repro.bnn import build_model
from repro.bnn.models import pack_params
from repro.cachesvc import (
    CacheService, MemoryBackend, TieredBackend, WorkerPool, WorkQueue,
)
from repro.cachesvc.jobs import (
    coverage_report,
    execution_counts,
    explore_once,
    flush_once,
    prewarm_once,
    refit_once,
)
from repro.core.mapper import (
    DEVICE, HOST, map_efficient_configuration, placement_of,
)
from repro.core.parallel_config import CONFIGS, CPU
from repro.core.profiler import ProfileTable
from repro.store import ProfileStore

from tests.fixtures import FakeClock, flat_table, planted_gamma_ledger
from tests.test_cluster import fake_cluster, fake_tenant

def _packed(m):
    return pack_params(m.specs, m.init(jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# work queue: dedupe, retry/backoff (virtual time), journal
# ---------------------------------------------------------------------------


def test_submit_dedupes_live_identities():
    q = WorkQueue(clock=FakeClock())
    assert q.submit("prewarm", "k1", lambda: None) is True
    assert q.submit("prewarm", "k1", lambda: None) is False
    assert q.submit("refit", "k1", lambda: None) is True    # kind differs
    assert q.submit("prewarm", "k2", lambda: None) is True
    assert q.stats()["submitted"] == 3 and q.stats()["deduped"] == 1
    q.run_pending()
    # a finished identity may be resubmitted (idempotent jobs)
    assert q.submit("prewarm", "k1", lambda: None) is True


def test_retry_backoff_schedule_is_virtual_time_only():
    clock = FakeClock()
    q = WorkQueue(clock=clock, max_attempts=3, backoff_s=0.5)
    attempt_times = []

    def flaky():
        attempt_times.append(clock())
        if len(attempt_times) < 3:
            raise RuntimeError("transient")
        return {"ok": True}

    q.submit("prewarm", "k", flaky)
    wall = time.monotonic()
    ran = q.drain(sleep=clock.advance)
    wall = time.monotonic() - wall
    assert ran == 3 and wall < 1.0          # no real sleeping
    # exponential schedule: +0.5 after attempt 1, +1.0 after attempt 2
    assert attempt_times[1] - attempt_times[0] == pytest.approx(
        0.5, abs=1e-6
    )
    assert attempt_times[2] - attempt_times[1] == pytest.approx(
        1.0, abs=1e-6
    )
    assert q.stats()["retries"] == 2
    (rec,) = q.journal
    assert rec.status == "done" and rec.attempts == 3
    assert rec.result == {"ok": True}


def test_permanent_failure_journaled_after_max_attempts():
    clock = FakeClock()
    q = WorkQueue(clock=clock, max_attempts=2, backoff_s=0.1)

    def broken():
        raise ValueError("planted failure")

    q.submit("explore", "bad-key", broken)
    assert q.drain(sleep=clock.advance) == 2
    (rec,) = q.journal
    assert rec.status == "failed" and rec.attempts == 2
    assert rec.error == "ValueError: planted failure"
    assert rec.result is None
    assert q.stats() == {
        "queued": 0, "running": 0, "repeating": 0, "submitted": 1,
        "deduped": 0, "retries": 1, "done": 0, "failed": 1,
    }


def test_run_pending_respects_backoff_deadlines():
    clock = FakeClock()
    q = WorkQueue(clock=clock, max_attempts=3, backoff_s=1.0)
    calls = []

    def once_flaky():
        calls.append(1)
        if len(calls) == 1:
            raise RuntimeError("once")

    q.submit("refit", "k", once_flaky)
    # first pass fails, job is re-queued 1s in the future
    assert q.run_pending() == 1
    assert q.pending() == 1
    assert q.run_pending() == 0             # not due yet
    assert q.next_due_s() == pytest.approx(1.0)
    clock.advance(1.0)
    assert q.run_pending() == 1
    assert q.journal[-1].status == "done"


def test_job_record_to_dict_round_trips():
    clock = FakeClock()
    q = WorkQueue(clock=clock)
    clock.advance(3.0)
    q.submit("prewarm", "k", lambda: {"n": 1})
    q.run_pending()
    d = q.journal[0].to_dict()
    assert d["seq"] == 0 and d["kind"] == "prewarm"
    assert d["enqueued_s"] == 3.0 and d["finished_s"] == 3.0
    assert d["result"] == {"n": 1}


def test_worker_pool_drains_in_background():
    q = WorkQueue()                          # real clock for threads
    done = []
    for i in range(8):
        q.submit("prewarm", f"k{i}", lambda i=i: done.append(i))
    pool = WorkerPool(q, n_workers=3).start()
    try:
        with pytest.raises(RuntimeError):
            pool.start()                     # already started
        assert pool.alive == 3
        assert pool.join_idle(timeout=5.0)
        assert sorted(done) == list(range(8))
        assert all(r.status == "done" for r in q.journal)
    finally:
        pool.stop()
    assert pool.alive == 0


def test_queue_validates_knobs():
    with pytest.raises(ValueError):
        WorkQueue(max_attempts=0)
    with pytest.raises(ValueError):
        WorkQueue(backoff_s=-1.0)
    with pytest.raises(ValueError):
        WorkerPool(WorkQueue(), n_workers=0)
    q = WorkQueue()
    with pytest.raises(ValueError):
        q.submit("k", "k", lambda: None, delay_s=-1.0)
    with pytest.raises(ValueError):
        q.submit("k", "k", lambda: None, repeat_s=0.0)


# ---------------------------------------------------------------------------
# periodic jobs (repeat_s): the timed write-back flush rides these
# ---------------------------------------------------------------------------


def test_periodic_job_repeats_on_its_cadence_until_cancelled():
    clock = FakeClock()
    q = WorkQueue(clock=clock)
    runs = []
    assert q.submit(
        "flush", "tier", lambda: runs.append(clock()) or {"n": 1},
        delay_s=2.0, repeat_s=2.0,
    ) is True
    # one timer per identity, however often it is (re)enqueued
    assert q.submit("flush", "tier", lambda: None) is False
    assert q.run_pending() == 0                # first tick not due yet
    assert q.stats()["repeating"] == 1
    clock.advance(2.0)
    assert q.run_pending() == 1
    assert q.run_pending() == 0                # rescheduled, not due
    clock.advance(2.0)
    assert q.run_pending() == 1
    assert runs == [2.0, 4.0]                  # exact virtual cadence
    assert all(r.status == "done" for r in q.journal)
    assert q.cancel("flush", "tier") is True   # dequeues the timer
    clock.advance(10.0)
    assert q.run_pending() == 0
    assert q.cancel("flush", "tier") is False  # nothing live anymore


def test_periodic_job_survives_failed_tick_and_drain_terminates():
    clock = FakeClock()
    q = WorkQueue(clock=clock, max_attempts=1)
    ticks = []

    def flaky():
        ticks.append(1)
        if len(ticks) == 1:
            raise RuntimeError("one bad tick")
        return {"ok": True}

    q.submit("flush", "k", flaky, repeat_s=1.0)
    q.submit("prewarm", "p", lambda: {"done": True})
    # drain must return once the one-shot finishes: a live timer never
    # makes the queue "dirty", or drain would spin forever
    q.drain(sleep=clock.advance)
    assert any(
        r.kind == "prewarm" and r.status == "done" for r in q.journal
    )
    flush_recs = [r for r in q.journal if r.kind == "flush"]
    assert flush_recs[0].status == "failed"    # tick failed...
    assert q.pending() == 1                    # ...but the timer lives
    clock.advance(1.0)
    assert q.run_pending() == 1                # next tick succeeds
    assert q.journal[-1].status == "done"
    assert q.journal[-1].result == {"ok": True}


def test_periodic_job_can_cancel_itself_mid_run():
    q = WorkQueue(clock=FakeClock())

    def last_tick():
        q.cancel("flush", "self")              # running: suppresses
        return {"last": True}                  # the re-enqueue only

    q.submit("flush", "self", last_tick, repeat_s=1.0)
    assert q.run_pending() == 1
    assert q.pending() == 0                    # no reschedule
    assert q.journal[-1].status == "done"


def test_join_idle_ignores_dormant_periodic_jobs():
    q = WorkQueue()                            # real clock for threads
    q.submit("flush", "timer", lambda: None, delay_s=60.0,
             repeat_s=60.0)
    q.submit("prewarm", "k", lambda: {"n": 1})
    pool = WorkerPool(q, n_workers=1).start()
    try:
        # a dormant flush timer must not make the pool non-idle
        assert pool.join_idle(timeout=5.0) is True
    finally:
        pool.stop()
    assert q.stats()["done"] == 1 and q.stats()["repeating"] == 1


# ---------------------------------------------------------------------------
# coverage accounting
# ---------------------------------------------------------------------------


def _stale_device_table(model, *, batch=4, cpu=1e-3, dev=5e-3,
                        bnd=1e-5):
    """Device kernel rows inflated (stale) relative to host: the solo
    mapper keeps everything on host, so device placements never
    execute and telemetry can never correct them — the explore loop's
    target regime."""
    n = len(model.specs)
    labels = tuple(f"L{s.idx}:{s.notation}" for s in model.specs)
    times = {batch: [
        {c: cpu if c == CPU else dev + 2 * bnd for c in CONFIGS}
        for _ in range(n)
    ]}
    kernels = {batch: [
        {c: cpu if c == CPU else dev for c in CONFIGS}
        for _ in range(n)
    ]}
    return ProfileTable(
        model.name, (batch,), labels, times, kernel_times=kernels,
        h2d_times={batch: [bnd] * n}, d2h_times={batch: [bnd] * n},
    )


def test_execution_counts_accumulates_across_mappings():
    m = build_model("fashion_mnist", scale=0.25)
    t = flat_table(m)
    host = map_efficient_configuration(t, policy="greedy")
    counts = execution_counts(host, 10)
    assert all(n == 10 for n in counts.values())
    assert len(counts) == len(t.layer_labels)
    counts = execution_counts(host, 5, into=counts)   # after a swap
    assert all(n == 15 for n in counts.values())


def test_coverage_report_flags_unexecuted_placements():
    m = build_model("fashion_mnist", scale=0.25)
    t = _stale_device_table(m)
    solo = map_efficient_configuration(t, policy="dp")
    assert all(placement_of(c) == HOST for c in solo.layer_configs)
    counts = execution_counts(solo, steps=10)
    rows = coverage_report(t, 4, counts)
    # every layer's device side is unexplored; host side is covered
    assert len(rows) == len(t.layer_labels)
    assert all(r.placement == DEVICE and r.executed == 0 for r in rows)
    assert all(r.candidates for r in rows)
    # raising min_count pulls the executed host side into the frontier
    rows = coverage_report(t, 4, counts, min_count=11)
    assert len(rows) == 2 * len(t.layer_labels)
    with pytest.raises(ValueError):
        coverage_report(t, 16, counts)      # batch never profiled


# ---------------------------------------------------------------------------
# job bodies
# ---------------------------------------------------------------------------


def test_prewarm_once_is_idempotent_zero_profiling_on_rerun(tmp_path):
    m = build_model("fashion_mnist", scale=0.25)
    packed = _packed(m)
    calls = {"profile": 0}

    def profile_fn(model, pp, *, batch_sizes):
        calls["profile"] += 1
        return flat_table(model, batch=batch_sizes[0])

    store = ProfileStore(f"sqlite://{tmp_path}/c.db", fingerprint="fp")
    r1 = prewarm_once(store, m, packed, profile_fn=profile_fn,
                      batch_sizes=(4,))
    assert r1["profiled"] is True and r1["mapped"] is True
    assert calls["profile"] == 1
    r2 = prewarm_once(store, m, packed, profile_fn=profile_fn,
                      batch_sizes=(4,))
    assert r2["profiled"] is False and r2["mapped"] is False
    assert calls["profile"] == 1            # fully warmed: no work
    assert r2["batch"] == r1["batch"]


def test_refit_once_thresholds_on_new_rows(tmp_path):
    m = build_model("fashion_mnist", scale=0.25)
    packed = _packed(m)
    store = ProfileStore(tmp_path, fingerprint="fp")
    store.get_or_profile(
        m, packed,
        lambda model, pp, *, batch_sizes: flat_table(model),
        batch_sizes=(4,),
    )
    n_rows = len(store.load_training_rows())
    assert n_rows > 0                       # profiling fed the set
    out = refit_once(store, min_new_rows=n_rows + 1)
    assert out["refit"] is False            # not enough rows yet
    assert store.load_predictor() is None
    out = refit_once(store, min_new_rows=1)
    assert out["refit"] is True and out["rows"] == n_rows
    pred = store.load_predictor()
    assert pred is not None and pred.n_rows > 0
    # idempotent: nothing new accumulated since the fit
    out = refit_once(store, min_new_rows=1)
    assert out["refit"] is False and out["new_rows"] == 0


def test_refit_once_fits_interference_from_observations(tmp_path):
    store = ProfileStore(tmp_path, fingerprint="fp")
    ledger, expected = planted_gamma_ledger(0.8)
    out = refit_once(store, observations=(ledger, expected))
    assert out["interference"] is True
    law = store.load_interference()
    assert law is not None
    assert law.gamma == pytest.approx(0.8, abs=0.05)
    assert out["gamma"] == law.gamma


def test_explore_corrects_planted_stale_row(tmp_path):
    """The acceptance scenario: device rows are stale-slow, so the
    stored mapping pins everything to host and telemetry can never
    see the truth.  One explore pass re-measures off the hot path and
    must persist a strictly better, different mapping — with zero
    profiling on the serving path."""
    m = build_model("fashion_mnist", scale=0.25)
    t = _stale_device_table(m, cpu=1e-3, dev=5e-3)
    store = ProfileStore(f"sqlite://{tmp_path}/c.db", fingerprint="fp")
    old = map_efficient_configuration(t, policy="dp", batch_sizes=(4,))
    assert all(placement_of(c) == HOST for c in old.layer_configs)
    store.save_mapping(old)
    counts = execution_counts(old, steps=25)

    measured = []

    def measure_fn(layer, config, batch):
        measured.append((layer, config, batch))
        return 1e-4                          # the truth: device is fast

    out = explore_once(store, m, t, batch=4, counts=counts,
                       measure_fn=measure_fn)
    assert out["explored"] == len(t.layer_labels)
    assert out["improved"] is True
    assert out["new_expected_s"] < out["old_expected_s"]
    # measurement happened off the hot path, once per stale row, and
    # never touched the profiler
    assert len(measured) == len(t.layer_labels)
    assert all(placement_of(c) == DEVICE for _, c, _ in measured)

    refreshed = store.load_mapping(m, policy="dp", batch=4)
    assert refreshed.layer_configs != old.layer_configs
    assert all(
        placement_of(c) == DEVICE for c in refreshed.layer_configs
    )
    # the corrected table is session-local: the stored profile (none
    # was ever saved here) and the table object are untouched
    assert t.kernel_time(4, 0, refreshed.layer_configs[0]) == 5e-3

    # with the frontier covered, a second pass is a no-op
    covered = execution_counts(refreshed, 25, into=dict(counts))
    out2 = explore_once(store, m, t, batch=4, counts=covered,
                        measure_fn=measure_fn)
    assert out2 == {
        "explored": 0, "improved": False, "sweep": "cheapest",
    }


def test_explore_keeps_old_mapping_when_measurement_confirms(tmp_path):
    """Measured times that agree with the stored profile must not
    churn the persisted mapping."""
    m = build_model("fashion_mnist", scale=0.25)
    t = _stale_device_table(m, cpu=1e-3, dev=5e-3)
    store = ProfileStore(tmp_path, fingerprint="fp")
    old = map_efficient_configuration(t, policy="dp", batch_sizes=(4,))
    store.save_mapping(old)
    counts = execution_counts(old, steps=25)
    out = explore_once(
        store, m, t, batch=4, counts=counts,
        measure_fn=lambda layer, c, b: t.kernel_time(b, layer, c),
    )
    assert out["improved"] is False
    kept = store.load_mapping(m, policy="dp", batch=4)
    assert kept.layer_configs == old.layer_configs


def test_explore_frontier_sweeps_every_stale_candidate(tmp_path):
    m = build_model("fashion_mnist", scale=0.25)
    t = _stale_device_table(m)
    store = ProfileStore(tmp_path, fingerprint="fp")
    old = map_efficient_configuration(t, policy="dp", batch_sizes=(4,))
    store.save_mapping(old)
    counts = execution_counts(old, steps=25)
    rows = coverage_report(t, 4, counts)
    n_candidates = sum(len(r.candidates) for r in rows)

    measured = []
    out = explore_once(
        store, m, t, batch=4, counts=counts, sweep="frontier",
        measure_fn=lambda l, c, b: measured.append(c) or 1e-4,
    )
    # every candidate of every stale row was measured, not just the
    # stored-cheapest one per row
    assert out["sweep"] == "frontier"
    assert out["explored"] == len(rows)
    assert out["measured"] == n_candidates > out["explored"]
    assert len(measured) == n_candidates
    assert out["improved"] is True
    for r in out["rows"]:
        assert r["stored_s"] == 5e-3 and r["observed_s"] == 1e-4
        assert r["ratio"] == pytest.approx(1e-4 / 5e-3)
    refreshed = store.load_mapping(m, policy="dp", batch=4)
    assert all(
        placement_of(c) == DEVICE for c in refreshed.layer_configs
    )
    with pytest.raises(ValueError):
        explore_once(store, m, t, batch=4, counts=counts,
                     measure_fn=lambda l, c, b: 1e-4, sweep="bogus")


def _decoy_table(model, *, batch=4, cpu=1e-3, decoy=2e-3, dev=5e-3,
                 bnd=1e-5, decoy_cfg="X"):
    """One device config (the decoy) stored cheapest-on-device and
    priced accurately; every *other* device config stored slow but
    actually fast.  The cheapest sweep only ever measures the decoy,
    so only a frontier sweep can find the real winner."""
    n = len(model.specs)
    labels = tuple(f"L{s.idx}:{s.notation}" for s in model.specs)

    def kern(c):
        if c == CPU:
            return cpu
        return decoy if c == decoy_cfg else dev

    times = {batch: [
        {c: kern(c) if c == CPU else kern(c) + 2 * bnd for c in CONFIGS}
        for _ in range(n)
    ]}
    kernels = {batch: [{c: kern(c) for c in CONFIGS} for _ in range(n)]}
    return ProfileTable(
        model.name, (batch,), labels, times, kernel_times=kernels,
        h2d_times={batch: [bnd] * n}, d2h_times={batch: [bnd] * n},
    )


def test_frontier_catches_mispriced_non_cheapest_candidate(tmp_path):
    m = build_model("fashion_mnist", scale=0.25)
    t = _decoy_table(m)
    store = ProfileStore(tmp_path, fingerprint="fp")
    old = map_efficient_configuration(t, policy="dp", batch_sizes=(4,))
    assert all(placement_of(c) == HOST for c in old.layer_configs)
    store.save_mapping(old)
    counts = execution_counts(old, steps=25)

    def truth(layer, config, batch):
        return 2e-3 if config == "X" else 1e-4

    # the cheapest sweep measures only the decoy, confirms it, and
    # scales the whole device side by its ratio of 1.0 — blind spot
    out = explore_once(store, m, t, batch=4, counts=counts,
                       measure_fn=truth, sweep="cheapest")
    assert out["improved"] is False
    assert all(r["config"] == "X" and r["ratio"] == 1.0
               for r in out["rows"])
    kept = store.load_mapping(m, policy="dp", batch=4)
    assert kept.layer_configs == old.layer_configs

    # the frontier sweep folds each candidate's own ratio: the truly
    # fast non-decoy configs surface and win the remap
    out = explore_once(store, m, t, batch=4, counts=counts,
                       measure_fn=truth, sweep="frontier")
    assert out["improved"] is True
    refreshed = store.load_mapping(m, policy="dp", batch=4)
    assert all(
        placement_of(c) == DEVICE and c != "X"
        for c in refreshed.layer_configs
    )


def test_flush_once_pushes_dirty_keys_then_is_idempotent():
    front, back = MemoryBackend("fl-f"), MemoryBackend("fl-b")
    tier = TieredBackend(front, back, write_back=True)
    tier.put("a/x.json", "1")
    tier.put("a/y.json", "2")
    assert back.get("a/x.json") is None      # write-back: front only
    assert flush_once(tier) == {"pushed": 2, "pending": 0}
    assert back.get("a/x.json") == "1"
    assert back.get("a/y.json") == "2"
    assert flush_once(tier) == {"pushed": 0, "pending": 0}


# ---------------------------------------------------------------------------
# CacheService: catalog, popularity, journaled background jobs
# ---------------------------------------------------------------------------


def _service(tmp_path, **kwargs):
    m1 = build_model("fashion_mnist", scale=0.25)
    m2 = build_model("fashion_mnist", scale=0.5)
    calls = {"profile": 0}

    def profile_fn(model, pp, *, batch_sizes):
        calls["profile"] += 1
        return flat_table(model, batch=batch_sizes[0])

    svc = CacheService(
        ProfileStore(tmp_path, fingerprint="fp"),
        profile_fn=profile_fn, batch_sizes=(4,),
        clock=kwargs.pop("clock", FakeClock()), **kwargs,
    )
    svc.register("small", m1, _packed(m1))
    svc.register("large", m2, _packed(m2))
    return svc, calls


def test_service_prewarm_jobs_dedupe_and_journal(tmp_path):
    svc, calls = _service(tmp_path)
    assert svc.catalog == ("large", "small")
    assert svc.enqueue_prewarm("small") is True
    assert svc.enqueue_prewarm("small") is False    # deduped
    assert svc.enqueue_prewarm("large") is True
    assert svc.run_pending() == 2
    assert calls["profile"] == 2
    recs = svc.journal
    assert [r.kind for r in recs] == ["prewarm", "prewarm"]
    assert all(r.status == "done" for r in recs)
    assert all(r.result["profiled"] for r in recs)
    # jobs are keyed like the store entries they materialize
    assert recs[0].key.endswith("profile-b4.json")
    # warmed: a re-run does no profiling
    assert svc.enqueue_prewarm("small") is True
    svc.run_pending()
    assert calls["profile"] == 2
    assert svc.journal[-1].result == {
        "profiled": False, "mapped": False, "batch": 4,
        "expected_s": svc.journal[-1].result["expected_s"],
    }


def test_service_popularity_ranks_by_store_access(tmp_path):
    svc, calls = _service(tmp_path)
    svc.enqueue_prewarm("small")
    svc.enqueue_prewarm("large")
    svc.run_pending()
    m2, _ = svc._catalog["large"]
    for _ in range(3):                       # real traffic loads large
        assert svc.store.load_profile(m2, (4,)) is not None
    pop = svc.popularity()
    assert pop["large"] > pop["small"]
    assert svc.prewarm_popular(top=1) == 1
    svc.run_pending()
    assert svc._sig("large") in svc.journal[-1].key
    s = svc.stats()
    assert s["store"]["hits"] >= 3 and s["queue"]["done"] == 3


def test_service_refit_and_guards(tmp_path):
    svc, _ = _service(tmp_path)
    svc.enqueue_prewarm("small")
    svc.run_pending()                        # records training rows
    svc.refit_min_new_rows = 1
    assert svc.enqueue_refit() is True
    assert svc.enqueue_refit() is False      # deduped while queued
    svc.run_pending()
    assert svc.journal[-1].kind == "refit"
    assert svc.journal[-1].result["refit"] is True
    assert svc.store.load_predictor() is not None

    model, packed = svc._catalog["small"]
    bare = CacheService(ProfileStore(tmp_path / "bare"))
    bare.register("m", model, packed)
    with pytest.raises(ValueError):
        bare.enqueue_prewarm("m")            # no profile_fn
    with pytest.raises(ValueError):
        bare.enqueue_explore("m", flat_table(model), batch=4, counts={})


def test_service_explore_closes_stale_row_through_queue(tmp_path):
    m = build_model("fashion_mnist", scale=0.25)
    t = _stale_device_table(m)
    store = ProfileStore(tmp_path, fingerprint="fp")
    old = map_efficient_configuration(t, policy="dp", batch_sizes=(4,))
    store.save_mapping(old)
    svc = CacheService(store, measure_fn=lambda l, c, b: 1e-4,
                       clock=FakeClock())
    svc.register("m", m, _packed(m))
    assert svc.enqueue_explore(
        "m", t, batch=4, counts=execution_counts(old, 25)
    ) is True
    assert svc.drain(sleep=svc.queue.clock.advance) == 1
    rec = svc.journal[-1]
    assert rec.kind == "explore" and rec.status == "done"
    assert rec.result["improved"] is True
    assert store.load_mapping(
        m, policy="dp", batch=4
    ).layer_configs != old.layer_configs


def test_service_timed_write_back_flush(tmp_path):
    front, back = MemoryBackend("svc-f"), MemoryBackend("svc-b")
    tier = TieredBackend(front, back, write_back=True,
                         flush_interval_s=5.0)
    clock = FakeClock()
    svc = CacheService(ProfileStore(tier, fingerprint="fp"),
                       clock=clock)
    tier.put("k.json", "v")
    assert svc.enqueue_flush() is True       # picks up the backend's
    assert svc.enqueue_flush() is False      # interval; one timer/tier
    assert svc.run_pending() == 0            # not due until t=5
    clock.advance(5.0)
    assert svc.run_pending() == 1
    rec = svc.journal[-1]
    assert rec.kind == "flush" and rec.key == tier.uri()
    assert rec.result == {"pushed": 1, "pending": 0}
    assert back.get("k.json") == "v"
    tier.put("k2.json", "v2")                # dirty again: the timer
    clock.advance(5.0)                       # fires every interval
    assert svc.run_pending() == 1
    assert back.get("k2.json") == "v2"
    assert svc.queue.stats()["repeating"] == 1
    assert svc.queue.cancel("flush", tier.uri()) is True


def test_service_one_shot_flush_and_backend_guard(tmp_path):
    front, back = MemoryBackend("os-f"), MemoryBackend("os-b")
    tier = TieredBackend(front, back, write_back=True)
    svc = CacheService(ProfileStore(tier, fingerprint="fp"),
                       clock=FakeClock())
    tier.put("x.json", "1")
    assert svc.enqueue_flush() is True       # no interval: one-shot,
    assert svc.run_pending() == 1            # due immediately
    assert svc.queue.stats()["repeating"] == 0
    assert back.get("x.json") == "1"
    assert svc.enqueue_flush() is True       # key freed: can re-queue

    # a plain (non-write-back) store backend has nothing to flush
    bare = CacheService(ProfileStore(tmp_path, fingerprint="fp"))
    with pytest.raises(ValueError, match="flush"):
        bare.enqueue_flush()


# ---------------------------------------------------------------------------
# wiring: api store URIs, cluster shared-cache warm start
# ---------------------------------------------------------------------------


def test_plan_single_reads_through_backend_uri(tmp_path):
    m = build_model("fashion_mnist", scale=0.25)
    packed = _packed(m)
    store = ProfileStore(f"sqlite://{tmp_path}/api.db")
    tp1 = api.plan_single(
        m, packed, batch_sizes=(4,), store=store,
        time_source="analytic", repeats=1,
    )
    before = store.stats()["hits"]
    tp2 = api.plan_single(
        m, packed, batch_sizes=(4,), store=store,
        time_source="analytic", repeats=1,
    )
    # the second plan warm-started: the profile came from the cache
    assert store.stats()["hits"] > before
    assert tp2.config.layer_configs == tp1.config.layer_configs
    assert tp2.table.times == tp1.table.times


def test_cluster_warm_starts_scale_up_from_shared_store():
    tenants = [fake_tenant("a"), fake_tenant("b")]
    _clock, cluster = fake_cluster(
        tenants, n_hosts=1, store="mem://warm-start-test"
    )
    assert cluster.cache_hits == 0 and cluster.cache_misses == 0
    cluster.scale_up()
    # replicating onto the empty host first re-maps tenant a solo (a
    # group never seen: miss), then lands on the seeded {a, b} joint
    # group: hit — the mapper run is skipped entirely
    assert cluster.cache_hits == 1
    assert cluster.cache_misses == 1
    stats = cluster.stats()
    assert stats["cache"]["hits"] == 1
    assert stats["cache"]["backend"]["backend"] == "mem"
    # every host serves every tenant after the scale-up
    for name in ("a", "b"):
        assert len(cluster._hosts_for(name)) == 2
