"""Flash attention kernel vs naive oracle: shape/dtype/block sweeps,
GQA groups, causal + full, prefill + single-token decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import flash_attention
from repro.kernels.ref import attention_ref


def _qkv(key, b, h, hkv, sq, sk, d, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, sq, d)).astype(dtype)
    k = jax.random.normal(kk, (b, hkv, sk, d)).astype(dtype)
    v = jax.random.normal(kv, (b, hkv, sk, d)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,h,hkv,s,d", [
    (1, 1, 1, 128, 32),
    (2, 4, 2, 256, 64),
    (1, 8, 1, 128, 128),   # MQA
    (2, 6, 6, 64, 64),     # MHA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_prefill(b, h, hkv, s, d, causal):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, h, hkv, s, s, d, jnp.float32)
    want = attention_ref(q, k, v, causal=causal)
    got = flash_attention(
        q, k, v, causal=causal, backend="pallas", interpret=True,
        q_blk=64, k_blk=64,
    )
    np.testing.assert_allclose(
        np.asarray(want), np.asarray(got), atol=2e-5, rtol=2e-5
    )


@pytest.mark.parametrize("q_blk,k_blk", [(32, 32), (64, 128), (128, 64)])
def test_flash_block_sweep(q_blk, k_blk):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 4, 2, 256, 256, 64, jnp.float32)
    want = attention_ref(q, k, v, causal=True)
    got = flash_attention(
        q, k, v, causal=True, backend="pallas", interpret=True,
        q_blk=q_blk, k_blk=k_blk,
    )
    np.testing.assert_allclose(
        np.asarray(want), np.asarray(got), atol=2e-5, rtol=2e-5
    )


def test_flash_decode_single_query():
    """Sq=1 against a long KV history — the serve_step shape."""
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 4, 2, 1, 512, 64, jnp.float32)
    want = attention_ref(q, k, v, causal=True)
    got = flash_attention(
        q, k, v, causal=True, backend="pallas", interpret=True,
        q_blk=1, k_blk=128,
    )
    np.testing.assert_allclose(
        np.asarray(want), np.asarray(got), atol=2e-5, rtol=2e-5
    )


def test_flash_bf16():
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 2, 1, 128, 128, 64, jnp.bfloat16)
    want = attention_ref(q, k, v, causal=True)  # computed in f32
    got = flash_attention(
        q, k, v, causal=True, backend="pallas", interpret=True,
        q_blk=64, k_blk=64,
    )
    np.testing.assert_allclose(
        np.asarray(want).astype(np.float32),
        np.asarray(got).astype(np.float32),
        atol=2e-2, rtol=2e-2,
    )


def test_flash_numerical_stability_large_logits():
    """Blockwise softmax must not overflow with large score magnitudes."""
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 1, 1, 128, 128, 32, jnp.float32)
    q = q * 30.0
    want = attention_ref(q, k, v, causal=True)
    got = flash_attention(
        q, k, v, causal=True, backend="pallas", interpret=True,
        q_blk=32, k_blk=32,
    )
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(
        np.asarray(want), np.asarray(got), atol=5e-5, rtol=5e-5
    )
