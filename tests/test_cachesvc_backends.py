"""Cache-service backend layer: one shared contract suite over the
dir / sqlite / mem backends (plus the tiered composition), URI
resolution, eviction policies, and ProfileStore bit-compatibility —
a store grown under the old plain-directory layout must load
unchanged through the backend layer, and the same artifacts must
round-trip through a single-file sqlite backend.
"""

import time
from pathlib import Path

import numpy as np
import pytest

import jax  # noqa: F401  (initialize before repro imports)

from repro.bnn import build_model
from repro.cachesvc import (
    EvictionPolicy,
    LocalDirBackend,
    MemoryBackend,
    SqliteBackend,
    StoreBackend,
    TieredBackend,
    parse_backend,
)
from repro.cachesvc.backends import validate_key
from repro.core.mapper import map_efficient_configuration
from repro.core.profiler import ProfileTable
from repro.store import ProfileStore

from tests.fixtures import FakeClock

BACKENDS = ("dir", "sqlite", "mem", "tiered")


def make_backend(kind, tmp_path, *, policy=None, clock=time.time):
    if kind == "dir":
        return LocalDirBackend(tmp_path / "root", policy=policy,
                               clock=clock)
    if kind == "sqlite":
        return SqliteBackend(tmp_path / "cache.db", policy=policy,
                             clock=clock)
    if kind == "mem":
        return MemoryBackend(policy=policy, clock=clock)
    if kind == "tiered":
        return TieredBackend(
            MemoryBackend(clock=clock),
            SqliteBackend(tmp_path / "back.db", clock=clock),
            policy=policy, clock=clock,
        )
    raise AssertionError(kind)


# ---------------------------------------------------------------------------
# shared contract: every backend behaves identically
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", BACKENDS)
def test_contract_roundtrip_counters_and_peek(kind, tmp_path):
    b = make_backend(kind, tmp_path)
    assert b.get("a/x.json") is None
    assert b.misses == 1 and b.hits == 0
    b.put("a/x.json", '{"v": 1}')
    assert b.puts == 1
    assert b.get("a/x.json") == '{"v": 1}'
    assert b.hits == 1
    # peek is counter-silent: maintenance reads must not skew the
    # popularity signal the prewarm worker ranks on
    assert b.peek("a/x.json") == '{"v": 1}'
    assert b.peek("a/missing.json") is None
    assert b.hits == 1 and b.misses == 1
    assert b.access_counts() == {"a/x.json": 1}
    b.get("a/x.json")
    assert b.access_counts() == {"a/x.json": 2}


@pytest.mark.parametrize("kind", BACKENDS)
def test_contract_overwrite_etag_and_delete(kind, tmp_path):
    b = make_backend(kind, tmp_path)
    assert b.etag("k.json") is None
    b.put("k.json", "one")
    tag1 = b.etag("k.json")
    assert tag1 and len(tag1) == 12
    b.put("k.json", "one")
    assert b.etag("k.json") == tag1          # content-addressed
    b.put("k.json", "two")
    assert b.etag("k.json") != tag1          # change detection
    assert b.get("k.json") == "two"
    assert b.delete("k.json") is True
    assert b.delete("k.json") is False
    assert b.deletes == 1
    assert b.get("k.json") is None
    assert b.access_counts() == {}           # forgotten with the entry


@pytest.mark.parametrize("kind", BACKENDS)
def test_contract_list_is_prefix_filtered_and_sorted(kind, tmp_path):
    b = make_backend(kind, tmp_path)
    for k in ("v1/fp/b/m.json", "v1/fp/a/p.json", "v2/other.json"):
        b.put(k, "{}")
    assert b.list() == [
        "v1/fp/a/p.json", "v1/fp/b/m.json", "v2/other.json",
    ]
    assert b.list("v1/fp/") == ["v1/fp/a/p.json", "v1/fp/b/m.json"]
    assert b.list("nope/") == []


@pytest.mark.parametrize("kind", BACKENDS)
def test_contract_stats_shape(kind, tmp_path):
    b = make_backend(kind, tmp_path)
    b.put("x.json", "1")
    s = b.stats()
    for field in ("backend", "uri", "entries", "hits", "misses",
                  "puts", "deletes", "evictions"):
        assert field in s
    assert s["entries"] == 1
    assert s["uri"] == b.uri()


@pytest.mark.parametrize("key", [
    "/abs/path.json", "a/../b.json", "./x.json", "a\\b.json",
    "bad\0key.json", "",
])
def test_hostile_keys_rejected_everywhere(key, tmp_path):
    with pytest.raises(ValueError):
        validate_key(key)
    b = make_backend("dir", tmp_path)
    for op in (b.get, b.peek, b.etag, b.delete):
        with pytest.raises(ValueError):
            op(key)
    with pytest.raises(ValueError):
        b.put(key, "x")


# ---------------------------------------------------------------------------
# eviction: LRU by access recency, TTL by write age
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ("dir", "sqlite", "mem"))
def test_lru_eviction_keeps_recently_accessed(kind, tmp_path):
    clock = FakeClock()
    clock.t = time.time() + 3600.0   # ahead of any file mtime
    b = make_backend(kind, tmp_path,
                     policy=EvictionPolicy(max_entries=2), clock=clock)
    b.put("a.json", "A")
    clock.advance(1.0)
    b.put("b.json", "B")
    clock.advance(1.0)
    assert b.get("a.json") == "A"    # freshen a: b is now the LRU
    clock.advance(1.0)
    b.put("c.json", "C")             # put sweeps -> evicts b
    assert b.evictions == 1
    assert b.list() == ["a.json", "c.json"]
    assert b.get("b.json") is None


@pytest.mark.parametrize("kind", ("sqlite", "mem"))
def test_ttl_eviction_drops_stale_writes(kind, tmp_path):
    clock = FakeClock()
    b = make_backend(kind, tmp_path,
                     policy=EvictionPolicy(ttl_s=50.0), clock=clock)
    b.put("old.json", "O")
    clock.advance(100.0)
    b.put("new.json", "N")           # put sweeps -> old is past TTL
    assert b.evictions == 1
    assert b.list() == ["new.json"]


def test_dir_ttl_uses_file_mtime(tmp_path):
    b = make_backend("dir", tmp_path,
                     policy=EvictionPolicy(ttl_s=50.0))
    b.put("old.json", "O")
    p = b.path_for("old.json")
    stale = time.time() - 100.0
    import os
    os.utime(p, (stale, stale))      # backdate: written 100s ago
    assert b.sweep() == 1
    assert b.list() == []


def test_eviction_policy_validates():
    with pytest.raises(ValueError):
        EvictionPolicy(max_entries=0)
    with pytest.raises(ValueError):
        EvictionPolicy(ttl_s=0.0)
    p = EvictionPolicy()             # unbounded by default
    assert p.max_entries is None and p.ttl_s is None


# ---------------------------------------------------------------------------
# backend-specific behavior
# ---------------------------------------------------------------------------


def test_dir_backend_atomic_files_and_prune(tmp_path):
    b = make_backend("dir", tmp_path)
    b.put("v1/deep/nested/x.json", "{}")
    p = b.path_for("v1/deep/nested/x.json")
    assert p.is_file() and p.read_text() == "{}"
    assert not list(b.root.rglob("*.tmp"))   # atomic writes clean up
    assert b.path_for("") == b.root
    b.delete("v1/deep/nested/x.json")
    b.prune_empty_dirs()
    assert not (b.root / "v1").exists()


def test_sqlite_two_handles_share_one_file(tmp_path):
    db = tmp_path / "shared.db"
    a = SqliteBackend(db)
    b = SqliteBackend(db)
    a.put("k.json", "from-a")
    assert b.get("k.json") == "from-a"
    b.put("k.json", "from-b")
    assert a.get("k.json") == "from-b"
    assert a.etag("k.json") == b.etag("k.json")


def test_mem_registry_shares_by_name():
    a = parse_backend("mem://contract-shared")
    b = parse_backend("mem://contract-shared")
    assert a is b
    a.put("k.json", "x")
    assert b.get("k.json") == "x"
    # anonymous mem:// handles are always fresh and private
    c = parse_backend("mem://")
    d = parse_backend("mem://")
    assert c is not d and c.get("k.json") is None


def test_tiered_front_serves_after_back_loss(tmp_path):
    front = MemoryBackend()
    back = MemoryBackend()
    t = TieredBackend(front, back)
    back.put("k.json", "v")
    assert t.get("k.json") == "v"            # read-through promotes
    assert front.peek("k.json") == "v"
    back.delete("k.json")
    assert t.get("k.json") == "v"            # served from the front
    t.put("w.json", "x")                     # write-through default
    assert back.peek("w.json") == "x"
    s = t.stats()
    assert s["front"]["backend"] == "mem" and s["back"]["backend"] == "mem"


def test_tiered_write_back_flush_and_etag_skip(tmp_path):
    front, back = MemoryBackend(), MemoryBackend()
    t = TieredBackend(front, back, write_back=True)
    t.put("a.json", "1")
    t.put("b.json", "2")
    assert back.peek("a.json") is None       # journaled, not pushed
    assert t.dirty() == ("a.json", "b.json")
    assert t.flush() == 2
    assert back.peek("a.json") == "1" and back.peek("b.json") == "2"
    assert t.flush() == 0                    # nothing dirty
    t.put("a.json", "1")                     # same bytes re-dirtied
    assert t.flush() == 0                    # ETag-identical: skipped
    t.put("a.json", "new")
    assert t.flush() == 1
    assert back.peek("a.json") == "new"


def test_tiered_flush_interval_knob_validated():
    front, back = MemoryBackend(), MemoryBackend()
    t = TieredBackend(front, back, write_back=True,
                      flush_interval_s=5.0)
    assert t.flush_interval_s == 5.0
    assert t.stats()["flush_interval_s"] == 5.0
    with pytest.raises(ValueError, match="positive"):
        TieredBackend(front, back, write_back=True,
                      flush_interval_s=0.0)
    with pytest.raises(ValueError, match="write_back"):
        TieredBackend(front, back, flush_interval_s=5.0)


# ---------------------------------------------------------------------------
# URI resolution
# ---------------------------------------------------------------------------


def test_parse_backend_resolution(tmp_path):
    assert isinstance(parse_backend(tmp_path), LocalDirBackend)
    assert isinstance(parse_backend(str(tmp_path)), LocalDirBackend)
    d = parse_backend(f"dir://{tmp_path}/sub")
    assert isinstance(d, LocalDirBackend)
    assert d.root == tmp_path / "sub"
    s = parse_backend(f"sqlite://{tmp_path}/c.db")
    assert isinstance(s, SqliteBackend)
    m = parse_backend("mem://p9")
    assert isinstance(m, MemoryBackend) and m.name == "p9"
    b = MemoryBackend()
    assert parse_backend(b) is b             # instance passthrough
    with pytest.raises(ValueError):
        parse_backend("sqlite://")
    with pytest.raises(ValueError):
        parse_backend("dir://")
    with pytest.raises(ValueError):
        parse_backend("redis://nope")
    with pytest.raises(TypeError):
        parse_backend(42)


def test_backend_base_class_is_abstract(tmp_path):
    b = StoreBackend()
    with pytest.raises(NotImplementedError):
        b.get("x.json")


# ---------------------------------------------------------------------------
# ProfileStore over backends: bit-compatibility and sqlite round-trip
# ---------------------------------------------------------------------------


def _model_and_table():
    m = build_model("fashion_mnist", scale=0.25)
    labels = tuple(f"L{s.idx}:{s.notation}" for s in m.specs)
    rng = np.random.default_rng(7)
    from repro.core.parallel_config import CONFIGS, CPU

    times, kernels, h2d, d2h = {}, {}, {}, {}
    for b in (1, 4):
        times[b], kernels[b], h2d[b], d2h[b] = [], [], [], []
        for _ in labels:
            krow = {c: float(rng.uniform(1e-6, 1e-3)) for c in CONFIGS}
            up, down = (float(x) for x in rng.uniform(1e-6, 5e-4, 2))
            kernels[b].append(krow)
            times[b].append({
                c: krow[c] if c == CPU else krow[c] + up + down
                for c in CONFIGS
            })
            h2d[b].append(up)
            d2h[b].append(down)
    t = ProfileTable(m.name, (1, 4), labels, times,
                     kernel_times=kernels, h2d_times=h2d,
                     d2h_times=d2h)
    return m, t


def test_old_plain_directory_roots_load_unchanged(tmp_path):
    """Bit-compatibility: a root grown before the backend layer (plain
    Path construction, files on disk) must read identically through a
    dir:// URI and an explicit LocalDirBackend handle."""
    m, t = _model_and_table()
    old = ProfileStore(tmp_path, fingerprint="fp-compat")
    p = old.save_profile(t)
    ec = map_efficient_configuration(t, policy="dp")
    old.save_mapping(ec)
    assert p.is_file()                       # real files, old layout

    for spec in (tmp_path, f"dir://{tmp_path}",
                 LocalDirBackend(tmp_path)):
        store = ProfileStore(spec, fingerprint="fp-compat")
        got = store.load_profile(m, (1, 4))
        assert got is not None and got.times == t.times
        cfg = store.load_mapping(m, policy="dp", batch=ec.proper_batch_size)
        assert cfg is not None
        assert cfg.layer_configs == ec.layer_configs


def test_profile_store_round_trips_through_sqlite(tmp_path):
    m, t = _model_and_table()
    uri = f"sqlite://{tmp_path}/store.db"
    a = ProfileStore(uri, fingerprint="fp-sql")
    a.save_profile(t)
    ec = map_efficient_configuration(t, policy="dp")
    a.save_mapping(ec)

    b = ProfileStore(uri, fingerprint="fp-sql")  # second handle
    got = b.load_profile(m, (1, 4))
    assert got is not None and got.times == t.times
    cfg = b.load_mapping(m, policy="dp", batch=ec.proper_batch_size)
    assert cfg is not None and cfg.layer_configs == ec.layer_configs
    assert sorted(e.kind for e in b.entries()) == [
        "efficient_configuration", "profile_table",
    ]
    # the whole store is one file: nothing else on disk
    assert [p.name for p in tmp_path.iterdir()
            if not p.name.startswith("store.db")] == []
    stats = b.stats()
    assert stats["backend"] == "sqlite" and stats["entries"] == 2


def test_store_stats_counts_hits_and_misses(tmp_path):
    m, t = _model_and_table()
    store = ProfileStore("mem://", fingerprint="fp-stats")
    assert store.load_profile(m, (1, 4)) is None
    store.save_profile(t)
    assert store.load_profile(m, (1, 4)) is not None
    s = store.stats()
    assert s["hits"] == 1 and s["misses"] >= 1 and s["puts"] == 1
