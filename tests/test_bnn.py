"""BNN substrate: fp-sim vs packed-integer equivalence for both paper
models, BN threshold folding property, training convergence."""

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.bnn import build_model
from repro.bnn import layers as L
from repro.bnn.fold_bn import fold_bn
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.bnn.train import init_train_state, train_step, eval_step
from repro.data import make_image_dataset, ShardedBatcher


@pytest.mark.parametrize("name,scale", [
    ("fashion_mnist", 0.5), ("cifar10", 0.25),
])
def test_fp_vs_packed_exact(name, scale):
    m = build_model(name, scale=scale)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    # randomize BN state so folding is non-trivial
    for spec, p in zip(m.specs, params):
        if spec.kind == "step":
            key, k1, k2, k3, k4 = jax.random.split(key, 5)
            p["gamma"] = jax.random.normal(k1, p["gamma"].shape)  # +/- mix
            p["beta"] = jax.random.normal(k2, p["beta"].shape)
            p["mean"] = jax.random.normal(k3, p["mean"].shape) * 5
            p["var"] = jax.random.uniform(k4, p["var"].shape, minval=0.1)
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, *m.input_hw, m.in_channels))
    logits_fp, _ = m.apply_fp(params, x, train=False)
    scores = forward_packed(m.specs, pack_params(m.specs, params),
                            prepare_input_packed(x))
    assert np.array_equal(
        np.asarray(scores), np.asarray(logits_fp).astype(np.int64)
    )


def test_paper_model_structure():
    fm = build_model("fashion_mnist")
    cf = build_model("cifar10")
    assert len(fm.specs) == 10           # paper: 10 layers
    assert len(cf.specs) == 19           # paper: 19 layers
    # paper's stated positions (1-based): conv at 1,4 (FMNIST)
    assert [s.kind for s in fm.specs[:2]] == ["conv", "mp"]
    assert fm.specs[3].kind == "conv"
    # CIFAR conv positions 1,3,6,8,11,13
    conv_idx = [s.idx for s in cf.specs if s.kind == "conv"]
    assert conv_idx == [1, 3, 6, 8, 11, 13]
    mp_idx = [s.idx for s in cf.specs if s.kind == "mp"]
    assert mp_idx == [4, 9, 14]
    # output head is 10 classes
    assert fm.specs[-1].out_shape == (10,)
    assert cf.specs[-1].out_shape == (10,)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    gamma_sign=st.sampled_from([-1.0, 0.0, 1.0]),
)
def test_fold_bn_matches_fp(seed, gamma_sign):
    """Property: integer threshold compare == sign(BN(y)) for integer y."""
    rng = np.random.default_rng(seed)
    c = 8
    gamma = rng.normal(size=c) * (gamma_sign if gamma_sign else 0.0)
    if gamma_sign == 0.0:
        gamma = np.zeros(c)
    beta = rng.normal(size=c) * 3
    mean = rng.normal(size=c) * 10
    var = rng.uniform(0.05, 4.0, size=c)
    t, flip = fold_bn(gamma, beta, mean, var)
    y = rng.integers(-500, 500, size=(64, c))
    bn = gamma * (y - mean) / np.sqrt(var + L.BN_EPS) + beta
    want = bn >= 0
    got = (y > t) ^ flip
    assert np.array_equal(want, got)


def test_training_learns():
    m = build_model("fashion_mnist", scale=0.25)
    ds = make_image_dataset(0, 512, (28, 28), 1)
    state, opt = init_train_state(m, jax.random.PRNGKey(0), lr=2e-3)
    bt = ShardedBatcher(n=512, global_batch=64, seed=0)
    for step in range(40):
        x, y = bt.batch((ds.x, ds.y), step)
        state, metrics = train_step(m, opt, state, x, y)
        assert np.isfinite(float(metrics["loss"]))
    xe, ye = bt.batch((ds.x, ds.y), 10_001)
    acc = float(eval_step(m, state.params, xe, ye))
    assert acc > 0.5, f"BNN failed to learn (acc={acc})"


def test_trained_model_packs_and_agrees():
    """Train a few steps, quantize, verify packed inference == fp eval."""
    m = build_model("fashion_mnist", scale=0.25)
    ds = make_image_dataset(1, 256, (28, 28), 1)
    state, opt = init_train_state(m, jax.random.PRNGKey(2), lr=1e-3)
    bt = ShardedBatcher(n=256, global_batch=32, seed=1)
    for step in range(10):
        x, y = bt.batch((ds.x, ds.y), step)
        state, _ = train_step(m, opt, state, x, y)
    x, _ = bt.batch((ds.x, ds.y), 99)
    logits_fp, _ = m.apply_fp(state.params, x, train=False)
    scores = forward_packed(
        m.specs, pack_params(m.specs, state.params), prepare_input_packed(x)
    )
    assert np.array_equal(
        np.asarray(scores), np.asarray(logits_fp).astype(np.int64)
    )
