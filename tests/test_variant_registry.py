"""Kernel-variant registry + autotuner: registry API, bit-exactness of
every applicable variant vs the reference GEMM, warm-up pruning,
variable-size config spaces end to end (profiler -> mapper -> executor
-> JSON), and the autotuned-vs-fixed-8 acceptance bound."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.bnn import build_model
from repro.bnn.binarize import pack_bits
from repro.bnn.models import forward_packed, pack_params, prepare_input_packed
from repro.core import cost_model as cm
from repro.core.mapped_model import build_mapped_model, build_segment_fns
from repro.core.mapper import (
    EfficientConfiguration,
    configuration_from_mapping,
    map_efficient_configuration,
    placement_of,
)
from repro.core.parallel_config import (
    CONFIGS,
    CPU,
    aspects_of,
    is_host_config,
    validate,
)
from repro.core.profiler import (
    autotune_bnn_model,
    gemm_shape_of,
    profile_bnn_model,
    prune_survivors,
)
from repro.kernels.ref import xnor_gemm_ref
from repro.kernels.registry import (
    DEFAULT_REGISTRY,
    DEVICE,
    HOST,
    GemmShape,
    KernelVariant,
    VariantRegistry,
)

RESULTS = Path(__file__).resolve().parents[1] / "results"


# ---------------------------------------------------------------------------
# Registry API
# ---------------------------------------------------------------------------


def test_default_registry_contents():
    names = DEFAULT_REGISTRY.names()
    for cfg in CONFIGS:  # the paper's 8 resolve by their legacy names
        assert cfg in DEFAULT_REGISTRY, cfg
    assert "xla_fused" in names
    assert any(n.startswith("pallas_") for n in names)
    assert len(set(names)) == len(names)
    assert DEFAULT_REGISTRY.get(CPU).placement == HOST
    assert DEFAULT_REGISTRY.get("xla_fused").placement == DEVICE


def test_register_rejects_duplicates_and_bad_placement():
    reg = VariantRegistry()
    v = KernelVariant(name="v1", builder=xnor_gemm_ref)
    reg.register(v)
    with pytest.raises(ValueError, match="already registered"):
        reg.register(v)
    reg.register(
        KernelVariant(name="v1", builder=xnor_gemm_ref), replace=True
    )
    with pytest.raises(ValueError, match="placement"):
        reg.register(
            KernelVariant(
                name="v2", builder=xnor_gemm_ref, placement="gpu"
            )
        )
    with pytest.raises(ValueError, match="unknown kernel variant"):
        reg.get("nope")
    assert reg.remove("v1").name == "v1"
    assert "v1" not in reg


def test_fixed8_placement_and_aspects_are_frozen():
    """The fixed-8 names short-circuit placement/pricing before the
    registry, so re-registering one must not change those semantics
    (builder hot-swaps keep them; divergent metadata is rejected)."""
    reg = VariantRegistry()
    with pytest.raises(ValueError, match="frozen placement/aspects"):
        reg.register(
            KernelVariant(name="X", builder=xnor_gemm_ref, placement=HOST)
        )
    with pytest.raises(ValueError, match="frozen placement/aspects"):
        reg.register(
            KernelVariant(
                name=CPU, builder=xnor_gemm_ref, placement=HOST,
                aspects=("X",), analytic="host",
            )
        )
    # same semantics, different builder: allowed
    reg.register(
        KernelVariant(
            name="X", builder=xnor_gemm_ref, placement=DEVICE,
            aspects=("X",),
        )
    )
    assert reg.get("X").builder is xnor_gemm_ref


def test_applicability_filtering():
    reg = VariantRegistry()
    reg.register(KernelVariant(name="always", builder=xnor_gemm_ref))
    reg.register(
        KernelVariant(
            name="small_only",
            builder=xnor_gemm_ref,
            applicable=lambda shape, platform: shape.work <= 100,
        )
    )
    small = GemmShape(b=1, p=5, n=2, kw=10)
    big = GemmShape(b=8, p=100, n=64, kw=16)
    assert [v.name for v in reg.applicable(small, "cpu")] == [
        "always", "small_only",
    ]
    assert [v.name for v in reg.applicable(big, "cpu")] == ["always"]


def test_parallel_config_consults_registry():
    assert validate("xla_fused") == "xla_fused"
    assert validate("pallas_p64n64") == "pallas_p64n64"
    with pytest.raises(ValueError, match="unknown parallel config"):
        validate("not_a_variant")
    assert aspects_of("xla_fused") == ("X", "Y", "Z")
    assert aspects_of("XZ") == ("X", "Z")
    assert aspects_of(CPU) == ()
    with pytest.raises(ValueError):
        aspects_of("not_a_variant")
    assert is_host_config(CPU)
    assert not is_host_config("xla_fused")
    assert placement_of("pallas_p128n128") == "device"
    # a typo'd name must fail loudly, never default to device placement
    with pytest.raises(ValueError, match="unknown parallel config"):
        is_host_config("not_a_variant")
    # custom registries resolve placement for their own names
    reg = VariantRegistry()
    reg.register(
        KernelVariant(
            name="my_host_v", builder=xnor_gemm_ref, placement=HOST,
            aspects=(), analytic="host",
        )
    )
    assert is_host_config("my_host_v", reg)
    with pytest.raises(ValueError):
        is_host_config("my_host_v")     # not globally registered


# ---------------------------------------------------------------------------
# Bit-exactness: every applicable registered variant vs the reference
# ---------------------------------------------------------------------------

_SHAPES = (
    (1, 1, 32, 1),
    (2, 9, 33, 5),
    (2, 24, 96, 17),
    (3, 17, 64, 40),
)


@settings(max_examples=8, deadline=None)
@given(
    case=st.integers(0, len(_SHAPES) - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_every_applicable_variant_bit_exact(case, seed):
    """Property (acceptance): any variant the registry deems applicable
    to a shape must compute exactly xnor_gemm_ref on it."""
    b, p, k_bits, n = _SHAPES[case]
    rng = np.random.default_rng(seed)
    a_pm1 = jnp.asarray(
        np.where(rng.random((b, p, k_bits)) < 0.5, 1.0, -1.0)
    )
    w_pm1 = jnp.asarray(
        np.where(rng.random((n, k_bits)) < 0.5, 1.0, -1.0)
    )
    a_words = pack_bits(a_pm1, pad_bit=0)
    w_words = pack_bits(w_pm1, pad_bit=1)
    want = np.asarray(xnor_gemm_ref(a_words, w_words, k_bits))
    shape = GemmShape(b=b, p=p, n=n, kw=int(a_words.shape[-1]))
    variants = DEFAULT_REGISTRY.applicable(shape)
    assert len(variants) >= len(CONFIGS)
    for v in variants:
        got = np.asarray(v.builder(a_words, w_words, k_bits))
        assert np.array_equal(want, got), f"variant {v.name} diverged"


# ---------------------------------------------------------------------------
# Autotune: variable spaces, pruning, fixed-8 bound
# ---------------------------------------------------------------------------


def _small_model():
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    return m, packed


def test_prune_survivors_decision():
    warmups = {"CPU": 5.0, "X": 1.0, "ext_ok": 2.9, "ext_slow": 3.1}
    kept = prune_survivors(warmups, prune_factor=3.0)
    assert "ext_ok" in kept
    assert "ext_slow" not in kept
    # fixed-8 names survive no matter how slow the warm-up said they are
    assert "CPU" in kept and "X" in kept
    assert prune_survivors({}) == ()


def test_autotune_analytic_variable_spaces_and_bound():
    """Acceptance: the autotuned table's DP expected end-to-end time is
    <= the fixed-8 DP's on the same (analytic) profile, and GEMM rows
    are strict supersets of the fixed-8 space."""
    m, packed = _small_model()
    table = autotune_bnn_model(
        m, packed, batch_sizes=(1, 16), time_source="analytic"
    )
    saw_extended = False
    for b in table.batch_sizes:
        for i, spec in enumerate(m.specs):
            row = set(table.configs_for(b, i))
            assert set(CONFIGS) <= row
            if spec.kind in ("conv", "fc"):
                assert "xla_fused" in row
                # analytic mode prices the TPU target: pallas tile
                # variants are candidates even on large layers the
                # interpret-mode cap would exclude on this CPU host
                assert "pallas_p128n128" in row
                saw_extended = True
            else:
                assert row == set(CONFIGS)
    assert saw_extended
    dp_full = map_efficient_configuration(table, policy="dp")
    dp_fixed = map_efficient_configuration(
        table, policy="dp", configs=CONFIGS
    )
    assert (
        dp_full.expected_time_per_example
        <= dp_fixed.expected_time_per_example + 1e-15
    )
    # greedy over the wider space is bounded the same way
    g_full = map_efficient_configuration(table, policy="greedy")
    g_fixed = map_efficient_configuration(
        table, policy="greedy", configs=CONFIGS
    )
    assert (
        g_full.expected_time_per_example
        <= g_fixed.expected_time_per_example + 1e-15
    )
    # config_space records the per-layer searchable space, variable-size
    sizes = {len(cs) for cs in dp_full.config_space}
    assert len(sizes) > 1
    assert all(
        len(cs) == len(CONFIGS) for cs in dp_fixed.config_space
    )


def test_autotune_measured_bound_and_pruning():
    m, packed = _small_model()
    table = autotune_bnn_model(
        m, packed, batch_sizes=(1,), repeats=1, prune_factor=3.0
    )
    for i, spec in enumerate(m.specs):
        row = set(table.configs_for(1, i))
        # pruning may drop extended variants but never the fixed 8
        assert set(CONFIGS) <= row
    dp_full = map_efficient_configuration(table, policy="dp")
    dp_fixed = map_efficient_configuration(
        table, policy="dp", configs=CONFIGS
    )
    assert (
        dp_full.expected_time_per_example
        <= dp_fixed.expected_time_per_example + 1e-12
    )


def test_autotune_honors_custom_registry():
    """A variant registered in a custom registry is profiled, priced
    analytically, and executable — without touching the process-wide
    default registry."""
    reg = VariantRegistry()
    for v in DEFAULT_REGISTRY:
        reg.register(v)
    reg.register(
        KernelVariant(
            name="custom_ref",
            builder=xnor_gemm_ref,
            placement=DEVICE,
            analytic="fused",
        )
    )
    m, packed = _small_model()
    table = autotune_bnn_model(
        m, packed, registry=reg, batch_sizes=(1,), repeats=1,
        prune_factor=float("inf"),
    )
    gemm_rows = [
        set(table.configs_for(1, i))
        for i, spec in enumerate(m.specs)
        if spec.kind in ("conv", "fc")
    ]
    assert all("custom_ref" in row for row in gemm_rows)
    assert "custom_ref" not in DEFAULT_REGISTRY
    # analytic pricing resolves through the custom registry too
    atable = autotune_bnn_model(
        m, packed, registry=reg, batch_sizes=(1,),
        time_source="analytic",
    )
    idx = next(
        i for i, s in enumerate(m.specs) if s.kind in ("conv", "fc")
    )
    assert "custom_ref" in atable.configs_for(1, idx)
    # device-placed: the paper-semantics total carries the boundary
    assert atable.times[1][idx]["custom_ref"] == pytest.approx(
        atable.kernel_time(1, idx, "custom_ref")
        + atable.h2d(1, idx) + atable.d2h(1, idx)
    )
    # mapping/executing a variant requires global registration (the
    # placement authority and validate() are global); after that the
    # custom name flows through pricing and execution like any other
    mapping = [
        "custom_ref" if s.kind in ("conv", "fc") else CPU
        for s in m.specs
    ]
    with pytest.raises(ValueError):
        configuration_from_mapping(atable, 1, mapping)
    DEFAULT_REGISTRY.register(reg.get("custom_ref"))
    try:
        ec = configuration_from_mapping(atable, 1, mapping)
        x = prepare_input_packed(
            jax.random.uniform(
                jax.random.PRNGKey(3), (1, *m.input_hw, m.in_channels)
            )
        )
        got = build_mapped_model(m, packed, ec, registry=reg)(x)
        want = forward_packed(m.specs, packed, x)
        assert np.array_equal(np.asarray(want), np.asarray(got))
    finally:
        DEFAULT_REGISTRY.remove("custom_ref")


def test_analytic_fused_never_loses_to_tiled():
    """The fused device reference's analytic kernel time is <= every
    tiled aspect config's for any GEMM (single-pass traffic is a lower
    bound on the loop-nest reuse traffic)."""
    for dims in (
        cm.GemmDims(b=2, p=1024, n=1024, kw=4),
        cm.GemmDims(b=8, p=196, n=64, kw=9),
        cm.GemmDims(b=1, p=1, n=512, kw=49),
    ):
        fused = cm.gemm_kernel_time_tpu(dims, "xla_fused")
        for cfg in CONFIGS[1:]:
            assert fused <= cm.gemm_kernel_time_tpu(dims, cfg) + 1e-15


def test_gemm_shape_of_matches_cost_model_dims():
    m, packed = _small_model()
    for spec, p in zip(m.specs, packed):
        shape = gemm_shape_of(spec, p, 4)
        dims = cm.gemm_dims_for(spec, 4)
        if dims is None:
            assert shape is None
        else:
            assert (shape.b, shape.p, shape.n) == (
                dims.b, dims.p, dims.n
            )
            assert shape.kw == dims.kw


# ---------------------------------------------------------------------------
# Variable-size config spaces end to end: executor + JSON
# ---------------------------------------------------------------------------


def test_extended_mapping_executes_bit_exact():
    m, packed = _small_model()
    table = autotune_bnn_model(
        m, packed, batch_sizes=(1, 4), time_source="analytic"
    )
    mapping = [
        "xla_fused" if s.kind in ("conv", "fc") else CPU for s in m.specs
    ]
    # at scale 0.25 the last FC is small enough for interpret-mode pallas
    assert m.specs[-1].kind == "fc"
    mapping[-1] = "pallas_p64n64"
    ec = configuration_from_mapping(table, 4, mapping)
    x = prepare_input_packed(
        jax.random.uniform(
            jax.random.PRNGKey(1), (4, *m.input_hw, m.in_channels)
        )
    )
    want = np.asarray(forward_packed(m.specs, packed, x))
    fused = build_mapped_model(m, packed, ec, fused=True)
    assert np.array_equal(want, np.asarray(fused(x)))
    faithful = build_mapped_model(m, packed, ec, fused=False)
    assert np.array_equal(want, np.asarray(faithful(x)))
    out = x
    for _seg, fn in build_segment_fns(m, packed, ec):
        out = fn(out)
    assert np.array_equal(want, np.asarray(out))


def test_config_space_json_roundtrip():
    m, packed = _small_model()
    table = autotune_bnn_model(
        m, packed, batch_sizes=(1,), time_source="analytic"
    )
    for policy in ("greedy", "dp"):
        ec = map_efficient_configuration(table, policy=policy)
        back = EfficientConfiguration.from_json(ec.to_json())
        assert back == ec
        d = json.loads(ec.to_json())
        assert all("candidates" in x for x in d["layers"])
        # per-layer candidate lists are genuinely variable-size
        assert len({len(x["candidates"]) for x in d["layers"]}) > 1


def test_legacy_fixed8_json_still_loads_and_reserializes():
    """Acceptance: the committed pre-registry artifact round-trips
    under the variable-size schema."""
    src = (RESULTS / "efficient_config_fmnist.json").read_text()
    ec = EfficientConfiguration.from_json(src)
    assert ec.policy == "dp"
    assert ec.config_space == ()            # legacy: fixed-8 implied
    assert all(c in CONFIGS for c in ec.layer_configs)
    assert all(validate(c) for c in ec.layer_configs)
    again = EfficientConfiguration.from_json(ec.to_json())
    assert again == ec
    # the re-serialized form stays legacy-shaped: no candidates key
    d = json.loads(ec.to_json())
    assert all("candidates" not in x for x in d["layers"])
    # and the original numbers survive the trip
    orig = json.loads(src)
    assert d["expected_time_per_example"] == (
        orig["expected_time_per_example"]
    )
    assert [x["config"] for x in d["layers"]] == [
        x["config"] for x in orig["layers"]
    ]


def test_fixed_profile_unchanged_by_registry():
    """profile_bnn_model keeps the paper's fixed-8 rows exactly."""
    m, packed = _small_model()
    table = profile_bnn_model(
        m, packed, batch_sizes=(1,), time_source="analytic"
    )
    for i in range(len(table.layer_labels)):
        assert table.configs_for(1, i) == CONFIGS
