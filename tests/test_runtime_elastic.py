"""Elastic re-mesh + serving-loop integration (subprocess for the
multi-device part)."""

import subprocess
import sys
import textwrap

import jax
import numpy as np

from repro import configs as C
from repro.models.steps import greedy_decode
from repro.models.transformer import init_params


def test_greedy_decode_runs_all_families():
    """Serving loop across a KV arch and an SSM arch."""
    for arch in ("olmo_1b", "mamba2_130m"):
        cfg = C.get_smoke(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompt = jax.random.randint(
            jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab
        )
        toks = greedy_decode(cfg, params, prompt, n_steps=4, max_len=16)
        assert toks.shape == (2, 4)
        assert int(toks.max()) < cfg.vocab


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs as C
    from repro.models.transformer import init_params, forward
    from repro.runtime.elastic import remesh_state
    from repro.parallel.sharding import ShardScheme

    cfg = C.get_smoke("olmo_1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab)
    ref = forward(cfg, params, toks)[0]

    # "lose a pod": 8 devices -> place on a 2x4 mesh, then degrade to 1x4
    scheme = ShardScheme(tp=True, fsdp="zero1")
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    state_a = remesh_state(cfg, params, mesh_a, scheme)
    from jax.sharding import Mesh
    mesh_b = Mesh(
        np.array(jax.devices()[:4]).reshape(1, 4), ("data", "model")
    )
    state_b = remesh_state(cfg, state_a, mesh_b, scheme)
    with mesh_b:
        out = jax.jit(lambda p, t: forward(cfg, p, t)[0])(state_b, toks)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-4, err
    print("REMESH-OK", err)
""")


def test_elastic_remesh_preserves_function():
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        timeout=600,
    )
    assert "REMESH-OK" in r.stdout, r.stdout + r.stderr
