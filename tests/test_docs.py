"""Project docs stay present and internally consistent: the CI docs
job runs the same checker, this keeps it honest under tier-1."""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

import check_docs_links as cdl  # noqa: E402


def test_required_docs_exist():
    for name in ("README.md", "EXPERIMENTS.md", "docs/ARCHITECTURE.md"):
        assert (ROOT / name).exists(), f"{name} missing"


def test_no_broken_relative_links():
    assert cdl.broken_links(ROOT) == []


def test_checker_flags_broken_link(tmp_path):
    (tmp_path / "README.md").write_text("see [gone](missing.md)")
    (tmp_path / "EXPERIMENTS.md").write_text("ok [self](README.md)")
    bad = cdl.broken_links(tmp_path)
    assert [(str(d), t) for d, t in bad] == [("README.md", "missing.md")]
    assert cdl.main(["check", str(tmp_path)]) == 1


def test_docstring_references_resolve():
    """Module docstrings that cite docs/ARCHITECTURE.md sections must
    point at sections that exist (guards against renumbering)."""
    arch = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    import re

    sections = set(re.findall(r"^## (\d+)\.", arch, re.M))
    cited = set()
    for py in (ROOT / "src").rglob("*.py"):
        cited |= set(
            re.findall(r"ARCHITECTURE\.md §(\d+)", py.read_text())
        )
    assert cited, "expected docstrings to cite ARCHITECTURE.md sections"
    assert cited <= sections, f"dangling section refs: {cited - sections}"
