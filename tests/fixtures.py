"""Shared deterministic test fixtures.

Pure-python helpers the estimator, fleet and adapt tests share instead
of hand-rolling: seeded synthetic ProfileTables, planted-gamma ledger
traces, fake clocks, telemetry feeders and an exactly log-linear
ground-truth cost law for held-out predictor recovery.  Everything
here is deterministic given its seed/arguments — no wall clock, no
real profiling.
"""

import math
import random
from types import SimpleNamespace

from repro.bnn.layers import LayerSpec
from repro.core.mapper import DEVICE, HOST
from repro.core.parallel_config import CONFIGS, CPU
from repro.core.profiler import ProfileTable
from repro.estimator.features import (
    boundary_features,
    feature_vector,
    group_key,
    layer_geometry,
    variant_meta,
)
from repro.fleet.ledger import DeviceTimeLedger


class FakeClock:
    """Injectable monotonic clock: starts at 0, advances only when the
    test says so — batcher max-waits and router deadlines become
    deterministic on loaded CI runners."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, seconds):
        self.t += seconds


# ---------------------------------------------------------------------------
# synthetic ProfileTables
# ---------------------------------------------------------------------------


def random_split_table(rng, n_layers=5, batches=(1, 4), name="synthetic"):
    """Random kernel/boundary-split table over the fixed-8 space
    (``rng`` is a ``numpy.random.Generator``)."""
    kernel, times, h2d, d2h = {}, {}, {}, {}
    for b in batches:
        kernel[b], times[b], h2d[b], d2h[b] = [], [], [], []
        for _ in range(n_layers):
            krow = {c: float(rng.uniform(1e-6, 1e-3)) for c in CONFIGS}
            up = float(rng.uniform(1e-6, 5e-4))
            down = float(rng.uniform(1e-6, 5e-4))
            times[b].append({
                c: krow[c] if c == CPU else krow[c] + up + down
                for c in CONFIGS
            })
            kernel[b].append(krow)
            h2d[b].append(up)
            d2h[b].append(down)
    return ProfileTable(
        name, tuple(batches),
        tuple(f"L{i+1}:C64" for i in range(n_layers)), times,
        kernel_times=kernel, h2d_times=h2d, d2h_times=d2h,
    )


def tied_table(name, n_layers=4, batch=4, cpu=1.0, gpu=0.9, bnd=0.005):
    """CPU and device near-tied per layer — the regime where joint
    mapping has a genuine placement choice."""
    times = {batch: [
        {c: cpu if c == CPU else gpu + 2 * bnd for c in CONFIGS}
        for _ in range(n_layers)
    ]}
    kernels = {batch: [
        {c: cpu if c == CPU else gpu for c in CONFIGS}
        for _ in range(n_layers)
    ]}
    return ProfileTable(
        name, (batch,),
        tuple(f"L{i+1}:C64" for i in range(n_layers)), times,
        kernel_times=kernels,
        h2d_times={batch: [bnd] * n_layers},
        d2h_times={batch: [bnd] * n_layers},
    )


def flat_table(model, batch=4, t=1e-4, up=1e-5, down=1e-5):
    """Uniform-cost table for a real model's specs: every config costs
    the same, so mappings are placement-driven and deterministic."""
    n = len(model.specs)
    return ProfileTable(
        model.name, (batch,),
        tuple(f"L{s.idx}:{s.notation}" for s in model.specs),
        times={batch: [
            {c: t if c == CPU else t + up + down for c in CONFIGS}
            for _ in range(n)
        ]},
        kernel_times={batch: [{c: t for c in CONFIGS} for _ in range(n)]},
        h2d_times={batch: [up] * n},
        d2h_times={batch: [down] * n},
    )


# ---------------------------------------------------------------------------
# telemetry feeding
# ---------------------------------------------------------------------------


def observe_segments(tel, ec, factors, batch=4, n=8):
    """Feed `n` steps' worth of observations into a SegmentTelemetry:
    each segment observed at its predicted time times
    ``factors.get(index, 1.0)``."""
    pred = ec.segment_expected_times()
    for _ in range(n):
        for idx, seg in enumerate(ec.segments()):
            f = factors.get(idx, 1.0)
            tel.on_segment(idx, seg, pred[idx] * f * batch, batch)
        tel.flush()                       # step boundary


# ---------------------------------------------------------------------------
# planted-gamma ledger traces
# ---------------------------------------------------------------------------

DEFAULT_OCCUPANCIES = {
    "t0": (0.6, 0.9),
    "t1": (0.25, 0.55),
    "t2": (0.9, 0.15),
}


def planted_gamma_ledger(
    gamma,
    occupancies=DEFAULT_OCCUPANCIES,
    *,
    steps=6,
    noise=0.0,
    seed=0,
):
    """A :class:`DeviceTimeLedger` whose step rows embody a **known**
    linear interference law, plus the solo step expectations that
    decode it.

    Each tenant's per-step measured (host_s, device_s) occupancy is
    its `occupancies` entry, jittered by a per-(tenant, step)
    multiplicative factor in ``[1-noise, 1+noise]`` applied *jointly*
    to both processors — so every tenant's normalized shares (and
    therefore every co-runner share) stay exact under noise.  The
    returned ``expected`` maps tenant -> solo (host_s, device_s) such
    that ``measured / expected == 1 + gamma * co_runner_share``
    exactly at ``noise=0``:
    ``InterferenceFit.from_ledger(ledger, expected).fit()`` must
    recover `gamma`.
    """
    rng = random.Random(seed)
    ledger = DeviceTimeLedger(window=steps + 2)
    shares = {
        t: (h / (h + d), d / (h + d))
        for t, (h, d) in occupancies.items()
    }
    co = {
        t: (
            sum(s[0] for u, s in shares.items() if u != t),
            sum(s[1] for u, s in shares.items() if u != t),
        )
        for t in occupancies
    }
    expected = {
        t: (
            h / (1.0 + gamma * co[t][0]),
            d / (1.0 + gamma * co[t][1]),
        )
        for t, (h, d) in occupancies.items()
    }
    for _ in range(steps):
        for t, (h, d) in occupancies.items():
            jit = 1.0 + (rng.uniform(-noise, noise) if noise else 0.0)
            ledger.record(t, HOST, h * jit)
            ledger.record(t, DEVICE, d * jit)
            ledger.close_step(t)
    return ledger, expected


# ---------------------------------------------------------------------------
# exactly log-linear ground-truth cost law (predictor recovery)
# ---------------------------------------------------------------------------

# one weight vector per estimator regression group / boundary
# direction — the truth lies exactly in the predictor's hypothesis
# class, so held-out error measures the fit, not model mismatch
TRUTH_WEIGHTS = {
    "gemm/host/host": (
        -13.0, -0.25, 0.55, 0.65, 0.45, 0.0, 0.0, 0.0, 0.0, 0.0
    ),
    "gemm/device/tiled": (
        -14.0, -0.35, 0.5, 0.6, 0.4, 0.0, 0.0, -0.05, -0.1, -0.15
    ),
    "ew/host/host": (-16.0, -0.2, 0.8),
    "ew/device/tiled": (-16.5, -0.25, 0.75),
    "h2d": (-14.0, -0.1, 0.6),
    "d2h": (-14.5, -0.1, 0.6),
}


def truth_kernel_s(geometry, meta, weights=TRUTH_WEIGHTS):
    x = feature_vector(geometry, meta)
    w = weights[group_key(geometry, meta)]
    return math.exp(sum(a * b for a, b in zip(x, w)))


def truth_boundary_s(geometry, direction, weights=TRUTH_WEIGHTS):
    x = boundary_features(geometry, direction)
    return math.exp(sum(a * b for a, b in zip(x, weights[direction])))


def synthetic_model(name, conv_units=(32, 64), fc_units=(128, 10), hw=12):
    """A spec-only model (no jax, no parameters): conv layers at
    `hw` x `hw` spatial size, then fc layers — enough structure to
    exercise both estimator geometry classes."""
    specs = []
    idx = 1
    cin = 32
    for u in conv_units:
        specs.append(LayerSpec(
            idx, "conv", f"C{u}", (hw, hw, cin), (hw, hw, u), units=u
        ))
        idx += 1
        specs.append(LayerSpec(
            idx, "step", "S", (hw, hw, u), (hw, hw, u), units=u
        ))
        idx += 1
        cin = u
    feat = hw * hw * cin
    specs.append(LayerSpec(
        idx, "flat", "FLAT", (hw, hw, cin), (feat,)
    ))
    idx += 1
    din = feat
    for u in fc_units:
        specs.append(LayerSpec(
            idx, "fc", f"FC{u}", (din,), (u,), units=u
        ))
        idx += 1
        din = u
    return SimpleNamespace(name=name, specs=tuple(specs))


def loglinear_table(model, batches=(1, 4), weights=TRUTH_WEIGHTS):
    """A ProfileTable for `model` priced exactly by the log-linear
    ground truth — what a profiler on a perfectly power-law platform
    would measure."""
    labels = tuple(f"L{s.idx}:{s.notation}" for s in model.specs)
    times, kernels, h2d, d2h = {}, {}, {}, {}
    for b in batches:
        times[b], kernels[b], h2d[b], d2h[b] = [], [], [], []
        for spec in model.specs:
            geom = layer_geometry(spec, b)
            up = truth_boundary_s(geom, "h2d", weights)
            down = truth_boundary_s(geom, "d2h", weights)
            krow, trow = {}, {}
            for cfg in CONFIGS:
                meta = variant_meta(cfg)
                k = truth_kernel_s(geom, meta, weights)
                krow[cfg] = k
                trow[cfg] = k if cfg == CPU else k + up + down
            kernels[b].append(krow)
            times[b].append(trow)
            h2d[b].append(up)
            d2h[b].append(down)
    return ProfileTable(
        model.name, tuple(batches), labels, times,
        kernel_times=kernels, h2d_times=h2d, d2h_times=d2h,
        provenance="analytic",
    )
