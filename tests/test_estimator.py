"""Learned latency estimator + calibrated interference law: feature
extraction, per-group log-linear prediction with held-out recovery on
an exactly log-linear ground truth, planted-gamma recovery from ledger
traces, the fitted-law contract (property-tested), law threading
through the cost model and joint mapper, and the ProfileStore
training-row loop."""

import json
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax

from repro.bnn import build_model
from repro.bnn.models import pack_params
from repro.core.cost_model import contention_inflation
from repro.core.mapper import map_efficient_configuration
from repro.core.parallel_config import CONFIGS, CPU, FULL_GPU
from repro.core.profiler import ProfileTable, profile_bnn_model
from repro.estimator import (
    TRAINING_ROW_SCHEMA,
    FittedInterference,
    InterferenceFit,
    InterferenceObservation,
    LatencyPredictor,
    boundary_features,
    feature_vector,
    fit_gamma,
    group_key,
    layer_geometry,
    training_rows_from_table,
    variant_meta,
)
from repro.fleet import (
    all_device_configuration,
    joint_makespan,
    map_fleet,
    tenant_inflations,
)
from repro.store import ProfileStore

from fixtures import (
    loglinear_table,
    planted_gamma_ledger,
    random_split_table,
    synthetic_model,
    tied_table,
    truth_boundary_s,
    truth_kernel_s,
)


# ---------------------------------------------------------------------------
# features
# ---------------------------------------------------------------------------


def test_layer_geometry_classes():
    m = synthetic_model("g")
    conv = layer_geometry(m.specs[0], 4)
    assert conv["cls"] == "gemm"
    assert conv["b"] > 0 and conv["p"] > 0 and conv["n"] > 0
    assert conv["in_bytes"] > 0 and conv["out_bytes"] > 0
    step = layer_geometry(m.specs[1], 4)
    assert step["cls"] == "ew"
    expect = 4
    for d in m.specs[1].in_shape:
        expect *= d
    assert step["elems"] == expect
    fc = layer_geometry(m.specs[-1], 2)
    assert fc["cls"] == "gemm" and fc["b"] == 2


def test_variant_meta_placement_analytic_and_aspects():
    cpu = variant_meta(CPU)
    assert cpu["placement"] == "host" and cpu["analytic"] == "host"
    assert cpu["aspects"] == "-"
    gpu = variant_meta(FULL_GPU)
    assert gpu["placement"] == "device"
    assert set("XYZ") <= set(gpu["aspects"])
    with pytest.raises((KeyError, ValueError)):
        variant_meta("NOPE")


def test_group_key_and_feature_dimensions():
    m = synthetic_model("g")
    geom = layer_geometry(m.specs[0], 4)
    meta = variant_meta(FULL_GPU)
    assert group_key(geom, meta) == "gemm/device/" + meta["analytic"]
    assert len(feature_vector(geom, meta)) == 10
    ew = layer_geometry(m.specs[1], 4)
    assert len(feature_vector(ew, variant_meta(CPU))) == 3
    assert len(boundary_features(geom, "h2d")) == 3
    # h2d keys on operand bytes, d2h on result bytes
    assert boundary_features(geom, "h2d") != boundary_features(geom, "d2h")


def test_training_rows_from_table_extracts_every_measurement():
    m = synthetic_model("t")
    table = loglinear_table(m, batches=(1, 4))
    rows = training_rows_from_table(m, table)
    assert len(rows) == 2 * len(m.specs) * len(CONFIGS)
    r = rows[0]
    assert r["schema"] == TRAINING_ROW_SCHEMA
    assert r["model"] == "t"
    assert r["kernel_s"] == table.kernel_time(
        r["batch"], r["layer"], r["config"]
    )
    assert json.loads(json.dumps(rows)) == rows      # JSON-able
    # spec/label mismatch (unknown model) extracts nothing, not garbage
    other = synthetic_model("other", conv_units=(16,))
    assert training_rows_from_table(other, table) == []


# ---------------------------------------------------------------------------
# latency predictor
# ---------------------------------------------------------------------------


def _trained_predictor(batches=(1, 2, 4, 8)):
    rows = []
    for name, conv_units, fc_units in (
        ("train_a", (32, 64), (128, 10)),
        ("train_b", (48,), (256, 64, 10)),
        ("train_c", (16, 32, 64), (32, 10)),
    ):
        m = synthetic_model(name, conv_units=conv_units, fc_units=fc_units)
        rows += training_rows_from_table(m, loglinear_table(m, batches))
    return LatencyPredictor().fit(rows)


def test_predictor_recovers_loglinear_truth_on_held_out_model():
    """The acceptance bound: trained on three models priced by an
    exactly log-linear cost law, the predictor prices an unseen
    model's every (layer, config, batch) within a tight relative
    error — the truth is in the hypothesis class, so residual error
    is numerics, not model mismatch."""
    pred = _trained_predictor()
    held = synthetic_model("held_out", conv_units=(24, 40), fc_units=(96, 10))
    errs = []
    for b in (1, 3, 4):                   # 3 is unseen in training
        for spec in held.specs:
            geom = layer_geometry(spec, b)
            for cfg in CONFIGS:
                meta = variant_meta(cfg)
                truth = truth_kernel_s(geom, meta)
                got = pred.predict_kernel_s(geom, meta)
                errs.append(abs(got - truth) / truth)
            for direction in ("h2d", "d2h"):
                truth = truth_boundary_s(geom, direction)
                got = pred.predict_boundary_s(geom, direction)
                errs.append(abs(got - truth) / truth)
    assert max(errs) < 0.05
    cov = pred.coverage()
    assert cov["gemm/host/host"] > 0 and any(
        k.startswith("gemm/device/") for k in cov
    )


def test_predict_table_follows_profiler_semantics():
    pred = _trained_predictor()
    held = synthetic_model("held", conv_units=(24,), fc_units=(64, 10))
    table = pred.predict_table(held, (1, 4))
    assert table.provenance == "predicted"
    assert table.model_name == "held"
    assert table.batch_sizes == (1, 4)
    assert len(table.layer_labels) == len(held.specs)
    for b in (1, 4):
        for i in range(len(held.specs)):
            for c in table.configs_for(b, i):
                total = table.times[b][i][c]
                k = table.kernel_time(b, i, c)
                assert 0.0 < total < 1e6 and math.isfinite(total)
                if c == CPU:
                    assert total == k          # host rows: kernel only
                else:
                    assert total == pytest.approx(
                        k + table.h2d(b, i) + table.d2h(b, i)
                    )
    # the predicted table seeds the DP like any measured one
    ec = map_efficient_configuration(table, policy="dp")
    assert len(ec.layer_configs) == len(held.specs)
    assert all(c in CONFIGS for c in ec.layer_configs)
    assert ec.expected_time_per_example > 0.0


def test_predict_table_with_registry_prices_open_variant_space():
    # a registry widens each gemm layer's candidate row to the same
    # space autotune_bnn_model sweeps; variants unseen in training are
    # priced through the fallback chain, never crash
    from repro.kernels.registry import VariantRegistry, _register_defaults

    reg = _register_defaults(VariantRegistry())
    pred = _trained_predictor()
    held = synthetic_model("held_reg", conv_units=(24,), fc_units=(64, 10))
    table = pred.predict_table(held, (4,), registry=reg)
    assert table.provenance == "predicted"
    saw_variant = False
    for i, spec in enumerate(held.specs):
        cfgs = set(table.configs_for(4, i))
        assert set(CONFIGS) <= cfgs
        geom = layer_geometry(spec, 4)
        if geom["cls"] == "gemm":
            assert "xla_fused" in cfgs
            saw_variant = True
        else:
            assert cfgs == set(CONFIGS)  # ew layers stay fixed-8
        for c in cfgs:
            t = table.times[4][i][c]
            assert 0.0 < t < 1e6 and math.isfinite(t)
    assert saw_variant
    # the widened table seeds the DP, which may now pick registry
    # variants — exactly what autotune_bnn_model does on measured data
    ec = map_efficient_configuration(table, policy="dp")
    assert all(
        c in table.configs_for(4, i)
        for i, c in enumerate(ec.layer_configs)
    )


def test_predictor_fallback_chain_and_clamps():
    # untrained: global default, never a crash
    cold = LatencyPredictor()
    m = synthetic_model("m")
    geom = layer_geometry(m.specs[0], 4)
    meta = variant_meta(FULL_GPU)
    assert cold.predict_kernel_s(geom, meta) == pytest.approx(1e-4)
    assert cold.predict_boundary_s(geom, "h2d") == 0.0
    # trained on gemm rows only: an ew layer falls through to the
    # global median instead of failing
    rows = [
        r for r in training_rows_from_table(m, loglinear_table(m))
        if r["geometry"]["cls"] == "gemm"
    ]
    p = LatencyPredictor().fit(rows)
    ew = layer_geometry(m.specs[1], 4)
    got = p.predict_kernel_s(ew, variant_meta(CPU))
    assert 0.0 < got < 1e6 and math.isfinite(got)
    # rows with garbage targets are dropped, not fitted
    junk = [dict(rows[0], kernel_s=0.0), dict(rows[0], kernel_s=-1.0)]
    assert LatencyPredictor().fit(junk).n_rows == 0


def test_predictor_json_roundtrip_preserves_predictions():
    pred = _trained_predictor()
    back = LatencyPredictor.from_json(pred.to_json())
    m = synthetic_model("rt", conv_units=(20,), fc_units=(40, 10))
    for spec in m.specs:
        geom = layer_geometry(spec, 4)
        for cfg in CONFIGS:
            meta = variant_meta(cfg)
            assert back.predict_kernel_s(geom, meta) == pytest.approx(
                pred.predict_kernel_s(geom, meta)
            )
    assert back.coverage() == pred.coverage()
    assert back.n_rows == pred.n_rows


def test_predictor_validates():
    with pytest.raises(ValueError):
        LatencyPredictor(ridge=0.0)
    with pytest.raises(ValueError):
        LatencyPredictor(min_rows=0)
    doc = json.loads(LatencyPredictor().to_json())
    doc["kind"] = "profile_table"
    with pytest.raises(ValueError, match="latency_predictor"):
        LatencyPredictor.from_json(json.dumps(doc))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), fitted=st.booleans())
def test_predicted_tables_never_crash_the_dp(seed, fitted):
    """The prediction contract: whatever the training set (including
    none at all) and whatever the model, the predicted table yields a
    valid DP mapping — finite positive times, one config per layer."""
    rng = np.random.default_rng(seed)
    if fitted:
        m_train = synthetic_model(
            "tr",
            conv_units=tuple(
                int(u) for u in rng.integers(8, 64, rng.integers(1, 3))
            ),
            fc_units=(int(rng.integers(16, 256)), 10),
        )
        rows = training_rows_from_table(m_train, loglinear_table(m_train))
        pred = LatencyPredictor().fit(rng.permutation(rows).tolist())
    else:
        pred = LatencyPredictor()
    model = synthetic_model(
        "probe",
        conv_units=tuple(
            int(u) for u in rng.integers(8, 96, rng.integers(1, 4))
        ),
        fc_units=(int(rng.integers(16, 512)), 10),
        hw=int(rng.integers(4, 20)),
    )
    batch = int(rng.choice((1, 2, 4, 8)))
    table = pred.predict_table(model, (batch,))
    ec = map_efficient_configuration(table, policy="dp")
    assert len(ec.layer_configs) == len(model.specs)
    assert math.isfinite(ec.expected_time_per_example)
    assert ec.expected_time_per_example > 0.0


# ---------------------------------------------------------------------------
# interference fit
# ---------------------------------------------------------------------------


def test_fit_gamma_exact_on_noiseless_linear_data():
    g = 0.7
    obs = [
        InterferenceObservation(share=s, inflation=1.0 + g * s)
        for s in (0.1, 0.4, 0.8, 1.3)
    ]
    assert fit_gamma(obs) == pytest.approx(g)
    assert fit_gamma([]) == 0.0
    assert fit_gamma(
        [InterferenceObservation(share=0.0, inflation=5.0)]
    ) == 0.0                                   # zero-share: no signal
    assert fit_gamma(
        [InterferenceObservation(share=1.0, inflation=0.5)]
    ) == 0.0                                   # speedup clamps to 0


def test_fitted_law_linear_and_piecewise_contract():
    lin = FittedInterference(gamma=0.5)
    assert lin.inflation(0.0) == 1.0
    assert lin.inflation(2.0) == pytest.approx(2.0)
    pw = FittedInterference(
        gamma=1.0, knots=((0.5, 1.2), (1.0, 1.8))
    )
    assert pw.inflation(0.0) == 1.0
    assert pw.inflation(0.25) == pytest.approx(1.1)    # interp to knot 1
    assert pw.inflation(0.75) == pytest.approx(1.5)    # between knots
    assert pw.inflation(1.0) == pytest.approx(1.8)
    # past the last knot: linear extrapolation at slope gamma
    assert pw.inflation(1.5) == pytest.approx(1.8 + 0.5)
    assert pw.inflation(-1.0) == 1.0                   # clamped domain
    with pytest.raises(ValueError):
        FittedInterference(gamma=-0.1)


def test_interference_fit_drops_measurement_garbage():
    fit = InterferenceFit()
    fit.observe(-0.1, 1.5)        # negative share
    fit.observe(0.5, 0.0)         # non-positive inflation
    fit.observe(0.5, -2.0)
    assert len(fit) == 0
    fit.observe(0.5, 1.4, placement="host", tenant="a")
    assert len(fit) == 1
    assert fit.observations()[0].tenant == "a"


def test_planted_gamma_recovered_from_ledger_within_tolerance():
    """The acceptance criterion: on ledger traces with a planted
    linear law, the fitted gamma lands within 10% relative error —
    exactly at zero noise, comfortably inside the bound at 15%
    multiplicative jitter."""
    for gamma in (0.35, 1.0, 2.5):
        ledger, expected = planted_gamma_ledger(gamma)
        fit = InterferenceFit.from_ledger(ledger, expected)
        assert len(fit) > 0
        law = fit.fit(refine=False)
        assert law.gamma == pytest.approx(gamma, rel=1e-6)
        assert law.residual == pytest.approx(0.0, abs=1e-9)
    ledger, expected = planted_gamma_ledger(
        1.0, steps=32, noise=0.15, seed=7
    )
    law = InterferenceFit.from_ledger(ledger, expected).fit()
    assert abs(law.gamma - 1.0) / 1.0 < 0.10
    assert law.n_obs > 0


def test_refined_law_tracks_a_nonlinear_planted_curve():
    """Observations from a saturating (concave) law: the piecewise
    refinement prices mid-range shares better than the pure linear
    fit, while keeping the monotone contract."""
    fit = InterferenceFit()
    shares = [0.05 * i for i in range(1, 41)]
    for s in shares:
        fit.observe(s, 1.0 + math.sqrt(s))     # concave ground truth
    law = fit.fit(max_knots=6, min_per_knot=4)
    assert law.knots                            # refinement engaged
    lin = fit.fit(refine=False)
    err_pw = max(
        abs(law.inflation(s) - (1.0 + math.sqrt(s))) for s in shares
    )
    err_lin = max(
        abs(lin.inflation(s) - (1.0 + math.sqrt(s))) for s in shares
    )
    assert err_pw < err_lin
    xs = [0.01 * i for i in range(301)]
    ys = [law.inflation(x) for x in xs]
    assert ys == sorted(ys)                     # still monotone


def test_interference_law_json_roundtrip():
    law = FittedInterference(
        gamma=0.8, knots=((0.4, 1.3), (0.9, 1.7)), n_obs=12,
        residual=0.05,
    )
    back = FittedInterference.from_json(law.to_json())
    assert back == law
    with pytest.raises(ValueError, match="interference_law"):
        FittedInterference.from_json(
            json.dumps({"kind": "profile_table", "gamma": 1.0})
        )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), refine=st.booleans())
def test_fitted_law_contract_holds_for_any_observations(seed, refine):
    """The property every consumer assumes: whatever garbage-free
    observation set is fitted — including adversarially non-monotone
    samples — the law is pinned at (0, 1), never below 1, and monotone
    non-decreasing in the share."""
    rng = np.random.default_rng(seed)
    fit = InterferenceFit()
    for _ in range(int(rng.integers(0, 60))):
        fit.observe(
            float(rng.uniform(0.0, 3.0)),
            float(rng.uniform(0.2, 6.0)),   # includes speedups < 1
        )
    law = fit.fit(refine=refine)
    assert law.inflation(0.0) == 1.0
    xs = [0.02 * i for i in range(201)]
    ys = [law.inflation(x) for x in xs]
    assert all(y >= 1.0 for y in ys)
    assert all(b >= a - 1e-12 for a, b in zip(ys, ys[1:]))


# ---------------------------------------------------------------------------
# law threading: cost model, joint mapper
# ---------------------------------------------------------------------------


def test_contention_inflation_prefers_fitted_law():
    law = FittedInterference(gamma=0.5)
    # the law overrides gamma entirely (gamma is not even validated)
    assert contention_inflation(1.0, gamma=99.0, law=law) == 1.5
    assert contention_inflation(-1.0, law=law) == 1.0   # clamped share
    pw = FittedInterference(gamma=0.0, knots=((1.0, 3.0), (2.0, 3.0)))
    assert contention_inflation(0.5, law=pw) == pytest.approx(2.0)


def test_tenant_inflations_with_fitted_law():
    shares = [(0.25, 0.75), (1.0, 0.0)]
    law = FittedInterference(gamma=2.0)
    host_f, dev_f = tenant_inflations(shares, 0, law=law)
    assert host_f == pytest.approx(3.0)     # 1 + 2*1.0
    assert dev_f == pytest.approx(1.0)      # 1 + 2*0.0
    # law= with the matching gamma agrees with the plain-gamma path
    lin = FittedInterference(gamma=1.0)
    assert tenant_inflations(shares, 1, law=lin) == pytest.approx(
        tenant_inflations(shares, 1, gamma=1.0)
    )


def test_map_fleet_threads_the_fitted_law():
    tables = [tied_table("a"), tied_table("b")]
    law = FittedInterference(gamma=1.0, knots=((0.5, 1.6), (1.0, 2.0)))
    plan = map_fleet(tables, law=law)
    assert all(t.law is law for t in plan.tenants)
    assert plan.joint_makespan_s == pytest.approx(
        joint_makespan(tables, plan.configs, law=law)
    )
    # identity law == no contention: degenerates to the solo DP
    free = map_fleet(tables, law=FittedInterference(gamma=0.0))
    for t in free.tenants:
        assert t.host_inflation == 1.0 and t.device_inflation == 1.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_map_fleet_never_worse_than_all_gpu_under_fitted_law(seed):
    """The PR-5 acceptance property survives the law swap: for any
    pair of tables and any law fitted from random observations, the
    joint plan's makespan under that law is <= the all-GPU fleet's."""
    rng = np.random.default_rng(seed)
    tables = [
        random_split_table(rng, name="a"),
        random_split_table(rng, name="b"),
    ]
    fit = InterferenceFit()
    for _ in range(int(rng.integers(4, 40))):
        fit.observe(
            float(rng.uniform(0.0, 2.0)), float(rng.uniform(0.5, 4.0))
        )
    law = fit.fit()
    plan = map_fleet(tables, law=law)
    all_gpu = [all_device_configuration(t) for t in tables]
    baseline = joint_makespan(tables, all_gpu, law=law)
    assert plan.baseline_makespan_s == pytest.approx(baseline)
    assert plan.joint_makespan_s <= baseline + 1e-12
    assert plan.joint_makespan_s == pytest.approx(
        joint_makespan(tables, plan.configs, law=law)
    )


# ---------------------------------------------------------------------------
# store integration: the training-row loop
# ---------------------------------------------------------------------------


def test_store_training_rows_roundtrip(tmp_path):
    store = ProfileStore(tmp_path, fingerprint="fp")
    assert store.load_training_rows() == []
    assert store.predictor() is None
    m = synthetic_model("s")
    rows = training_rows_from_table(m, loglinear_table(m))
    store.save_training_rows(rows)
    assert store.load_training_rows() == rows
    # a second batch from another sweep accumulates, not overwrites
    m2 = synthetic_model("s2", conv_units=(16,))
    rows2 = training_rows_from_table(m2, loglinear_table(m2))
    store.save_training_rows(rows2)
    assert len(store.load_training_rows()) == len(rows) + len(rows2)
    # re-saving the same source overwrites in place
    store.save_training_rows(rows)
    assert len(store.load_training_rows()) == len(rows) + len(rows2)
    with pytest.raises(ValueError):
        store.save_training_rows([])
    # rows are keyed: a different fingerprint sees nothing
    other = ProfileStore(tmp_path, fingerprint="other")
    assert other.load_training_rows() == []


def test_store_get_or_profile_feeds_the_predictor(tmp_path):
    """The closing of the loop: every real profile run records
    training rows, and ``store.predictor()`` fits on them."""
    store = ProfileStore(tmp_path, fingerprint="fp")
    m = synthetic_model("fed", conv_units=(24, 48), fc_units=(64, 10))
    calls = []

    def fake_profiler(model, packed, *, batch_sizes):
        calls.append(model.name)
        return loglinear_table(model, batch_sizes)

    table, loaded = store.get_or_profile(
        m, None, fake_profiler, batch_sizes=(1, 4)
    )
    assert not loaded and calls == ["fed"]
    rows = store.load_training_rows()
    assert len(rows) == 2 * len(m.specs) * len(CONFIGS)
    pred = store.predictor()
    assert pred is not None and pred.n_rows == len(rows)
    # the fitted predictor prices the profiled model close to truth
    geom = layer_geometry(m.specs[0], 4)
    meta = variant_meta(FULL_GPU)
    assert pred.predict_kernel_s(geom, meta) == pytest.approx(
        truth_kernel_s(geom, meta), rel=0.05
    )
    # warm start: the stored table is served with zero profiling and
    # no duplicate training rows
    _, loaded = store.get_or_profile(
        m, None, fake_profiler, batch_sizes=(1, 4)
    )
    assert loaded and calls == ["fed"]
    assert len(store.load_training_rows()) == len(rows)


# ---------------------------------------------------------------------------
# table provenance
# ---------------------------------------------------------------------------


def test_profile_table_provenance_roundtrip_and_legacy():
    m = synthetic_model("prov")
    t = loglinear_table(m)
    assert t.provenance == "analytic"
    back = ProfileTable.from_json(t.to_json())
    assert back.provenance == "analytic"
    legacy = json.loads(t.to_json())
    del legacy["provenance"]
    assert ProfileTable.from_json(json.dumps(legacy)).provenance is None


def test_profiler_stamps_provenance():
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = profile_bnn_model(
        m, packed, batch_sizes=(1,), repeats=1, time_source="analytic"
    )
    assert table.provenance == "analytic"
