"""Adaptive runtime: telemetry sampling/statistics, drift-detector
hysteresis, profile folding, remap atomicity (bit-exactness across hot
swaps, swaps never landing mid-wave), the idle force-flush regression,
and the registry-wired BNN mapping hillclimb."""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax

from repro.adapt import (
    DriftDetector,
    RemapController,
    SegmentTelemetry,
    fold_observed,
)
from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.core.mapper import (
    configuration_from_mapping,
    map_efficient_configuration,
)
from repro.core.parallel_config import CONFIGS, CPU
from repro.core.profiler import ProfileTable
from repro.launch.hillclimb import bnn_mapping_hillclimb
from repro.serving import ServingEngine, canonical_mixed_mapping

from fixtures import FakeClock, flat_table, observe_segments


@pytest.fixture(scope="module")
def small():
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = flat_table(m)
    ec = configuration_from_mapping(table, 4, canonical_mixed_mapping(m))
    return m, packed, table, ec


def _inputs(m, n, batch=4, seed0=0):
    return [
        np.asarray(prepare_input_packed(
            jax.random.uniform(
                jax.random.PRNGKey(seed0 + i), (batch, 28, 28, 1)
            )
        ))
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


class _Seg:
    placement = "host"


def test_telemetry_sampling_cadence_and_warmup():
    tel = SegmentTelemetry(sample_every=2, warmup=1)
    # step 1 is warmup, then every 2nd step is sampled
    got = [tel.sample() is not None for _ in range(6)]
    assert got == [False, True, False, True, False, True]
    tel.reset()
    assert tel.sample() is None          # warmup again after reset


def test_telemetry_disabled_is_never_sampled():
    assert SegmentTelemetry(enabled=False).sample() is None
    assert SegmentTelemetry(sample_every=0).sample() is None


def test_telemetry_stats_per_example_normalization():
    tel = SegmentTelemetry(alpha=0.5, warmup=0)
    tel.on_segment(0, _Seg(), 8.0, 4)     # 2 s/example
    tel.flush()                           # step boundary
    tel.on_segment(0, _Seg(), 4.0, 4)     # 1 s/example
    s = tel.observed(0)
    assert s.count == 2
    assert s.ewma == pytest.approx(1.5)   # 2 -> 0.5*2 + 0.5*1
    assert s.recent_median(2) == pytest.approx(1.5)
    assert s.quantile(0.0) == 1.0 and s.quantile(1.0) == 2.0
    snap = tel.snapshot()
    assert snap[0]["count"] == 2 and snap[0]["placement"] == "host"
    tel.reset()
    assert tel.observed(0) is None


def test_telemetry_recent_median_ignores_single_outlier():
    tel = SegmentTelemetry(warmup=0)
    for v in (1.0, 1.0, 100.0):
        tel.on_segment(0, _Seg(), v, 1)
        tel.flush()
    assert tel.observed(0).recent_median(3) == 1.0


def test_telemetry_recent_floor_survives_outlier_runs():
    """The floor holds the true cost through any run of fewer than k
    slow steps — and tracks a genuine regime change once every recent
    step sits at the new level."""
    tel = SegmentTelemetry(warmup=0)
    for v in (1.0, 50.0, 80.0):          # 2-of-3 slow: still 1.0
        tel.on_segment(0, _Seg(), v, 1)
        tel.flush()
    assert tel.observed(0).recent_floor(3) == 1.0
    for v in (40.0, 50.0, 60.0):         # sustained: floor moves
        tel.on_segment(0, _Seg(), v, 1)
        tel.flush()
    assert tel.observed(0).recent_floor(3) == 40.0


def test_telemetry_aggregates_one_sample_per_step_and_segment():
    """One engine step may drain many micro-batches; they must fold
    into a single window sample (the step's best) so a single stalled
    wave-train can never fill the hysteresis window."""
    tel = SegmentTelemetry(warmup=0)
    for v in (9.0, 3.0, 7.0):            # three micro-batches, one step
        tel.on_segment(0, _Seg(), v, 1)
    s = tel.observed(0)                  # read flushes the step
    assert s.count == 1 and s.window[0] == 3.0


def test_telemetry_validates():
    with pytest.raises(ValueError):
        SegmentTelemetry(alpha=0.0)
    with pytest.raises(ValueError):
        SegmentTelemetry(window=0)
    with pytest.raises(ValueError):
        SegmentTelemetry(sample_every=-1)
    with pytest.raises(ValueError):
        SegmentTelemetry(warmup=-1)


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------


# _observe: the shared telemetry feeder (predicted * factor per
# segment, n steps) now lives in tests/fixtures.py
_observe = observe_segments


def test_no_drift_when_observed_matches_predicted(small):
    _, _, _, ec = small
    tel = SegmentTelemetry(warmup=0)
    _observe(tel, ec, {})
    assert DriftDetector(min_samples=3).check(ec, tel) == ()


def test_slow_batches_never_trigger_until_sustained(small):
    """The hysteresis contract: any run of fewer than min_samples slow
    batches — however extreme — cannot clear the recent-floor gate."""
    _, _, _, ec = small
    tel = SegmentTelemetry(warmup=0)
    _observe(tel, ec, {}, n=6)
    pred = ec.segment_expected_times()
    det = DriftDetector(min_samples=3)
    for _ in range(2):                    # two consecutive slow batches
        for idx, seg in enumerate(ec.segments()):
            tel.on_segment(idx, seg, pred[idx] * 1000 * 4, 4)
        assert det.check(ec, tel) == ()
    # the third consecutive slow batch makes it sustained
    for idx, seg in enumerate(ec.segments()):
        tel.on_segment(idx, seg, pred[idx] * 1000 * 4, 4)
    assert det.check(ec, tel) != ()


def test_sustained_drift_is_reported_with_evidence(small):
    _, _, _, ec = small
    tel = SegmentTelemetry(warmup=0)
    _observe(tel, ec, {0: 5.0, 1: 5.0})
    det = DriftDetector(rel_threshold=0.5, min_samples=3)
    reports = det.check(ec, tel)
    assert {r.segment_index for r in reports} == {0, 1}
    for r in reports:
        assert r.ratio == pytest.approx(5.0, rel=1e-6)
        assert r.samples == 8
        assert r.placement == ec.segments()[r.segment_index].placement


def test_drift_needs_min_samples(small):
    _, _, _, ec = small
    tel = SegmentTelemetry(warmup=0)
    _observe(tel, ec, {0: 5.0}, n=2)
    assert DriftDetector(min_samples=3).check(ec, tel) == ()
    _observe(tel, ec, {0: 5.0}, n=1)
    assert DriftDetector(min_samples=3).check(ec, tel) != ()


def test_drift_direction_and_threshold(small):
    _, _, _, ec = small
    tel = SegmentTelemetry(warmup=0)
    _observe(tel, ec, {0: 0.1})           # much faster than predicted
    assert DriftDetector(min_samples=3).check(ec, tel) == ()
    both = DriftDetector(min_samples=3, direction="both").check(ec, tel)
    assert [r.segment_index for r in both] == [0]
    # within threshold: quiet in both directions
    tel2 = SegmentTelemetry(warmup=0)
    _observe(tel2, ec, {0: 1.3})
    assert DriftDetector(
        min_samples=3, rel_threshold=0.5, direction="both"
    ).check(ec, tel2) == ()


def test_drift_min_share_keys_on_observed_too(small):
    """A segment priced as negligible but observed as expensive is the
    contention case — the share gate must not filter it."""
    _, _, _, ec = small
    tel = SegmentTelemetry(warmup=0)
    _observe(tel, ec, {0: 1000.0})
    det = DriftDetector(min_samples=3, min_share=0.5)
    assert [r.segment_index for r in det.check(ec, tel)] == [0]


def test_drift_gates_on_retained_window_not_lifetime_count(small):
    """A telemetry window shorter than min_samples can never prove a
    sustained deviation — the lifetime count must not stand in for
    samples actually retained."""
    _, _, _, ec = small
    tel = SegmentTelemetry(warmup=0, window=2)
    _observe(tel, ec, {0: 50.0}, n=20)    # count=20, retained=2
    assert DriftDetector(min_samples=3).check(ec, tel) == ()


def test_drift_detector_validates():
    with pytest.raises(ValueError):
        DriftDetector(rel_threshold=0.0)
    with pytest.raises(ValueError):
        DriftDetector(min_samples=0)
    with pytest.raises(ValueError):
        DriftDetector(direction="sideways")


# ---------------------------------------------------------------------------
# profile folding
# ---------------------------------------------------------------------------


def test_fold_observed_changes_only_drifted_layers_same_placement(small):
    _, _, table, ec = small
    tel = SegmentTelemetry(warmup=0)
    _observe(tel, ec, {0: 3.0})
    reports = DriftDetector(min_samples=3).check(ec, tel)
    assert len(reports) == 1
    seg = ec.segments()[0]
    corrected = fold_observed(table, ec, reports)
    drifted_host = not seg.on_device
    for b in table.batch_sizes:
        for i in range(len(table.layer_labels)):
            for c in table.configs_for(b, i):
                old = table.kernel_time(b, i, c)
                new = corrected.kernel_time(b, i, c)
                in_seg = seg.start <= i < seg.stop
                same_place = (c == CPU) == drifted_host
                if in_seg and same_place:
                    assert new == pytest.approx(old * reports[0].ratio)
                else:
                    assert new == old
                # totals rebuilt as kernel + unchanged boundary
                assert corrected.times[b][i][c] == pytest.approx(
                    new + corrected.boundary_time(b, i, c)
                )
    assert corrected.h2d_times == table.h2d_times
    assert corrected.d2h_times == table.d2h_times


def test_fold_observed_noop_without_reports(small):
    _, _, table, ec = small
    assert fold_observed(table, ec, ()) is table


# ---------------------------------------------------------------------------
# engine hot swap: atomicity + the idle force-flush regression
# ---------------------------------------------------------------------------


def test_force_flush_on_idle_engine_is_noop(small):
    """Regression: step(force=True) with an empty queue must be a
    no-op — no zero batch is padded and run, nothing errors, and
    telemetry records nothing."""
    m, packed, table, ec = small
    tel = SegmentTelemetry(warmup=0)
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(), telemetry=tel,
    )
    for _ in range(3):
        assert engine.step(force=True) == 0
    assert engine.served == 0 and engine.steps == 0
    assert tel.stats() == {}
    # and a pending swap still applies at the idle boundary
    ec2 = configuration_from_mapping(table, 4, (CPU,) * len(m.specs))
    engine._pending_swap = ec2
    assert engine.step(force=True) == 0
    assert engine.config is ec2 and engine.swaps == 1


def test_swap_between_steps_applies_immediately(small):
    m, packed, table, ec = small
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(),
    )
    old_pipe = engine.pipeline
    ec2 = configuration_from_mapping(table, 4, ("XYZ",) * len(m.specs))
    assert engine.swap_configuration(ec2) is True
    assert engine.config is ec2 and engine.pipeline is not old_pipe
    assert engine.swaps == 1


def test_swap_must_preserve_serving_batch_size(small):
    """The batcher was sized for the serving batch — a configuration
    priced at another batch is an engine rebuild, not a swap."""
    m, packed, _, ec = small
    table2 = flat_table(m, batch=2)
    engine = ServingEngine(m, packed, ec, clock=FakeClock())
    other = configuration_from_mapping(
        table2, 2, canonical_mixed_mapping(m)
    )
    with pytest.raises(ValueError, match="batch size"):
        engine.swap_configuration(other)
    assert engine.config is ec and engine.swaps == 0


def test_reprice_only_swap_reuses_compiled_pipeline(small):
    """A swap that changes expectations but not the mapping (the
    controller's calibration case) must not re-jit the segments."""
    m, packed, table, ec = small
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(),
    )
    old_pipe = engine.pipeline
    repriced = dataclasses.replace(
        ec, expected_time_per_example=ec.expected_time_per_example * 2
    )
    assert engine.swap_configuration(repriced) is True
    assert engine.config is repriced
    assert engine.pipeline is old_pipe and engine.swaps == 1


def test_swap_requested_mid_step_is_deferred_to_batch_boundary(small):
    """A swap from inside a completion callback — i.e. mid-pipeline —
    must not land until the in-flight wave-train retires."""
    m, packed, table, ec = small
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(),
    )
    ec2 = configuration_from_mapping(table, 4, ("XYZ",) * len(m.specs))
    xs = _inputs(m, 3)
    for xw in xs:
        for j in range(4):
            engine.submit(xw[j])
    seen = []

    # hook the pipeline to request the swap while micro-batches are in
    # flight, recording what config was live at each completion
    real_run = engine.pipeline.run_pipelined

    def run_with_midstream_swap(inputs, *, on_complete=None, observer=None):
        def complete(i, out):
            if i == 0:
                assert engine.swap_configuration(ec2) is False  # deferred
            seen.append(engine.config)
            on_complete(i, out)

        return real_run(inputs, on_complete=complete, observer=observer)

    engine.pipeline.run_pipelined = run_with_midstream_swap
    assert engine.step(force=True) == 12
    # every completion in that step saw the OLD configuration...
    assert all(c is ec for c in seen) and len(seen) == 3
    # ...and the swap landed exactly at the batch boundary
    assert engine.config is ec2 and engine.swaps == 1


@settings(max_examples=5, deadline=None)
@given(swap_at=st.integers(0, 2), seed=st.integers(0, 2**31 - 1))
def test_outputs_bit_exact_before_during_after_swap(swap_at, seed):
    """Property: for any swap point within a served stream, every
    response equals the serial packed reference — remapping never
    perturbs results."""
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = flat_table(m)
    ec = configuration_from_mapping(table, 4, canonical_mixed_mapping(m))
    ec2 = map_efficient_configuration(table, policy="dp")
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(),
    )
    rng = np.random.default_rng(seed)
    xs = _inputs(m, 4, seed0=int(rng.integers(0, 1000)))
    for step_i, xw in enumerate(xs):
        if step_i == swap_at:
            engine.swap_configuration(ec2)
        reqs = [engine.submit(xw[j]) for j in range(4)]
        assert engine.step(force=True) == 4
        ref = np.asarray(forward_packed(m.specs, packed, xw))
        for j, r in enumerate(reqs):
            assert np.array_equal(r.wait(timeout=5.0), ref[j])
    assert engine.swaps == 1


# ---------------------------------------------------------------------------
# controller: fold -> remap -> swap -> journal
# ---------------------------------------------------------------------------


def test_controller_remaps_on_drift_and_journals(small):
    m, packed, table, ec = small
    tel = SegmentTelemetry(warmup=0)
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(), telemetry=tel,
    )
    ctl = RemapController(
        engine, table,
        detector=DriftDetector(rel_threshold=0.5, min_samples=3),
        clock=FakeClock(),
    )
    assert ctl.maybe_remap() is None      # no samples -> no remap
    # host segments observed 50x slower than predicted (contention)
    host_idx = [
        i for i, s in enumerate(ec.segments()) if not s.on_device
    ]
    _observe(tel, ec, {i: 50.0 for i in host_idx})
    rec = ctl.maybe_remap()
    assert rec is not None and ctl.journal == [rec]
    assert engine.swaps == 1 and engine.config is not ec
    assert rec.applied_immediately and rec.changed
    assert {r.segment_index for r in rec.reports} == set(host_idx)
    # the remap routed every *drifted* layer off the contended host
    # (undrifted layers may legally migrate anywhere the DP likes)
    segs = ec.segments()
    for i_seg in host_idx:
        for li in range(segs[i_seg].start, segs[i_seg].stop):
            assert engine.config.layer_configs[li] != CPU
    # DP on the corrected table can only improve on the old mapping
    assert rec.new_expected_s <= rec.old_expected_s
    # remap stays at the serving batch; telemetry starts fresh
    assert engine.config.proper_batch_size == ec.proper_batch_size
    assert tel.stats() == {} and ctl.table is not table
    # journal is exportable
    d = rec.to_dict()
    assert d["changed"] and d["reports"][0]["segment_index"] in host_idx


def test_controller_respects_max_remaps(small):
    m, packed, table, ec = small
    tel = SegmentTelemetry(warmup=0)
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(), telemetry=tel,
    )
    ctl = RemapController(
        engine, table, max_remaps=1,
        detector=DriftDetector(rel_threshold=0.5, min_samples=3),
        clock=FakeClock(),
    )
    _observe(tel, ec, {i: 50.0 for i in range(len(ec.segments()))})
    assert ctl.maybe_remap() is not None
    _observe(tel, engine.config,
             {i: 50.0 for i in range(len(engine.config.segments()))})
    assert ctl.maybe_remap() is None      # budget exhausted
    assert engine.swaps == 1


def test_controller_requires_telemetry(small):
    m, packed, table, ec = small
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(),
    )
    with pytest.raises(ValueError, match="telemetry"):
        RemapController(engine, table)


def test_controller_serves_bit_exact_across_live_remap(small):
    """End to end through the controller: drift injected between
    steps, outputs stay bit-exact with the reference throughout."""
    m, packed, table, ec = small
    tel = SegmentTelemetry(warmup=0)
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(), telemetry=tel,
    )
    ctl = RemapController(
        engine, table,
        detector=DriftDetector(rel_threshold=0.5, min_samples=3),
        clock=FakeClock(),
    )
    xs = _inputs(m, 3, seed0=7)
    for step_i, xw in enumerate(xs):
        if step_i == 1:                   # drift appears mid-stream
            _observe(tel, engine.config, {0: 50.0})
        reqs = [engine.submit(xw[j]) for j in range(4)]
        assert ctl.step(force=True) == 4
        ref = np.asarray(forward_packed(m.specs, packed, xw))
        for j, r in enumerate(reqs):
            assert np.array_equal(r.wait(timeout=5.0), ref[j])
    assert engine.swaps >= 1


def test_tenant_id_namespaces_journal_and_snapshot(small):
    """Two engines' controllers in one process must produce
    attributable records: the telemetry's tenant id rides in its
    snapshot and (via the controller default) in every SwapRecord."""
    m, packed, table, ec = small
    host_idx = [
        i for i, s in enumerate(ec.segments()) if not s.on_device
    ]
    records = []
    for name in ("tenant-a", "tenant-b"):
        tel = SegmentTelemetry(warmup=0, tenant=name)
        engine = ServingEngine(
            m, packed, ec, allowed_batch_sizes=table.batch_sizes,
            clock=FakeClock(), telemetry=tel,
        )
        ctl = RemapController(
            engine, table,
            detector=DriftDetector(rel_threshold=0.5, min_samples=3),
            clock=FakeClock(),
        )
        assert ctl.tenant == name         # defaulted from telemetry
        _observe(tel, ec, {i: 50.0 for i in host_idx})
        assert tel.snapshot()["tenant"] == name
        records.append(ctl.maybe_remap())
    assert [r.tenant for r in records] == ["tenant-a", "tenant-b"]
    assert records[0].to_dict()["tenant"] == "tenant-a"
    # explicit tenant= beats the telemetry default
    tel = SegmentTelemetry(warmup=0, tenant="from-tel")
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(), telemetry=tel,
    )
    assert RemapController(
        engine, table, tenant="explicit", clock=FakeClock()
    ).tenant == "explicit"
    # legacy single-tenant loops: unnamed telemetry keeps the old
    # snapshot schema (segment indices only)
    assert "tenant" not in SegmentTelemetry().snapshot()


# ---------------------------------------------------------------------------
# registry-wired hillclimb
# ---------------------------------------------------------------------------


def _variable_space_table():
    """Synthetic table with variable-size per-layer candidate sets
    drawn from the open registry: xla_fused is clearly cheapest on
    layer 1 — a fixed-8 searcher could never find it."""
    rows = [
        {"CPU": 5e-4, "X": 4e-4, "XYZ": 3e-4},
        {"CPU": 5e-4, "XYZ": 4e-4, "xla_fused": 1e-4},
        {"CPU": 2e-4, "X": 4e-4, "XYZ": 4e-4, "pallas_p64n64": 3e-4},
    ]
    kernels = [dict(r) for r in rows]
    return ProfileTable(
        "synthetic", (1,), ("L1:C64", "L2:C64", "L3:FC128"),
        times={1: rows}, kernel_times={1: kernels},
        h2d_times={1: [1e-5] * 3}, d2h_times={1: [1e-5] * 3},
    )


def test_hillclimb_searches_registry_candidate_sets():
    table = _variable_space_table()
    ec, trajectory = bnn_mapping_hillclimb(table)
    ec_dp = map_efficient_configuration(table, policy="dp")
    # sandwich: dp (exact) <= hillclimb <= greedy seed
    assert ec_dp.expected_time_per_example <= (
        ec.expected_time_per_example + 1e-15
    )
    assert ec.expected_time_per_example <= trajectory[0] + 1e-15
    assert trajectory == sorted(trajectory, reverse=True)
    # the climb moved beyond the fixed 8 where the registry wins
    assert ec.layer_configs[1] == "xla_fused"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hillclimb_never_worse_than_seed_and_dp_is_lower_bound(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 6))
    batches = (1, 2)
    times, kernels, h2d, d2h = {}, {}, {}, {}
    for b in batches:
        times[b], kernels[b], h2d[b], d2h[b] = [], [], [], []
        for _ in range(n):
            krow = {c: float(rng.uniform(1e-6, 1e-3)) for c in CONFIGS}
            up, down = rng.uniform(1e-6, 5e-4, 2)
            kernels[b].append(krow)
            times[b].append({
                c: krow[c] if c == CPU else krow[c] + up + down
                for c in CONFIGS
            })
            h2d[b].append(float(up))
            d2h[b].append(float(down))
    table = ProfileTable(
        "synthetic", batches, tuple(f"L{i+1}:C8" for i in range(n)),
        times, kernel_times=kernels, h2d_times=h2d, d2h_times=d2h,
    )
    ec, trajectory = bnn_mapping_hillclimb(table)
    ec_dp = map_efficient_configuration(table, policy="dp")
    assert ec.expected_time_per_example <= trajectory[0] + 1e-15
    assert ec_dp.expected_time_per_example <= (
        ec.expected_time_per_example + 1e-12
    )
