"""SegmentPlan IR: encoding chain invariants, boundary/transfer rules,
pricing consistency, and the plan executor vs the pre-refactor faithful
driver (bit-exactness property, inlined reference implementation)."""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.bnn import build_model
from repro.bnn.models import forward_packed, pack_params, prepare_input_packed
from repro.core.mapped_model import (
    _layer_fns,
    build_mapped_model,
    build_segment_fns,
)
from repro.core.mapper import (
    DEVICE,
    HOST,
    configuration_from_mapping,
    map_efficient_configuration,
)
from repro.core.parallel_config import CPU, FULL_GPU, is_host_config
from repro.core.plan import (
    MODES,
    PACKED,
    UNPACKED,
    PlanError,
    SegmentPlan,
    boundary_encoding_changes,
    build_plan,
    device_spans,
    encoding_conversions,
    kind_of_label,
    layer_encodings,
    select_fused_segments,
)
from repro.core.profiler import profile_bnn_model, profile_segment_variants


def _model_and_table(name="fashion_mnist", scale=0.25, batches=(1, 2)):
    m = build_model(name, scale=scale)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = profile_bnn_model(
        m, packed, batch_sizes=batches, time_source="analytic"
    )
    return m, packed, table


def _mixed_mapping(m):
    return tuple(
        FULL_GPU if s.kind in ("conv", "fc") else CPU for s in m.specs
    )


# ---------------------------------------------------------------------------
# Encoding chain
# ---------------------------------------------------------------------------


def test_kind_of_label():
    assert kind_of_label("L1:C64") == "conv"
    assert kind_of_label("L2:S") == "step"
    assert kind_of_label("L3:MP14") == "mp"
    assert kind_of_label("L7:FLAT") == "flat"
    assert kind_of_label("L8:FC128") == "fc"
    with pytest.raises(PlanError):
        kind_of_label("L9:Q7")


def test_layer_encodings_chain_from_packed_input():
    m = build_model("fashion_mnist", scale=0.25)
    kinds = tuple(s.kind for s in m.specs)
    encs = layer_encodings(kinds)
    assert encs[0][0] == PACKED            # prepare_input_packed
    for (a_in, a_out), (b_in, _) in zip(encs, encs[1:]):
        assert a_out == b_in               # adjacent ops always agree
    # conv/fc unpack, step repacks, mp/flat preserve
    for kind, (e_in, e_out) in zip(kinds, encs):
        if kind in ("conv", "fc"):
            assert (e_in, e_out) == (PACKED, UNPACKED)
        elif kind == "step":
            assert (e_in, e_out) == (UNPACKED, PACKED)
        else:
            assert e_in == e_out


def test_layer_encodings_rejects_unchainable_sequences():
    # conv produces unpacked pre-activations; a second conv demands
    # packed words — no bit-exact executor exists for that chain
    with pytest.raises(PlanError, match="encoding mismatch"):
        layer_encodings(("conv", "conv"))
    # step thresholds unpacked input; the network input is packed
    with pytest.raises(PlanError, match="encoding mismatch"):
        layer_encodings(("step",))
    with pytest.raises(PlanError, match="unknown layer kind"):
        layer_encodings(("conv", "softmax"))


# ---------------------------------------------------------------------------
# Satellite: co-placed adjacent layers never unpack/repack between them
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("mapping_kind", ["mixed", "all_device"])
def test_no_encoding_change_ever_crosses_an_op_boundary(
    mode, mapping_kind
):
    """The invariant the IR proves: conversions live *inside* the op
    that changes encoding, so no executor packs/unpacks between
    co-placed adjacent layers — in any plan mode, under any mapping."""
    m, packed, table = _model_and_table()
    mapping = (
        _mixed_mapping(m)
        if mapping_kind == "mixed"
        else tuple(FULL_GPU for _ in m.specs)
    )
    ec = configuration_from_mapping(table, 2, mapping)
    plan = build_plan(ec, mode=mode)
    assert boundary_encoding_changes(plan) == ()


@pytest.mark.parametrize("mapping_kind", ["mixed", "all_device", "dp"])
def test_encoding_cost_charged_exactly_once_per_change(mapping_kind):
    """Each encoding change appears exactly once (inside its op), and
    the set of conversions is a property of the *architecture* — the
    same in every plan mode, so segmenting/fusing never adds a
    pack/unpack that per-layer execution wouldn't pay."""
    m, packed, table = _model_and_table()
    if mapping_kind == "dp":
        ec = map_efficient_configuration(table, policy="dp")
    else:
        mapping = (
            _mixed_mapping(m)
            if mapping_kind == "mixed"
            else tuple(FULL_GPU for _ in m.specs)
        )
        ec = configuration_from_mapping(table, 2, mapping)

    kinds = tuple(s.kind for s in m.specs)
    encs = layer_encodings(kinds)
    want = tuple(
        (i, e_in, e_out)
        for i, (e_in, e_out) in enumerate(encs)
        if e_in != e_out
    )
    per_mode = {
        mode: encoding_conversions(build_plan(ec, mode=mode))
        for mode in MODES
    }
    for mode, got in per_mode.items():
        assert got == want, mode
    # and the charge is priced once: every mode's kernel total is the
    # same per-layer sum (boundary transfers differ by design)
    kernels = ec.per_layer_kernel_times or ec.per_layer_times
    for mode in MODES:
        plan = build_plan(ec, mode=mode)
        assert sum(n.kernel_s for n in plan.nodes) == pytest.approx(
            sum(kernels)
        )


# ---------------------------------------------------------------------------
# Transfers and pricing
# ---------------------------------------------------------------------------


def test_transfers_only_at_placement_changes():
    m, packed, table = _model_and_table()
    ec = configuration_from_mapping(table, 2, _mixed_mapping(m))
    placements = [
        seg.placement for seg in ec.segments() for _ in range(len(seg))
    ]
    n = len(placements)

    plan = build_plan(ec, mode="layers")
    for i, node in enumerate(plan.nodes):
        dev = placements[i] == DEVICE
        want_in = dev and (i == 0 or placements[i - 1] == HOST)
        want_out = dev and (i == n - 1 or placements[i + 1] == HOST)
        assert (node.transfer_in, node.transfer_out) == (
            want_in, want_out,
        )

    # paper §IV-A: every device layer round-trips
    for node in build_plan(ec, mode="roundtrip").nodes:
        assert node.transfer_in == node.transfer_out == node.on_device

    # segment nodes transfer at their edges only — interior co-placed
    # layers share no transfer by construction (one node)
    for node in build_plan(ec, mode="segments").nodes:
        assert node.transfer_in == node.transfer_out == node.on_device

    # the whole-network jit leaves transfers to XLA
    [whole] = build_plan(ec, mode="whole").nodes
    assert not whole.transfer_in and not whole.transfer_out


def test_segments_plan_prices_match_mapper():
    m, packed, table = _model_and_table()
    for policy in ("greedy", "dp"):
        ec = map_efficient_configuration(table, policy=policy)
        plan = build_plan(ec, mode="segments")
        assert plan.expected_time_per_example == pytest.approx(
            ec.expected_time_per_example
        )
        assert plan.node_times() == pytest.approx(
            ec.segment_expected_times()
        )
        assert plan.batch == ec.proper_batch_size
        assert plan.policy == policy


def test_plan_nodes_duck_type_segments():
    m, packed, table = _model_and_table()
    ec = configuration_from_mapping(table, 2, _mixed_mapping(m))
    plan = build_plan(ec, mode="segments")
    for node, seg in zip(plan.nodes, ec.segments()):
        assert (node.start, node.stop) == (seg.start, seg.stop)
        assert node.placement == seg.placement
        assert node.on_device == seg.on_device
        assert node.configs == seg.configs
        assert len(node) == len(seg)


def test_plan_json_roundtrip():
    m, packed, table = _model_and_table()
    ec = map_efficient_configuration(table, policy="dp")
    for mode in MODES:
        plan = build_plan(ec, mode=mode)
        again = SegmentPlan.from_json(plan.to_json())
        assert again == plan
        d = json.loads(plan.to_json())
        assert d["mode"] == mode


def test_unknown_mode_rejected():
    m, packed, table = _model_and_table()
    ec = map_efficient_configuration(table, policy="dp")
    with pytest.raises(PlanError, match="unknown plan mode"):
        build_plan(ec, mode="wavefront")


# ---------------------------------------------------------------------------
# Fused pricing: min over a superset that contains per-layer
# ---------------------------------------------------------------------------


def test_fused_plan_never_priced_worse_than_per_layer():
    """select_fused_segments takes min(per-layer kernel sum, profiled
    segment variants) per device span, so the fused plan's total is <=
    the per-layer plan's — the DP's config space with segment variants
    is a superset of the per-layer-only space."""
    m, packed, table = _model_and_table()
    for mapping in (
        _mixed_mapping(m), tuple(FULL_GPU for _ in m.specs),
    ):
        ec = configuration_from_mapping(table, 2, mapping)
        profile_segment_variants(
            m, packed, table,
            spans=device_spans(ec),
            batch_sizes=(2,),
            time_source="analytic",
        )
        fused = select_fused_segments(ec, table)
        base = build_plan(ec, mode="segments")
        plan = build_plan(fused, mode="segments")
        assert (
            plan.expected_time_per_example
            <= base.expected_time_per_example
        )
        kernels = ec.per_layer_kernel_times or ec.per_layer_times
        for start, stop, name, t in fused.fused_segments:
            # recorded winners are strict wins over per-layer
            assert t < sum(kernels[start:stop])
            node = next(
                nd for nd in plan.nodes if (nd.start, nd.stop) == (start, stop)
            )
            assert node.fused_variant == name
            assert node.kernel_s == pytest.approx(t)


# ---------------------------------------------------------------------------
# The plan executor vs the pre-refactor faithful driver
# ---------------------------------------------------------------------------


def _pre_refactor_faithful(model, packed, config, registry=None,
                           elide_transfers=True):
    """The faithful driver exactly as it existed before the plan IR
    (inlined reference — the refactor must not change its semantics)."""
    fns = _layer_fns(model, packed, config, registry)
    jitted = [jax.jit(f) for f in fns]
    cfgs = config.layer_configs

    def run_faithful(x_words):
        x = np.asarray(x_words)  # input starts on host
        for i, (f, cfg) in enumerate(zip(jitted, cfgs)):
            xd = jnp.asarray(x)
            out = f(xd)
            jax.block_until_ready(out)
            if is_host_config(cfg, registry):
                x = out
            elif (
                elide_transfers
                and i + 1 < len(cfgs)
                and not is_host_config(cfgs[i + 1], registry)
            ):
                x = out
            else:
                x = np.asarray(out)
        return np.asarray(x)

    return run_faithful


_MAPPING_STYLES = ("mixed", "all_device", "all_host", "alternating")


def _style_mapping(m, style, rng):
    if style == "mixed":
        return _mixed_mapping(m)
    if style == "all_device":
        return tuple(FULL_GPU for _ in m.specs)
    if style == "all_host":
        return tuple(CPU for _ in m.specs)
    # random per-layer draw over host + device fixed-8 configs (the
    # ones a plain profile_bnn_model table prices)
    pool = (CPU, "X", "XY", FULL_GPU)
    return tuple(pool[rng.integers(len(pool))] for _ in m.specs)


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    style=st.sampled_from(_MAPPING_STYLES),
    elide=st.booleans(),
)
def test_plan_executor_bitexact_vs_prerefactor_driver(
    seed, style, elide
):
    """Property: over random mappings, every plan shape — faithful
    per-layer (elided and roundtrip), whole-network jit, and the
    segments plan — is bit-exact against the pre-refactor driver and
    the packed reference forward."""
    rng = np.random.default_rng(seed)
    m, packed, table = _model_and_table(batches=(2,))
    mapping = _style_mapping(m, style, rng)
    ec = configuration_from_mapping(table, 2, mapping)
    x = prepare_input_packed(
        jax.random.uniform(
            jax.random.PRNGKey(seed % 997),
            (2, *m.input_hw, m.in_channels),
        )
    )
    want = np.asarray(forward_packed(m.specs, packed, x))
    old = _pre_refactor_faithful(
        m, packed, ec, elide_transfers=elide
    )(x)
    assert np.array_equal(want, old)

    new = build_mapped_model(
        m, packed, ec, fused=False, elide_transfers=elide
    )(x)
    assert np.array_equal(old, new)
    assert np.array_equal(want, np.asarray(
        build_mapped_model(m, packed, ec, fused=True)(x)
    ))
    out = x
    for _node, fn in build_segment_fns(m, packed, ec):
        out = fn(out)
    assert np.array_equal(want, np.asarray(out))
