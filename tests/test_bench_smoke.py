"""bench_smoke regression gate: pure comparison logic (no timing —
the actual tiny benchmark run is exercised by the CI bench-smoke job
and the committed baseline)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.bench_smoke import compare, gate  # noqa: E402


def _doc(metrics, settings=None):
    return {
        "schema": 1,
        "settings": settings or {"kernel_bench": {"scale": 0.25}},
        "metrics": metrics,
    }


def test_compare_passes_within_tolerance():
    base = {"a": {"us": 100.0}, "b": {"us": 50.0}}
    pr = {"a": {"us": 120.0}, "b": {"us": 40.0}}
    failures, notes = compare(pr, base, tolerance=0.25)
    assert failures == []
    assert len(notes) == 2


def test_compare_fails_on_regression_over_tolerance():
    base = {"a": {"us": 100.0}}
    pr = {"a": {"us": 126.0}}
    failures, _ = compare(pr, base, tolerance=0.25)
    assert len(failures) == 1
    assert "a" in failures[0] and "tolerance" in failures[0]
    # looser tolerance clears it
    failures, _ = compare(pr, base, tolerance=0.30)
    assert failures == []


def test_compare_skips_functional_rows_but_requires_presence():
    """us=0 sentinel rows (e.g. adapt_bench) are never timing-gated,
    but dropping one from the PR run is still a coverage failure."""
    base = {"adapt/x": {"us": 0.0}, "a": {"us": 100.0}}
    pr = {"adapt/x": {"us": 0.0}, "a": {"us": 100.0}}
    failures, notes = compare(pr, base, tolerance=0.25)
    assert failures == []
    assert any("functional" in n for n in notes)
    failures, _ = compare({"a": {"us": 100.0}}, base, tolerance=0.25)
    assert len(failures) == 1 and "missing" in failures[0]


def test_compare_fails_on_missing_metric_but_not_new():
    base = {"gone": {"us": 10.0}}
    pr = {"new": {"us": 10.0}}
    failures, notes = compare(pr, base, tolerance=0.25)
    assert len(failures) == 1 and "missing" in failures[0]
    assert any("new metric" in n for n in notes)


def test_gate_refuses_settings_mismatch():
    base = _doc({"a": {"us": 100.0}}, settings={"kernel_bench": {"scale": 0.25}})
    pr = _doc({"a": {"us": 100.0}}, settings={"kernel_bench": {"scale": 0.5}})
    failures, notes = gate(pr, base, tolerance=0.25)
    assert len(failures) == 1 and "settings changed" in failures[0]
    assert notes == []


def test_gate_delegates_to_compare_when_settings_match():
    base = _doc({"a": {"us": 100.0}})
    pr = _doc({"a": {"us": 90.0}})
    failures, notes = gate(pr, base, tolerance=0.25)
    assert failures == [] and len(notes) == 1


def test_committed_baseline_matches_current_settings():
    """The committed baseline must gate the workload bench_smoke
    actually runs — a SMOKE_KWARGS change without a refresh fails."""
    import json

    from benchmarks.bench_smoke import BASELINE_PATH, SMOKE_KWARGS

    doc = json.loads(BASELINE_PATH.read_text())
    want = {
        k: {kk: list(v) if isinstance(v, tuple) else v
            for kk, v in kw.items()}
        for k, kw in SMOKE_KWARGS.items()
    }
    assert doc["settings"] == want
    assert doc["metrics"], "baseline has no metrics"
