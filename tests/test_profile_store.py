"""Profile store: ProfileTable JSON round-trip (schema-versioned,
legacy-tolerant), store keying (fingerprint/model/batch/registry),
warm start with zero profiler invocations, gc/export, and the
tools/profile_store.py CLI."""

import json
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.core.mapper import (
    EfficientConfiguration,
    map_efficient_configuration,
)
from repro.core.parallel_config import CONFIGS, CPU
from repro.core.profiler import ProfileTable
from repro.kernels.registry import (
    KernelVariant, VariantRegistry, _register_defaults,
)
from repro.serving import ServingEngine
from repro.store import (
    ProfileStore,
    hardware_fingerprint,
    model_signature,
    registry_hash,
    signature_from_labels,
)

REPO = Path(__file__).resolve().parent.parent


def _table(model_name="m", batches=(1, 4), n_layers=3, seed=0):
    rng = np.random.default_rng(seed)
    times, kernels, h2d, d2h = {}, {}, {}, {}
    for b in batches:
        times[b], kernels[b], h2d[b], d2h[b] = [], [], [], []
        for _ in range(n_layers):
            krow = {c: float(rng.uniform(1e-6, 1e-3)) for c in CONFIGS}
            up, down = (float(x) for x in rng.uniform(1e-6, 5e-4, 2))
            kernels[b].append(krow)
            times[b].append({
                c: krow[c] if c == CPU else krow[c] + up + down
                for c in CONFIGS
            })
            h2d[b].append(up)
            d2h[b].append(down)
    return ProfileTable(
        model_name, tuple(batches),
        tuple(f"L{i+1}:C8" for i in range(n_layers)),
        times, kernel_times=kernels, h2d_times=h2d, d2h_times=d2h,
    )


# ---------------------------------------------------------------------------
# ProfileTable JSON round-trip
# ---------------------------------------------------------------------------


def test_profile_table_json_roundtrip_exact():
    t = _table()
    t2 = ProfileTable.from_json(t.to_json())
    assert t2.model_name == t.model_name
    assert t2.batch_sizes == t.batch_sizes          # ints, not strings
    assert t2.layer_labels == t.layer_labels
    assert t2.times == t.times
    assert t2.kernel_times == t.kernel_times
    assert t2.h2d_times == t.h2d_times
    assert t2.d2h_times == t.d2h_times
    doc = json.loads(t.to_json())
    assert doc["schema"] == ProfileTable.SCHEMA_VERSION
    assert doc["kind"] == "profile_table"


def test_profile_table_json_legacy_tolerant():
    """A pre-schema document without envelope or split fields loads
    and degrades exactly like a legacy in-memory table."""
    legacy = {
        "model": "m", "batch_sizes": [1],
        "layer_labels": ["L1:C8"],
        "times": {"1": [{"CPU": 1.0, "X": 2.0}]},
    }
    t = ProfileTable.from_json(json.dumps(legacy))
    assert t.batch_sizes == (1,)
    assert t.kernel_time(1, 0, "X") == 2.0          # kernel == total
    assert t.h2d(1, 0) == 0.0 and t.d2h(1, 0) == 0.0
    assert t.boundary_time(1, 0, "X") == 0.0
    # and it re-serializes under the current schema
    t2 = ProfileTable.from_json(t.to_json())
    assert t2.times == t.times and t2.kernel_times is None


def test_profile_table_json_refuses_newer_schema_and_wrong_kind():
    doc = json.loads(_table().to_json())
    doc["schema"] = ProfileTable.SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="newer"):
        ProfileTable.from_json(json.dumps(doc))
    doc["schema"] = ProfileTable.SCHEMA_VERSION
    doc["kind"] = "efficient_configuration"
    with pytest.raises(ValueError, match="profile_table"):
        ProfileTable.from_json(json.dumps(doc))


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------


def test_fingerprint_and_signatures_are_stable():
    assert hardware_fingerprint() == hardware_fingerprint()
    m = build_model("fashion_mnist", scale=0.25)
    assert model_signature(m) == model_signature(m)
    t = _table(model_name=m.name)
    # a table keyed from its own labels matches the model only when
    # the labels actually match
    assert signature_from_labels(m.name, t.layer_labels) != (
        model_signature(m)
    )
    labels = tuple(f"L{s.idx}:{s.notation}" for s in m.specs)
    assert signature_from_labels(m.name, labels) == model_signature(m)


def test_registry_hash_tracks_the_variant_space():
    base = registry_hash()
    custom = _register_defaults(VariantRegistry())
    assert registry_hash(custom) == base        # same space, same key
    custom.register(KernelVariant(
        name="my_kernel", builder=lambda a, w, k: a, placement="device",
    ))
    assert registry_hash(custom) != base        # new variant re-keys


# ---------------------------------------------------------------------------
# store round trips and isolation
# ---------------------------------------------------------------------------


def test_store_profile_roundtrip_and_cross_fingerprint_isolation(tmp_path):
    m = build_model("fashion_mnist", scale=0.25)
    labels = tuple(f"L{s.idx}:{s.notation}" for s in m.specs)
    t = _table(model_name=m.name, n_layers=len(labels))
    t = ProfileTable(
        m.name, t.batch_sizes, labels, t.times,
        kernel_times=t.kernel_times, h2d_times=t.h2d_times,
        d2h_times=t.d2h_times,
    )
    a = ProfileStore(tmp_path, fingerprint="machine-a")
    path = a.save_profile(t)
    assert path.exists()
    got = a.load_profile(m, t.batch_sizes)
    assert got is not None and got.times == t.times
    # a different platform must never see machine A's profile
    b = ProfileStore(tmp_path, fingerprint="machine-b")
    assert b.load_profile(m, t.batch_sizes) is None
    # nor a different batch-size sweep
    assert a.load_profile(m, (1, 2)) is None


def test_store_batch_key_is_order_insensitive(tmp_path):
    m = build_model("fashion_mnist", scale=0.25)
    labels = tuple(f"L{s.idx}:{s.notation}" for s in m.specs)
    t = _table(model_name=m.name, n_layers=len(labels))
    t = ProfileTable(
        m.name, t.batch_sizes, labels, t.times,
        kernel_times=t.kernel_times, h2d_times=t.h2d_times,
        d2h_times=t.d2h_times,
    )
    store = ProfileStore(tmp_path, fingerprint="machine-a")
    store.save_profile(t)                      # batch_sizes (1, 4)
    got = store.load_profile(m, (4, 1))        # same set, any order
    assert got is not None and got.times == t.times


def test_identical_signatures_different_registries_never_collide(tmp_path):
    """Two fleets may serve the *same* model under different kernel
    registries (e.g. one with an extra variant registered); their
    signatures are identical, so only the registry hash separates
    their entries — it must, in both directions."""
    m = build_model("fashion_mnist", scale=0.25)
    labels = tuple(f"L{s.idx}:{s.notation}" for s in m.specs)
    t = _table(model_name=m.name, n_layers=len(labels))
    t = ProfileTable(
        m.name, t.batch_sizes, labels, t.times,
        kernel_times=t.kernel_times, h2d_times=t.h2d_times,
        d2h_times=t.d2h_times,
    )
    reg2 = VariantRegistry()
    _register_defaults(reg2)
    reg2.register(KernelVariant(
        name="fleet_only", placement="device", aspects=("X",),
        applicable=lambda shape, platform=None: True,
        builder=lambda p, w, k: None,
    ))
    a = ProfileStore(tmp_path, fingerprint="f")
    b = ProfileStore(tmp_path, fingerprint="f", registry=reg2)
    assert a.space_hash != b.space_hash
    assert model_signature(m) == model_signature(m)  # same model key
    a.save_profile(t)
    assert a.load_profile(m, t.batch_sizes) is not None
    assert b.load_profile(m, t.batch_sizes) is None  # no cross-read
    b.save_profile(t)
    # distinct paths on disk, both now readable through their own key
    assert a.profile_path(model_signature(m), t.batch_sizes) != (
        b.profile_path(model_signature(m), t.batch_sizes)
    )
    assert a.load_profile(m, t.batch_sizes) is not None
    assert b.load_profile(m, t.batch_sizes) is not None


def test_fleet_scope_round_trip_and_isolation(tmp_path):
    """The fleet-key contract: a mapping jointly optimized under one
    co-tenancy round-trips through its scoped store, and neither a
    solo (scope-less) store nor a different fleet's scope can read
    it — same model, same fingerprint, same registry throughout."""
    from repro.store import fleet_scope

    m = build_model("fashion_mnist", scale=0.25)
    labels = tuple(f"L{s.idx}:{s.notation}" for s in m.specs)
    t = _table(model_name=m.name, n_layers=len(labels))
    t = ProfileTable(
        m.name, t.batch_sizes, labels, t.times,
        kernel_times=t.kernel_times, h2d_times=t.h2d_times,
        d2h_times=t.d2h_times,
    )
    ec = map_efficient_configuration(t, policy="dp")

    # scope canonicalization: order/duplicates collapse, mix re-keys
    scope = fleet_scope(("mnist-a", "mnist-b"))
    assert scope == fleet_scope(("mnist-b", "mnist-a", "mnist-a"))
    assert scope != fleet_scope(("mnist-a", "mnist-c"))
    with pytest.raises(ValueError):
        fleet_scope(())

    solo = ProfileStore(tmp_path, fingerprint="f")
    fleet = ProfileStore(tmp_path, fingerprint="f", scope=scope)
    other = ProfileStore(
        tmp_path, fingerprint="f", scope=fleet_scope(("x", "y"))
    )
    fleet.save_mapping(ec)
    fleet.save_profile(t)
    got = fleet.load_mapping(m, policy="dp")
    assert got is not None and got.layer_configs == ec.layer_configs
    assert fleet.load_profile(m, t.batch_sizes) is not None
    # isolation in every direction
    assert solo.load_mapping(m, policy="dp") is None
    assert other.load_mapping(m, policy="dp") is None
    solo.save_mapping(ec)
    assert solo.load_mapping(m, policy="dp") is not None
    assert other.load_mapping(m, policy="dp") is None
    # the envelope records the scope, and inspect sees all entries
    doc = json.loads(
        fleet.mapping_path(
            model_signature(m), "dp", ec.proper_batch_size
        ).read_text()
    )
    assert doc["key"]["scope"] == scope
    kinds = [e.key.get("scope") for e in solo.entries()]
    assert scope in kinds and None in kinds


def test_store_scope_validates():
    with pytest.raises(ValueError, match="scope"):
        ProfileStore("/tmp/x", scope="")
    with pytest.raises(ValueError, match="scope"):
        ProfileStore("/tmp/x", scope="a/b")


def test_warm_start_rejects_mapping_from_unprofiled_batch(tmp_path):
    """A mapping remapped/saved at a batch outside the requested sweep
    must be re-derived from the table, not served against it."""
    m = build_model("fashion_mnist", scale=0.25)
    labels = tuple(f"L{s.idx}:{s.notation}" for s in m.specs)
    t = _table(model_name=m.name, batches=(1, 4), n_layers=len(labels))
    t = ProfileTable(
        m.name, t.batch_sizes, labels, t.times,
        kernel_times=t.kernel_times, h2d_times=t.h2d_times,
        d2h_times=t.d2h_times,
    )
    t16 = _table(model_name=m.name, batches=(16,), n_layers=len(labels))
    t16 = ProfileTable(
        m.name, t16.batch_sizes, labels, t16.times,
        kernel_times=t16.kernel_times, h2d_times=t16.h2d_times,
        d2h_times=t16.d2h_times,
    )
    store = ProfileStore(tmp_path, fingerprint="machine-a")
    store.save_profile(t)
    # most recently saved mapping is for batch 16
    store.save_mapping(map_efficient_configuration(t, policy="dp"))
    store.save_mapping(map_efficient_configuration(t16, policy="dp"))
    table, config = store.warm_start(m, batch_sizes=(1, 4))
    assert config.proper_batch_size in table.batch_sizes


def test_store_mapping_roundtrip(tmp_path):
    m = build_model("fashion_mnist", scale=0.25)
    labels = tuple(f"L{s.idx}:{s.notation}" for s in m.specs)
    t = _table(model_name=m.name, n_layers=len(labels))
    t = ProfileTable(
        m.name, t.batch_sizes, labels, t.times,
        kernel_times=t.kernel_times, h2d_times=t.h2d_times,
        d2h_times=t.d2h_times,
    )
    ec = map_efficient_configuration(t, policy="dp")
    store = ProfileStore(tmp_path, fingerprint="machine-a")
    store.save_mapping(ec)
    got = store.load_mapping(m, policy="dp")
    assert isinstance(got, EfficientConfiguration)
    assert got.layer_configs == ec.layer_configs
    assert got.proper_batch_size == ec.proper_batch_size
    assert store.load_mapping(m, policy="greedy") is None
    assert store.load_mapping(
        m, policy="dp", batch=ec.proper_batch_size
    ) is not None


def test_warm_start_serves_with_zero_profiler_invocations(tmp_path):
    """The acceptance path: save on machine state A, reload under the
    same fingerprint, serve — counting profiler invocations."""
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    calls = []

    def fake_profiler(model, packed_params, *, batch_sizes):
        calls.append(batch_sizes)
        labels = tuple(f"L{s.idx}:{s.notation}" for s in model.specs)
        t = _table(model_name=model.name, batches=batch_sizes,
                   n_layers=len(labels))
        return ProfileTable(
            model.name, t.batch_sizes, labels, t.times,
            kernel_times=t.kernel_times, h2d_times=t.h2d_times,
            d2h_times=t.d2h_times,
        )

    store = ProfileStore(tmp_path, fingerprint="machine-a")
    assert store.warm_start(m, batch_sizes=(1, 4)) is None  # cold

    t1, loaded = store.get_or_profile(
        m, packed, fake_profiler, batch_sizes=(1, 4)
    )
    assert not loaded and len(calls) == 1       # cold start profiles once

    # same fingerprint, fresh process-equivalent: zero further profiling
    store2 = ProfileStore(tmp_path, fingerprint="machine-a")
    t2, loaded = store2.get_or_profile(
        m, packed, fake_profiler, batch_sizes=(1, 4)
    )
    assert loaded and len(calls) == 1
    assert t2.times == t1.times

    warm = store2.warm_start(m, batch_sizes=(1, 4))
    assert warm is not None and len(calls) == 1
    table, config = warm
    # the warm-started configuration serves real traffic correctly
    engine = ServingEngine(
        m, packed, config, allowed_batch_sizes=table.batch_sizes
    )
    x01 = jax.random.uniform(jax.random.PRNGKey(7), (4, 28, 28, 1))
    xw = np.asarray(prepare_input_packed(x01))
    reqs = [engine.submit(xw[i]) for i in range(4)]
    assert engine.step(force=True) == 4
    ref = np.asarray(forward_packed(m.specs, packed, xw))
    for i, r in enumerate(reqs):
        assert np.array_equal(r.wait(timeout=5.0), ref[i])
    # the derived mapping was persisted: next warm start loads it as-is
    assert store2.load_mapping(m, policy="dp") is not None
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# maintenance: entries / gc / export
# ---------------------------------------------------------------------------


def _seeded_store(tmp_path):
    m = build_model("fashion_mnist", scale=0.25)
    labels = tuple(f"L{s.idx}:{s.notation}" for s in m.specs)
    t = _table(model_name=m.name, n_layers=len(labels))
    t = ProfileTable(
        m.name, t.batch_sizes, labels, t.times,
        kernel_times=t.kernel_times, h2d_times=t.h2d_times,
        d2h_times=t.d2h_times,
    )
    store = ProfileStore(tmp_path, fingerprint="machine-a")
    store.save_profile(t)
    store.save_mapping(map_efficient_configuration(t, policy="dp"))
    return store, m, t


def test_entries_gc_and_export(tmp_path):
    store, _, _ = _seeded_store(tmp_path)
    entries = store.entries()
    assert {e.kind for e in entries} == {
        "profile_table", "efficient_configuration"
    }
    # plant a stale old-schema artifact
    old = tmp_path / "v0" / "machine-a" / "x" / "profile-b1.json"
    old.parent.mkdir(parents=True)
    old.write_text(json.dumps({
        "schema": 0, "kind": "profile_table",
        "saved_at": time.time() - 1e6, "key": {}, "payload": {},
    }))
    assert len(store.entries()) == 3
    planned = store.gc(dry_run=True)
    assert planned == [old] and old.exists()    # dry run plans only
    removed = store.gc()
    assert removed == [old] and not old.exists()
    assert not (tmp_path / "v0").exists()       # empty dirs pruned
    # age-based gc takes the rest
    assert len(store.gc(max_age_s=0.0)) == 2
    assert store.entries() == []
    # export is a self-contained bundle
    store2, _, _ = _seeded_store(tmp_path)
    bundle = store2.export()
    assert bundle["kind"] == "profile_store_export"
    assert len(bundle["entries"]) == 2
    for e in bundle["entries"]:
        assert "payload" in e["document"]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _cli(*args):
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "profile_store.py"), *args],
        capture_output=True, text=True, env=env,
    )


def test_cli_inspect_gc_export(tmp_path):
    _seeded_store(tmp_path)
    out = _cli("inspect", "--root", str(tmp_path))
    assert out.returncode == 0, out.stderr
    assert "profile_table" in out.stdout
    assert "efficient_configuration" in out.stdout

    export_path = tmp_path / "bundle.json"
    out = _cli("export", "--root", str(tmp_path), "--out", str(export_path))
    assert out.returncode == 0, out.stderr
    bundle = json.loads(export_path.read_text())
    assert len(bundle["entries"]) == 2

    # preview and delete are mutually exclusive modes
    out = _cli("gc", "--root", str(tmp_path), "--dry-run", "--yes")
    assert out.returncode != 0
    out = _cli("gc", "--root", str(tmp_path), "--max-age-days", "0",
               "--yes")
    assert out.returncode == 0, out.stderr
    out = _cli("inspect", "--root", str(tmp_path))
    assert out.returncode == 0
    assert "0 entries" in out.stdout


def test_cli_fit_trains_on_stored_rows(tmp_path):
    from fixtures import loglinear_table, synthetic_model

    from repro.estimator import (
        LatencyPredictor, training_rows_from_table,
    )

    # an empty store has nothing to fit — distinct exit code
    out = _cli("fit", "--root", str(tmp_path))
    assert out.returncode == 1
    assert "no training rows" in out.stdout

    # rows saved under the *default* fingerprint, which is what the
    # CLI's handle resolves
    store = ProfileStore(tmp_path)
    m = synthetic_model("cli_fit")
    store.save_training_rows(training_rows_from_table(m, loglinear_table(m)))
    pred_json = tmp_path / "predictor.json"
    out = _cli("fit", "--root", str(tmp_path), "--out", str(pred_json))
    assert out.returncode == 0, out.stderr
    assert "fitted on" in out.stdout
    assert "gemm/host/host" in out.stdout
    pred = LatencyPredictor.from_json(pred_json.read_text())
    assert pred.n_rows > 0

    # inspect surfaces the training-row artifact with its row count
    out = _cli("inspect", "--root", str(tmp_path))
    assert out.returncode == 0
    assert "training_rows" in out.stdout and "rows=" in out.stdout
