"""Sharding plan unit tests (no devices needed for spec logic) +
multi-device integration via a subprocess (so the main test process
keeps seeing exactly 1 device)."""

import subprocess
import sys
import textwrap

import pytest

from repro import configs as C
from repro.core.hep_shard import ShardTrial, search
from repro.parallel.sharding import ShardScheme, default_scheme


def test_default_scheme_size_adaptive():
    assert default_scheme(C.get("qwen2_0_5b")).tp is False   # <2B: DP only
    assert default_scheme(C.get("mamba2_130m")).tp is False
    s14 = default_scheme(C.get("qwen2_5_14b"))
    assert s14.tp is True and s14.fsdp == "zero1"
    sg = default_scheme(C.get("grok_1_314b"))
    assert sg.tp is True and sg.fsdp == "zero3"              # >20B: ZeRO-3


def test_expert_mode_auto():
    ds = C.get("deepseek_moe_16b")
    assert ShardScheme().resolve_expert_mode(ds, 16) == "ep"   # 64 % 16
    gk = C.get("grok_1_314b")
    assert ShardScheme().resolve_expert_mode(gk, 16) == "tp"   # 8 % 16


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs as C
    from repro.models.transformer import init_params, forward
    from repro.parallel.sharding import make_param_shardings, make_batch_shardings, ShardScheme
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = C.get_smoke("olmo_1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    scheme = ShardScheme(tp=True, fsdp="zero1")
    p_sh = make_param_shardings(cfg, mesh, params, scheme)
    params_s = jax.tree.map(jax.device_put, params, p_sh)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
    toks_s = jax.device_put(toks, NamedSharding(mesh, P("data", None)))

    with mesh:
        sharded = jax.jit(lambda p, t: forward(cfg, p, t)[0])(params_s, toks_s)
    local = forward(cfg, params, toks)[0]
    err = float(jnp.max(jnp.abs(sharded.astype(jnp.float32) - local.astype(jnp.float32))))
    rel = err / (float(jnp.max(jnp.abs(local))) + 1e-9)
    assert rel < 2e-4, f"sharded != local: rel {rel}"
    print("SHARDED-OK", rel)
""")


def test_sharded_forward_matches_local():
    """8-device SPMD forward == single-device forward (subprocess)."""
    r = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        timeout=600,
    )
    assert "SHARDED-OK" in r.stdout, r.stdout + r.stderr


# ------------------------- HEP-Shard search -------------------------------


def test_hep_shard_search_finds_planted_optimum():
    """Coordinate descent reaches the planted best scheme and never
    returns a worse-cost scheme than any it evaluated."""
    target = ShardScheme(tp=False, fsdp="zero3", batch_over_model=True)

    def evaluate(s: ShardScheme) -> ShardTrial:
        dist = (
            (s.tp != target.tp)
            + (s.fsdp != target.fsdp)
            + (s.batch_over_model != target.batch_over_model)
        )
        return ShardTrial(
            scheme=s, compute_s=0.1 + dist, memory_s=0.05,
            collective_s=0.01 * dist, peak_bytes=2**30,
        )

    best, history = search(
        evaluate,
        knobs={
            "tp": (True, False),
            "fsdp": ("zero1", "zero3"),
            "batch_over_model": (False, True),
        },
        log=None,
    )
    assert best.scheme.tp == target.tp
    assert best.scheme.fsdp == target.fsdp
    assert best.scheme.batch_over_model == target.batch_over_model
    assert best.cost == min(t.cost for t in history)


def test_hep_shard_oom_penalty_dominates():
    def evaluate(s: ShardScheme) -> ShardTrial:
        fits = s.fsdp == "zero3"
        return ShardTrial(
            scheme=s,
            compute_s=1.0 if fits else 0.1,   # the OOM config is "faster"
            memory_s=0.0, collective_s=0.0,
            peak_bytes=2**30 if fits else 64 * 2**30,
        )

    best, _ = search(
        evaluate, knobs={"fsdp": ("zero1", "zero3")}, log=None
    )
    assert best.scheme.fsdp == "zero3"  # fitting beats fast-but-OOM

def test_hep_shard_transfer_split_in_cost():
    """h2d/d2h staging is priced separately from the on-device step and
    can flip the argmin toward a transfer-lighter scheme."""
    t = ShardTrial(
        scheme=ShardScheme(), compute_s=1.0, memory_s=0.5,
        collective_s=0.1, peak_bytes=2**30, h2d_s=0.2, d2h_s=0.05,
    )
    assert t.kernel_s == pytest.approx(1.1)
    assert t.transfer_s == pytest.approx(0.25)
    assert t.cost == pytest.approx(1.35)

    def evaluate(s: ShardScheme) -> ShardTrial:
        heavy = s.fsdp == "zero1"  # faster kernel, much heavier staging
        return ShardTrial(
            scheme=s, compute_s=0.1 if heavy else 0.12,
            memory_s=0.0, collective_s=0.0, peak_bytes=2**30,
            h2d_s=0.5 if heavy else 0.0, d2h_s=0.0,
        )

    best, _ = search(
        evaluate, knobs={"fsdp": ("zero1", "zero3")}, log=None
    )
    assert best.scheme.fsdp == "zero3"


def test_hep_shard_all_failing_knob_skipped():
    """A knob whose every candidate value fails evaluation must be
    skipped, not crash the search with min() on an empty list."""
    def evaluate(s: ShardScheme) -> ShardTrial:
        if s.tp:
            raise RuntimeError("tp unsupported on this mesh")
        return ShardTrial(
            scheme=s, compute_s=1.0, memory_s=0.0,
            collective_s=0.0, peak_bytes=2**30,
        )

    best, _ = search(
        evaluate, ShardScheme(tp=False), knobs={"tp": (True,)}, log=None
    )
    assert best.scheme.tp is False
