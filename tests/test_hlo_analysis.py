"""HLO-text analyzer: trip-count extraction, multiplicity propagation,
collective/flop accounting — validated on synthetic HLO snippets and on
a real compiled program with known structure."""

import textwrap

import pytest

from repro.launch import hlo_analysis as H

_SYNTH = textwrap.dedent("""
    HloModule jit_f

    %add (a: f32[], b: f32[]) -> f32[] {
      ROOT %r = f32[] add(%a, %b)
    }

    %cond (p: (s32[], f32[8])) -> pred[] {
      %c = s32[] constant(5)
      %i = s32[] get-tuple-element(%p), index=0
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    %body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
      %x = f32[8]{0} get-tuple-element(%p), index=1
      %ar = f32[8]{0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%add
      %one = s32[] constant(1)
      %i2 = s32[] get-tuple-element(%p), index=0
      %ip = s32[] add(%i2, %one)
      ROOT %t = (s32[], f32[8]) tuple(%ip, %ar)
    }

    ENTRY %main (x: f32[8]) -> f32[8] {
      %zero = s32[] constant(0)
      %t0 = (s32[], f32[8]) tuple(%zero, %x)
      %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
      %ag = f32[16]{0} all-gather(%x), replica_groups=[4,2]<=[8], dimensions={0}
      ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
    }
""")


def test_trip_count_and_multiplicity():
    comps, mult = H.computation_multiplicity(_SYNTH)
    assert mult["main"] == 1.0
    assert mult["body"] == 5.0          # constant(5) in %cond
    ws = H.while_summary(_SYNTH)
    assert ws == [{"in": "main", "body": "body", "trip": 5}]


def test_collective_bytes_trip_corrected():
    stats = H.collective_bytes(_SYNTH, 8)
    # all-reduce: 8 f32 = 32B x ring 2*(4-1)/4 x 5 trips = 240
    assert stats.bytes_by_kind["all-reduce"] == pytest.approx(240.0)
    assert stats.count_by_kind["all-reduce"] == 5.0
    # all-gather: 16 f32 out = 64B x (2-1)/2 x 1 = 32
    assert stats.bytes_by_kind["all-gather"] == pytest.approx(32.0)


def test_shape_bytes_tuples_and_dtypes():
    assert H._shape_bytes("f32[2,3]") == 24
    assert H._shape_bytes("bf16[4]") == 8
    assert H._shape_bytes("(s32[], f32[2,2]{1,0}, pred[3])") == 4 + 16 + 3
    assert H._shape_bytes("u8[]") == 1


def test_real_program_scan_accounting():
    """dot_flops on a compiled scan must count trips: scan of L matmuls
    => exactly L x per-iteration flops (single-device => no sharding)."""
    import jax
    import jax.numpy as jnp

    L, N = 6, 32

    def f(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        return jax.lax.scan(body, x, w)[0]

    txt = (
        jax.jit(f)
        .lower(
            jax.ShapeDtypeStruct((L, N, N), jnp.float32),
            jax.ShapeDtypeStruct((4, N), jnp.float32),
        )
        .compile()
        .as_text()
    )
    flops = H.dot_flops(txt)
    want = L * 2 * 4 * N * N
    assert flops == pytest.approx(want, rel=0.01), (flops, want)


def test_hbm_bytes_positive_and_bounded():
    import jax
    import jax.numpy as jnp

    def f(x):
        return jnp.tanh(x) @ jnp.ones((64, 64))

    txt = (
        jax.jit(f)
        .lower(jax.ShapeDtypeStruct((64, 64), jnp.float32))
        .compile()
        .as_text()
    )
    b = H.hbm_bytes(txt)
    assert 0 < b < 10e6  # a few tensors of 16KB each, 2x counted
