"""Per-architecture smoke tests: reduced same-family configs run one
forward + one train step on CPU, asserting shapes and no NaNs; decode
path checked against the full forward for one arch per family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models.steps import make_train_step
from repro.models.transformer import (
    forward, init_cache, init_params,
)
from repro.optim import adamw


@pytest.mark.parametrize("arch", C.ARCH_NAMES)
def test_smoke_forward_and_train(arch):
    cfg = C.get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    fe = (
        jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frontend_embeds, cfg.d_model)
        ).astype(cfg.dtype)
        if cfg.n_frontend_embeds
        else None
    )
    logits, _, _ = forward(cfg, params, toks, frontend_embeds=fe)
    total = S + cfg.n_frontend_embeds
    assert logits.shape == (B, total, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt = adamw(1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    batch = {"tokens": toks, "labels": toks}
    if fe is not None:
        batch["frontend_embeds"] = fe
    p2, o2, m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(
            lambda a, b: bool(jnp.any(a != b)), params, p2
        ),
    )
    assert moved


@pytest.mark.parametrize("arch", C.ARCH_NAMES)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact assigned hyperparams."""
    cfg = C.get(arch)
    expected = {
        "deepseek_moe_16b": (28, 2048, 16, 16, 1408, 102_400),
        "grok_1_314b": (64, 6144, 48, 8, 32_768, 131_072),
        "zamba2_7b": (81, 3584, 32, 32, 14_336, 32_000),
        "llava_next_mistral_7b": (32, 4096, 32, 8, 14_336, 32_000),
        "qwen2_5_14b": (48, 5120, 40, 8, 13_824, 152_064),
        "olmo_1b": (16, 2048, 16, 16, 8192, 50_304),
        "minitron_8b": (32, 4096, 32, 8, 16_384, 256_000),
        "qwen2_0_5b": (24, 896, 14, 2, 4864, 151_936),
        "mamba2_130m": (24, 768, 1, 1, 0, 50_280),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected, f"{arch}: {got} != {expected}"
    if arch == "deepseek_moe_16b":
        assert (cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.n_shared) == (64, 6, 2)
    if arch == "grok_1_314b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (8, 2)
    if arch == "zamba2_7b":
        assert cfg.ssm.d_state == 64 and cfg.subquadratic
    if arch == "mamba2_130m":
        assert cfg.ssm.d_state == 128 and cfg.subquadratic


@pytest.mark.parametrize("arch", C.ARCH_NAMES)
def test_input_specs_all_cells(arch):
    cfg = C.get(arch)
    for shape in C.SHAPES:
        if not C.cell_supported(cfg, shape):
            assert shape == "long_500k"
            continue
        specs = C.input_specs(cfg, shape)
        sh = C.SHAPES[shape]
        if sh.kind == "train":
            assert specs["tokens"].shape[0] == sh.batch
            assert (
                specs["tokens"].shape[1] + cfg.n_frontend_embeds == sh.seq
            )
        elif sh.kind == "decode":
            assert specs["token"].shape == (sh.batch, 1)
            if "k" in specs["cache"]:
                assert specs["cache"]["k"].shape[2] == sh.seq


def test_param_counts_order_of_magnitude():
    """6ND sanity: headline parameter counts are in the right range."""
    expect = {
        "grok_1_314b": (280e9, 360e9),
        "deepseek_moe_16b": (14e9, 20e9),
        "qwen2_5_14b": (13e9, 16e9),
        "olmo_1b": (0.9e9, 1.4e9),
        "qwen2_0_5b": (0.4e9, 0.65e9),
        "mamba2_130m": (0.10e9, 0.17e9),
        "zamba2_7b": (6e9, 9e9),
        "minitron_8b": (7e9, 10e9),
        "llava_next_mistral_7b": (6.5e9, 8e9),
        "musicgen_medium": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = C.get(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "mamba2_130m", "zamba2_7b",
                                  "deepseek_moe_16b"])
def test_smoke_decode_matches_full(arch):
    """One arch per family: single-token decode == teacher-forced full
    forward at the same position."""
    cfg = C.get_smoke(arch)
    if cfg.moe:  # avoid capacity-drop nondeterminism in the check
        cfg = type(cfg)(**{
            **cfg.__dict__,
            "moe": type(cfg.moe)(**{
                **cfg.moe.__dict__, "capacity_factor": 16.0
            }),
        })
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    logits_full, _, _ = forward(cfg, params, toks)
    _, cache, _ = forward(cfg, params, toks[:, : S - 1], return_cache=True)
    full = init_cache(cfg, B, S + 4)
    for k in ("k", "v"):
        if k in full:
            full[k] = jax.lax.dynamic_update_slice(
                full[k], cache[k].astype(full[k].dtype), (0, 0, 0, 0, 0)
            )
    for k in ("conv_x", "conv_bc", "ssd"):
        if k in full:
            full[k] = cache[k].astype(full[k].dtype)
    full["len"] = jnp.asarray(S - 1, jnp.int32)
    dec, _, _ = forward(cfg, params, toks[:, S - 1 : S], cache=full)
    a = np.asarray(logits_full[:, S - 1, :], np.float32)
    b = np.asarray(dec[:, 0, :], np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < 1e-4, rel
