"""Property tests on LM-substrate invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.config import ModelConfig
from repro.models.mamba2 import causal_conv1d, ssd_chunked
from repro.models.modules import (
    chunked_attention, chunked_attention_kv_parallel, rope,
)
from repro.models.moe import capacity, route
from repro.models.transformer import forward, init_params


def _tiny_dense(vocab=97):
    return ModelConfig(
        name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=vocab, dtype="float32", remat=False,
    )


def test_causality_future_tokens_do_not_affect_past_logits():
    cfg = _tiny_dense()
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    l1, _, _ = forward(cfg, params, toks)
    toks2 = toks.at[:, 8:].set((toks[:, 8:] + 1) % cfg.vocab)
    l2, _, _ = forward(cfg, params, toks2)
    np.testing.assert_allclose(
        np.asarray(l1[:, :8]), np.asarray(l2[:, :8]), atol=1e-5
    )
    assert not np.allclose(np.asarray(l1[:, 8:]), np.asarray(l2[:, 8:]))


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([2, 4, 8, 16]))
def test_ssd_chunk_size_invariance(chunk):
    """The chunked SSD must compute the same function for any chunk."""
    key = jax.random.PRNGKey(3)
    B, S, H, P, N = 2, 16, 3, 4, 8
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, 1, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, 1, N))
    y_ref, h_ref = ssd_chunked(x, dt, A, Bm, Cm, chunk=S)  # one chunk
    y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h),
                               rtol=2e-4, atol=2e-4)


def test_causal_conv_matches_explicit():
    key = jax.random.PRNGKey(5)
    B, S, C, K = 2, 10, 3, 4
    x = jax.random.normal(key, (B, S, C))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, C))
    b = jax.random.normal(jax.random.fold_in(key, 2), (C,))
    y = causal_conv1d(x, w, b)
    # explicit: y[t] = b + sum_i w[i] * x[t-K+1+i]
    for t in (0, 3, 9):
        want = b.copy()
        for i in range(K):
            src = t - (K - 1 - i)
            if src >= 0:
                want = want + w[i] * x[0, src]
        np.testing.assert_allclose(np.asarray(y[0, t]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(1, 4),
)
def test_route_gates_normalized_and_topk(seed, k):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (16, 8))
    gates, ids = route(logits, k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)),
                               np.ones(16), rtol=1e-5)
    assert np.asarray(gates).min() >= 0
    # ids are the true top-k of softmax(logits) == top-k of logits
    want = np.argsort(-np.asarray(logits), axis=-1)[:, :k]
    assert np.array_equal(np.sort(np.asarray(ids), -1), np.sort(want, -1))


def test_capacity_scales_with_tokens():
    from repro.models.config import MoEConfig
    cfg = ModelConfig(
        name="m", family="moe", n_layers=1, d_model=8, n_heads=1,
        n_kv_heads=1, d_ff=8, vocab=16,
        moe=MoEConfig(n_experts=8, top_k=2),
    )
    assert capacity(cfg, 1024) > capacity(cfg, 64)
    assert capacity(cfg, 64) >= 4


def test_rope_preserves_norm_and_relative_phase():
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (1, 6, 2, 16))
    pos = jnp.arange(6)[None, :]
    y = rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # dot(q_i, k_j) depends only on i-j: shift positions by 7
    q, k = x[:, :3], x[:, 3:]
    d1 = jnp.einsum(
        "bshd,bthd->bhst", rope(q, pos[:, :3], 1e4), rope(k, pos[:, :3] + 2, 1e4)
    )
    d2 = jnp.einsum(
        "bshd,bthd->bhst",
        rope(q, pos[:, :3] + 7, 1e4), rope(k, pos[:, :3] + 9, 1e4),
    )
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_parts", [2, 4, 8])
def test_kv_parallel_attention_matches_chunked(n_parts):
    key = jax.random.PRNGKey(11)
    B, S, H, Hkv, D = 2, 64, 6, 2, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    a = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    b = chunked_attention_kv_parallel(
        q, k, v, causal=True, q_chunk=16, n_kv_parts=n_parts
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
