"""Substrate tests: optimizers, schedules, compression, checkpointing,
failure recovery, deterministic data resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.ckpt import (
    CheckpointManager, latest_step, restore_checkpoint, save_checkpoint,
)
from repro.data import ShardedBatcher, make_token_stream
from repro.optim import (
    Int8ErrorFeedback, adamw, clip_by_global_norm, compress_bf16,
    cosine_schedule, decompress_bf16, linear_warmup_cosine, lion, sgd,
)
from repro.runtime.loop import InjectedFailure, LoopConfig, TrainLoop


# --------------------------- optimizers -----------------------------------


def _quadratic_problem():
    target = jnp.asarray([1.5, -2.0, 0.5])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return {"w": jnp.zeros(3)}, loss, target


@pytest.mark.parametrize("maker", [
    lambda: adamw(0.1),
    lambda: sgd(0.1, momentum=0.9),
    lambda: sgd(0.1, momentum=0.9, nesterov=True),
    lambda: lion(0.02),
])
def test_optimizers_converge(maker):
    params, loss, target = _quadratic_problem()
    opt = maker()
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target), atol=0.05)


def test_adamw_state_dtype_bf16():
    opt = adamw(0.1, state_dtype=jnp.bfloat16)
    params = {"w": jnp.zeros(4)}
    st_ = opt.init(params)
    assert st_.inner["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4)}
    p2, st2 = opt.update(g, st_, params)
    assert st2.inner["v"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((2,), -10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_schedules():
    s1 = cosine_schedule(1.0, 100)
    assert float(s1(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(s1(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)
    s2 = linear_warmup_cosine(1.0, 10, 110)
    assert float(s2(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(s2(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)


def test_bf16_compression_roundtrip():
    g = {"w": jnp.linspace(-3, 3, 64)}
    back = decompress_bf16(compress_bf16(g))
    assert back["w"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(back["w"]),
                               np.asarray(g["w"]), atol=0.02)


def test_int8_error_feedback_unbiased_over_steps():
    """Error feedback: repeated compression of a constant gradient must
    converge to the true value on average."""
    g = {"w": jnp.asarray([0.3, -0.7, 1.1, 0.01])}
    ef = Int8ErrorFeedback.init(g)
    acc = jnp.zeros(4)
    n = 200
    for _ in range(n):
        payload, scales, ef = ef.compress(g)
        acc = acc + Int8ErrorFeedback.decompress(payload, scales)["w"]
    np.testing.assert_allclose(
        np.asarray(acc / n), np.asarray(g["w"]), atol=1e-2
    )


# --------------------------- checkpointing --------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.int32)},
    }
    save_checkpoint(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    back = restore_checkpoint(tmp_path, 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": jnp.ones((4,))}
    p = save_checkpoint(tmp_path, 1, tree)
    # corrupt the array file
    arrs = dict(np.load(p / "arrays.npz"))
    arrs["a0"] = arrs["a0"] + 1
    np.savez(p / "arrays.npz", **arrs)
    with pytest.raises(ValueError, match="checksum"):
        restore_checkpoint(tmp_path, 1, tree)


def test_checkpoint_keep_n_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, save_every=1, keep=2)
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        mgr.save(s, tree)
    steps = sorted(
        int(p.name.split("_")[1]) for p in tmp_path.iterdir()
    )
    assert steps == [4, 5]


def test_checkpoint_tmp_never_visible(tmp_path):
    save_checkpoint(tmp_path, 3, {"a": jnp.zeros(3)})
    assert not list(tmp_path.glob("*.tmp-*"))


# --------------------------- failure recovery -----------------------------


def _toy_loop(tmp_path, inject_at=None, total=12):
    opt = adamw(0.05)
    target = jnp.asarray([2.0, -1.0])

    def step_fn(state, batch):
        params, ost = state

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2) + 0.0 * jnp.sum(batch)

        g = jax.grad(loss)(params)
        params, ost = opt.update(g, ost, params)
        return (params, ost), {"loss": loss(params)}

    params = {"w": jnp.zeros(2)}
    state = (params, opt.init(params))
    cfg = LoopConfig(
        total_steps=total, ckpt_dir=str(tmp_path / "ckpt"),
        save_every=4, inject_failure_at=inject_at,
    )
    return TrainLoop(step_fn, lambda s: jnp.ones(2) * s, state, cfg)


def test_loop_recovers_identically_after_failure(tmp_path):
    # uninterrupted run
    ref = _toy_loop(tmp_path / "ref")
    ref_out = ref.run()
    ref_final = np.asarray(ref.state[0]["w"])

    # interrupted at step 6 (checkpoint at 4), then relaunched
    crash = _toy_loop(tmp_path / "crash", inject_at=6)
    with pytest.raises(InjectedFailure):
        crash.run()
    resumed = _toy_loop(tmp_path / "crash")
    out = resumed.run()
    assert resumed.start_step in (4, 8)  # restored from a checkpoint
    np.testing.assert_allclose(
        np.asarray(resumed.state[0]["w"]), ref_final, atol=1e-6
    )
    assert out["final_step"] == ref_out["final_step"]


# --------------------------- data pipeline --------------------------------


@settings(max_examples=25, deadline=None)
@given(step=st.integers(0, 10_000), seed=st.integers(0, 2**31 - 1))
def test_batcher_deterministic_resume(step, seed):
    bt = ShardedBatcher(n=1000, global_batch=32, seed=seed)
    assert np.array_equal(bt.indices(step), bt.indices(step))


def test_batcher_shards_partition_global_batch():
    shards = [
        ShardedBatcher(n=100, global_batch=16, seed=1,
                       shard_index=i, num_shards=4)
        for i in range(4)
    ]
    full = ShardedBatcher(n=100, global_batch=16, seed=1)
    got = np.concatenate([s.indices(5) for s in shards])
    assert np.array_equal(got, full.indices(5))


def test_token_stream_resumable_and_learnable_structure():
    sample = make_token_stream(0, vocab=50, order=1)
    a = sample(3, 4, 16)
    b = sample(3, 4, 16)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    c = sample(4, 4, 16)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert int(a.max()) < 50 and int(a.min()) >= 0
