"""Launch/parallel dry-run paths: shape-cell gating, size-adaptive
sharding schemes, batch-axis selection, roofline attribution, and
real NamedSharding construction on a debug mesh — everything that can
run with one CPU device and ShapeDtypeStruct stand-ins (no compile,
no 512-device subprocess).
"""

from types import SimpleNamespace

import numpy as np
import pytest

import jax  # must initialize before repro.launch.dryrun sets XLA_FLAGS
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as C
from repro.launch.dryrun import roofline_terms, run_cell
from repro.launch.mesh import make_debug_mesh
from repro.models.transformer import param_specs
from repro.parallel.sharding import (
    ShardScheme,
    batch_axes,
    default_scheme,
    make_batch_shardings,
    make_cache_shardings,
    make_opt_shardings,
    make_param_shardings,
)


def fake_mesh(**axis_sizes):
    """axis_names + devices.shape is all the pure helpers consult."""
    return SimpleNamespace(
        axis_names=tuple(axis_sizes),
        devices=np.zeros(tuple(axis_sizes.values())),
    )


# ---------------------------------------------------------------------------
# configs registry and shape cells
# ---------------------------------------------------------------------------


def test_canonical_normalizes_and_rejects():
    assert C.canonical("qwen2.5-14b") == "qwen2_5_14b"
    assert C.canonical("olmo_1b") == "olmo_1b"
    with pytest.raises(KeyError):
        C.canonical("gpt-17")


def test_cell_supported_gates_long_context():
    assert C.cell_supported(C.get("mamba2_130m"), "long_500k")
    assert not C.cell_supported(C.get("olmo_1b"), "long_500k")
    assert C.cell_supported(C.get("olmo_1b"), "train_4k")


def test_run_cell_skips_unsupported_cell_before_any_mesh():
    """A full-attention arch on the 500k cell is skipped by design —
    and the skip path must trigger before mesh construction, so it
    runs on a 1-device host."""
    r = run_cell("olmo_1b", "long_500k", multi_pod=False)
    assert r["status"] == "skipped"
    assert r["arch"] == "olmo_1b" and r["shape"] == "long_500k"
    assert "sub-quadratic" in r["reason"]


def test_input_specs_allocate_nothing():
    cfg = C.get_smoke("olmo_1b")
    specs = C.input_specs(cfg, "train_4k")
    assert set(specs) == {"tokens", "labels"}
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in specs.values())
    assert specs["tokens"].shape == (256, 4096)
    decode = C.input_specs(cfg, "decode_32k")
    assert decode["token"].shape == (128, 1)
    assert isinstance(decode["cache"], dict)


# ---------------------------------------------------------------------------
# scheme selection and batch-axis choice (pure helpers, fake meshes)
# ---------------------------------------------------------------------------


def test_default_scheme_is_size_adaptive():
    small = default_scheme(C.get("olmo_1b"))          # ~1B
    assert small.tp is False and small.fsdp == "zero1"
    assert small.batch_over_model is True
    mid = default_scheme(C.get("qwen2_5_14b"))        # ~14B
    assert mid.tp is True and mid.fsdp == "zero1"
    big = default_scheme(C.get("grok_1_314b"))        # ~314B
    assert big.tp is True and big.fsdp == "zero3"


def test_batch_axes_prefers_largest_dividing_subset():
    mesh = fake_mesh(data=16, model=16)
    plain = ShardScheme(batch_over_model=False)
    folded = ShardScheme(batch_over_model=True)
    assert batch_axes(mesh, plain, 256) == ("data",)
    assert batch_axes(mesh, folded, 256) == ("data", "model")
    # batch indivisible by every candidate: replicate, never crash
    assert batch_axes(mesh, plain, 3) == ()
    assert batch_axes(mesh, folded, 3) == ()


def test_batch_axes_multi_pod_engages_model_before_idling_it():
    mesh = fake_mesh(pod=2, data=16, model=16)
    folded = ShardScheme(batch_over_model=True)
    # 512 divides pod*data*model
    assert batch_axes(mesh, folded, 512) == ("pod", "data", "model")
    # 256 cannot span all 512 chips; ('data','model') beats ('pod','data')
    assert batch_axes(mesh, folded, 256) == ("data", "model")
    plain = ShardScheme(batch_over_model=False)
    assert batch_axes(mesh, plain, 32) == ("pod", "data")


def test_resolve_expert_mode():
    moe = C.get("deepseek_moe_16b")
    assert moe.moe is not None
    if moe.moe.n_experts % 16 == 0:
        assert ShardScheme().resolve_expert_mode(moe, 16) == "ep"
    assert ShardScheme().resolve_expert_mode(moe, 7) == (
        "ep" if moe.moe.n_experts % 7 == 0 else "tp"
    )
    assert ShardScheme(expert_mode="tp").resolve_expert_mode(moe, 16) == "tp"
    dense = C.get("olmo_1b")
    assert ShardScheme().resolve_expert_mode(dense, 16) == "tp"


# ---------------------------------------------------------------------------
# real shardings on a debug mesh (1x1 — always divisible, 1 device)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def debug_mesh():
    return make_debug_mesh((1, 1))


def test_param_shardings_cover_the_tree(debug_mesh):
    cfg = C.get_smoke("olmo_1b")
    tree = param_specs(cfg)
    sh = make_param_shardings(cfg, debug_mesh, tree)
    leaves = jax.tree.leaves(sh)
    assert leaves and all(isinstance(s, NamedSharding) for s in leaves)
    # same tree structure as the params
    assert jax.tree.structure(sh) == jax.tree.structure(tree)


def test_opt_shardings_zero1_and_unknown_kind(debug_mesh):
    cfg = C.get_smoke("olmo_1b")
    tree = param_specs(cfg)
    opt = make_opt_shardings(cfg, debug_mesh, tree, kind="adamw")
    assert isinstance(opt.step, NamedSharding)
    assert opt.step.spec == P()              # scalars replicated
    assert set(opt.inner) == {"m", "v"}
    sgd = make_opt_shardings(cfg, debug_mesh, tree, kind="sgd")
    assert jax.tree.structure(sgd.inner) == jax.tree.structure(tree)
    with pytest.raises(ValueError):
        make_opt_shardings(cfg, debug_mesh, tree, kind="adafactor")


def test_batch_shardings_for_every_cell_kind(debug_mesh):
    cfg = C.get_smoke("olmo_1b")
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        specs = C.input_specs(cfg, shape)
        sh = make_batch_shardings(cfg, debug_mesh, specs)
        assert set(sh) == set(specs)
        for k, v in sh.items():
            if k == "cache":
                assert all(
                    isinstance(s, NamedSharding) for s in v.values()
                )
            else:
                assert isinstance(v, NamedSharding)


def test_cache_shardings_replicate_len(debug_mesh):
    cfg = C.get_smoke("olmo_1b")
    cache = C.input_specs(cfg, "decode_32k")["cache"]
    sh = make_cache_shardings(cfg, debug_mesh, cache)
    assert set(sh) == set(cache)
    assert sh["len"].spec == P()
    for k in ("k", "v"):
        assert isinstance(sh[k], NamedSharding)


def test_decode_replicate_batch_pins_token_replicated(debug_mesh):
    cfg = C.get_smoke("olmo_1b")
    specs = C.input_specs(cfg, "decode_32k")
    scheme = ShardScheme(decode_replicate_batch=True)
    sh = make_batch_shardings(cfg, debug_mesh, specs, scheme)
    assert sh["token"].spec == P()


# ---------------------------------------------------------------------------
# roofline attribution (pure arithmetic over a recorded result)
# ---------------------------------------------------------------------------


def _fake_result(*, flops, bytes_, coll, devices=256):
    return {
        "devices": devices,
        "collectives": {"per_device_bytes": coll},
        "per_device": {"hlo_flops": flops, "hlo_bytes": bytes_},
    }


def test_roofline_terms_pick_the_dominant_resource():
    cfg = C.get("olmo_1b")
    compute_bound = roofline_terms(
        _fake_result(flops=1e15, bytes_=1e9, coll=1e9), cfg, "train_4k"
    )
    assert compute_bound["dominant"] == "compute"
    coll_bound = roofline_terms(
        _fake_result(flops=1e12, bytes_=1e9, coll=1e12), cfg, "train_4k"
    )
    assert coll_bound["dominant"] == "collective"
    # useful_ratio compares model flops to total HLO flops
    sh = C.SHAPES["train_4k"]
    expect = 2 * 3 * cfg.n_active_params() * sh.batch * sh.seq
    assert compute_bound["model_flops"] == expect
    assert compute_bound["useful_ratio"] == pytest.approx(
        expect / (1e15 * 256)
    )
    zero = roofline_terms(
        _fake_result(flops=0.0, bytes_=0.0, coll=0.0), cfg, "decode_32k"
    )
    assert zero["useful_ratio"] == 0.0
