"""Tests for tools/check_coverage.py — the CI coverage-floor gate.

The gate is pure stdlib (it parses the ``coverage.json`` document
pytest-cov writes, it does not import coverage.py), so these tests run
everywhere tier-1 runs, including boxes without pytest-cov installed.
Synthetic reports are built inline; the shape mirrors pytest-cov's
``--cov-report=json`` output: ``files.<path>.summary`` with
``covered_lines`` / ``num_statements``, plus ``totals``.
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

import check_coverage  # noqa: E402


def _report():
    return {
        "files": {
            "src/repro/estimator/latency.py": {
                "summary": {"covered_lines": 95, "num_statements": 100}
            },
            "src/repro/estimator/features.py": {
                "summary": {"covered_lines": 90, "num_statements": 100}
            },
            "src/repro/core/mapper.py": {
                "summary": {"covered_lines": 40, "num_statements": 100}
            },
        },
        "totals": {
            "covered_lines": 225,
            "num_statements": 300,
            "percent_covered": 75.0,
        },
    }


def test_path_floor_met():
    fails = check_coverage.check(
        _report(), [("src/repro/estimator", 90.0)], None
    )
    assert fails == []  # (95 + 90) / 200 = 92.5%


def test_path_floor_violated_reports_aggregate():
    fails = check_coverage.check(
        _report(), [("src/repro/estimator", 95.0)], None
    )
    assert len(fails) == 1
    assert "92.5%" in fails[0] and "src/repro/estimator" in fails[0]


def test_prefix_matches_with_or_without_src():
    # report paths carry src/, the floor spec may not (or vice versa)
    fails = check_coverage.check(
        _report(), [("repro/estimator", 90.0)], None
    )
    assert fails == []
    stripped = {
        "files": {
            "repro/estimator/latency.py": {
                "summary": {"covered_lines": 99, "num_statements": 100}
            }
        },
        "totals": {"percent_covered": 99.0},
    }
    assert check_coverage.check(
        stripped, [("src/repro/estimator", 90.0)], None
    ) == []


def test_prefix_is_a_path_component_boundary():
    # repro/estimator must not swallow repro/estimator_extras
    report = {
        "files": {
            "src/repro/estimator_extras/x.py": {
                "summary": {"covered_lines": 0, "num_statements": 100}
            },
            "src/repro/estimator/latency.py": {
                "summary": {"covered_lines": 100, "num_statements": 100}
            },
        },
        "totals": {"percent_covered": 50.0},
    }
    assert check_coverage.check(
        report, [("src/repro/estimator", 90.0)], None
    ) == []


def test_unmatched_prefix_is_a_failure():
    # a floor over an unmeasured package must fail loudly, not pass
    fails = check_coverage.check(
        _report(), [("src/repro/nonexistent", 90.0)], None
    )
    assert len(fails) == 1 and "no measured files" in fails[0]


def test_total_floor():
    assert check_coverage.check(_report(), [], 75.0) == []
    fails = check_coverage.check(_report(), [], 80.0)
    assert len(fails) == 1 and fails[0].startswith("TOTAL")


def test_total_floor_without_percent_field():
    report = _report()
    del report["totals"]["percent_covered"]
    assert check_coverage.check(report, [], 75.0) == []
    assert len(check_coverage.check(report, [], 76.0)) == 1


def test_main_cli_pass_and_fail(tmp_path, capsys):
    f = tmp_path / "coverage.json"
    f.write_text(json.dumps(_report()))
    rc = check_coverage.main([
        "--file", str(f),
        "--path-floor", "src/repro/estimator=90",
        "--total-floor", "70",
    ])
    assert rc == 0
    assert "all floors met" in capsys.readouterr().out
    rc = check_coverage.main([
        "--file", str(f),
        "--path-floor", "src/repro/estimator=99",
        "--total-floor", "99",
    ])
    assert rc == 1
    out = capsys.readouterr().out
    assert "src/repro/estimator" in out and "TOTAL" in out


def test_main_missing_report_fails(tmp_path, capsys):
    rc = check_coverage.main(["--file", str(tmp_path / "nope.json")])
    assert rc == 1
    assert "cannot read" in capsys.readouterr().out
