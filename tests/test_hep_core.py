"""HEP mapper (Algorithm 1) properties + end-to-end mapping pipeline."""

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.core.mapper import (
    EfficientConfiguration,
    best_uniform,
    map_efficient_configuration,
    uniform_total,
)
from repro.core.mapped_model import build_mapped_model
from repro.core.parallel_config import CONFIGS
from repro.core.profiler import ProfileTable, profile_bnn_model


def _random_table(rng, n_layers=5, batches=(1, 2, 4)):
    times = {
        b: [
            {c: float(rng.uniform(1e-6, 1e-3)) for c in CONFIGS}
            for _ in range(n_layers)
        ]
        for b in batches
    }
    return ProfileTable(
        "synthetic", tuple(batches),
        tuple(f"L{i+1}:C64" for i in range(n_layers)), times,
    )


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mapped_dominates_every_uniform(seed):
    """Alg.1 invariant: the efficient configuration's total is <= every
    uniform config's total at every batch size."""
    table = _random_table(np.random.default_rng(seed))
    ec = map_efficient_configuration(table)
    for cfg in CONFIGS:
        for b in table.batch_sizes:
            assert ec.expected_time_per_example <= uniform_total(
                table, cfg, b
            ) + 1e-12


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mapper_picks_per_layer_argmin(seed):
    table = _random_table(np.random.default_rng(seed))
    ec = map_efficient_configuration(table)
    b = ec.proper_batch_size
    for i, cfg in enumerate(ec.layer_configs):
        row = table.times[b][i]
        assert row[cfg] == min(row.values())
    # and the proper batch minimizes the summed minima
    def summin(bb):
        return sum(min(r.values()) for r in table.times[bb])
    assert summin(b) == min(summin(bb) for bb in table.batch_sizes)


def test_mapper_deterministic_and_json_roundtrip():
    table = _random_table(np.random.default_rng(0))
    e1 = map_efficient_configuration(table)
    e2 = map_efficient_configuration(table)
    assert e1 == e2
    back = EfficientConfiguration.from_json(e1.to_json())
    assert back == e1


@pytest.fixture(scope="module")
def small_profiled():
    m = build_model("fashion_mnist", scale=0.25)
    params = m.init(jax.random.PRNGKey(0))
    packed = pack_params(m.specs, params)
    table = profile_bnn_model(
        m, packed, batch_sizes=(1, 4), repeats=1
    )
    return m, packed, table


def test_profile_shape(small_profiled):
    m, _, table = small_profiled
    assert set(table.times.keys()) == {1, 4}
    assert len(table.times[1]) == len(m.specs)
    for row in table.times[1]:
        assert set(row) == set(CONFIGS)
        assert all(t > 0 for t in row.values())


def test_mapped_model_exact_and_dominates(small_profiled):
    m, packed, table = small_profiled
    ec = map_efficient_configuration(table)
    x = jax.random.uniform(
        jax.random.PRNGKey(1), (ec.proper_batch_size, 28, 28, 1)
    )
    xw = prepare_input_packed(x)
    ref = forward_packed(m.specs, packed, xw)
    fused = build_mapped_model(m, packed, ec, fused=True)
    faithful = build_mapped_model(m, packed, ec, fused=False)
    assert np.array_equal(np.asarray(fused(xw)), np.asarray(ref))
    assert np.array_equal(faithful(xw), np.asarray(ref))
    # paper's headline comparison: HEP config beats full-XYZ
    _, t_xyz = best_uniform(table, "XYZ")
    assert ec.expected_time_per_example <= t_xyz + 1e-12


def test_analytic_source_runs():
    m = build_model("fashion_mnist", scale=0.25)
    params = m.init(jax.random.PRNGKey(0))
    packed = pack_params(m.specs, params)
    table = profile_bnn_model(
        m, packed, batch_sizes=(1, 16), time_source="analytic"
    )
    ec = map_efficient_configuration(table)
    assert ec.proper_batch_size in (1, 16)
    # the analytic TPU model should keep tiny layers on the host
    kinds = {label.split(":")[1][:2] for label, c in zip(
        ec.layer_labels, ec.layer_configs) if c == "CPU"}
    assert kinds, "analytic model mapped nothing to CPU"
