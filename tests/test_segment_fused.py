"""Fused segment kernels (`repro.kernels.segment_fused`) + the
segment-scope registry surface: bit-exactness on both BNN
architectures, applicability caps, segment-row profiling, and
fused-vs-per-layer selection (analytic and measured)."""

from __future__ import annotations

import json

import jax
import numpy as np
import pytest

from repro.bnn import build_model
from repro.bnn.models import forward_packed, pack_params, prepare_input_packed
from repro.core.mapped_model import build_node_fns, build_segment_fns
from repro.core.mapper import (
    EfficientConfiguration,
    configuration_from_mapping,
)
from repro.core.parallel_config import CPU, FULL_GPU
from repro.core.plan import (
    PACKED,
    UNPACKED,
    build_plan,
    device_spans,
    fuse_configuration,
    select_fused_segments,
)
from repro.core.profiler import (
    ProfileTable,
    profile_bnn_model,
    profile_segment_variants,
)
from repro.kernels.registry import (
    DEFAULT_REGISTRY,
    PALLAS_INTERPRET_MAX_WORK,
    SCOPE_LAYER,
    SCOPE_SEGMENT,
    SEGMENT_VMEM_BUDGET,
    SegmentShape,
    current_platform,
    segment_shape_of,
)
from repro.kernels.segment_fused import (
    build_pallas_segment,
    build_xla_segment,
    encoded_shape,
    infer_in_encoding,
    segment_out_encoding,
)


def _setup(name, scale=0.25, batch=2):
    m = build_model(name, scale=scale)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    x = prepare_input_packed(
        jax.random.uniform(
            jax.random.PRNGKey(1), (batch, *m.input_hw, m.in_channels)
        )
    )
    return m, packed, x


# ---------------------------------------------------------------------------
# Encoding helpers
# ---------------------------------------------------------------------------


def test_encoded_shape():
    assert encoded_shape((4, 8, 8, 64), PACKED) == (4, 8, 8, 2)
    assert encoded_shape((4, 8, 8, 40), PACKED) == (4, 8, 8, 2)
    assert encoded_shape((4, 8, 8, 64), UNPACKED) == (4, 8, 8, 64)


def test_infer_and_out_encoding_follow_the_chain():
    m, _, _ = _setup("fashion_mnist")
    specs = m.specs
    # whole network: packed input, fc scores out (unpacked ints)
    assert infer_in_encoding(specs) == PACKED
    assert segment_out_encoding(specs, PACKED) == UNPACKED
    # a tail starting at a step layer consumes unpacked
    step_i = next(i for i, s in enumerate(specs) if s.kind == "step")
    assert infer_in_encoding(specs[step_i:]) == UNPACKED


# ---------------------------------------------------------------------------
# Bit-exactness on both architectures
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["fashion_mnist", "cifar10"])
def test_fused_segment_bitexact_whole_network(name):
    """Acceptance: both fused builders reproduce the reference packed
    forward exactly, on both BNN architectures."""
    m, packed, x = _setup(name)
    want = np.asarray(forward_packed(m.specs, packed, x))
    xla = build_xla_segment(tuple(m.specs), list(packed))
    assert np.array_equal(want, np.asarray(xla(x)))
    pallas = build_pallas_segment(
        tuple(m.specs), list(packed), interpret=True
    )
    assert np.array_equal(want, np.asarray(pallas(x)))


@pytest.mark.parametrize("name", ["fashion_mnist", "cifar10"])
def test_fused_segment_bitexact_tail_span(name):
    """Spans that start mid-network (unpacked input encoding) are
    bit-exact too — the encoding is inferred from the first layer."""
    m, packed, x = _setup(name)
    step_i = next(i for i, s in enumerate(m.specs) if s.kind == "step")
    head = build_xla_segment(tuple(m.specs[:step_i]), list(packed[:step_i]))
    mid = head(x)                      # unpacked pre-activations
    want = np.asarray(forward_packed(m.specs, packed, x))
    for builder in (
        build_xla_segment,
        lambda s, p: build_pallas_segment(s, p, interpret=True),
    ):
        tail = builder(tuple(m.specs[step_i:]), list(packed[step_i:]))
        assert np.array_equal(want, np.asarray(tail(mid)))


def test_registry_applicable_segments_bitexact():
    """Every variant the registry deems applicable for the segment
    shape executes bit-exactly (the autotuner's contract)."""
    m, packed, x = _setup("fashion_mnist")
    shape = segment_shape_of(m.specs, packed, int(x.shape[0]))
    variants = DEFAULT_REGISTRY.applicable_segments(
        shape, current_platform()
    )
    assert {v.name for v in variants} >= {"seg_xla"}
    want = np.asarray(forward_packed(m.specs, packed, x))
    for v in variants:
        fn = v.builder(tuple(m.specs), list(packed), PACKED)
        assert np.array_equal(want, np.asarray(fn(x))), v.name


# ---------------------------------------------------------------------------
# Registry scope rules
# ---------------------------------------------------------------------------


def test_scopes_partition_the_registry():
    seg_names = set(DEFAULT_REGISTRY.segment_names())
    assert {"seg_xla", "seg_pallas"} <= seg_names
    for name in seg_names:
        assert DEFAULT_REGISTRY.get(name).scope == SCOPE_SEGMENT
    # layer-scope applicability never returns segment variants: the
    # per-layer autotuner can't accidentally pick one
    from repro.kernels.registry import GemmShape

    layer_vs = DEFAULT_REGISTRY.applicable(
        GemmShape(b=2, p=16, n=64, kw=4), "tpu"
    )
    assert not ({v.name for v in layer_vs} & seg_names)
    for v in layer_vs:
        assert v.scope == SCOPE_LAYER


def test_seg_pallas_applicability_caps():
    small = SegmentShape(b=1, n_layers=3, work=1 << 10, vmem_bytes=1 << 20)
    assert "seg_pallas" in {
        v.name
        for v in DEFAULT_REGISTRY.applicable_segments(small, "tpu")
    }
    over_work = SegmentShape(
        b=1, n_layers=3,
        work=PALLAS_INTERPRET_MAX_WORK + 1, vmem_bytes=1 << 20,
    )
    # interpret-mode cap binds off-TPU only
    assert "seg_pallas" not in {
        v.name
        for v in DEFAULT_REGISTRY.applicable_segments(over_work, "cpu")
    }
    assert "seg_pallas" in {
        v.name
        for v in DEFAULT_REGISTRY.applicable_segments(over_work, "tpu")
    }
    over_vmem = SegmentShape(
        b=1, n_layers=3, work=1 << 10,
        vmem_bytes=SEGMENT_VMEM_BUDGET + 1,
    )
    assert "seg_pallas" not in {
        v.name
        for v in DEFAULT_REGISTRY.applicable_segments(over_vmem, "tpu")
    }
    # seg_xla has no cap
    for shape in (small, over_work, over_vmem):
        assert "seg_xla" in {
            v.name
            for v in DEFAULT_REGISTRY.applicable_segments(shape, "cpu")
        }


# ---------------------------------------------------------------------------
# Segment-row profiling + selection
# ---------------------------------------------------------------------------


def _mixed_ec(m, packed, batch=2, time_source="analytic"):
    table = profile_bnn_model(
        m, packed, batch_sizes=(batch,), time_source=time_source
    )
    mapping = tuple(
        FULL_GPU if s.kind in ("conv", "fc") else CPU for s in m.specs
    )
    # put the elementwise layers between GEMMs on the device too so a
    # multi-layer device segment exists
    mapping = (mapping[0],) + tuple(
        FULL_GPU for _ in mapping[1:-1]
    ) + (mapping[-1],)
    return table, configuration_from_mapping(table, batch, mapping)


def test_profile_segment_variants_stores_rows_and_roundtrips():
    m, packed, x = _setup("fashion_mnist")
    table, ec = _mixed_ec(m, packed)
    spans = device_spans(ec)
    assert spans
    profile_segment_variants(
        m, packed, table, spans=spans, batch_sizes=(2,),
        time_source="analytic",
    )
    for start, stop in spans:
        names = table.segment_variants_for(2, start, stop)
        assert "seg_xla" in names
        for name in names:
            assert table.segment_time(2, start, stop, name) > 0.0
    again = ProfileTable.from_json(table.to_json())
    assert again.segment_times == table.segment_times
    with pytest.raises(KeyError):
        table.segment_time(2, 0, 1, "seg_xla")


def test_unprofiled_batch_rejected():
    m, packed, x = _setup("fashion_mnist")
    table, ec = _mixed_ec(m, packed)
    with pytest.raises(ValueError, match="not profiled"):
        profile_segment_variants(
            m, packed, table, spans=device_spans(ec),
            batch_sizes=(64,), time_source="analytic",
        )


def test_analytic_selection_prefers_fused_when_cheaper():
    """Acceptance: the analytic model prices a fused multi-layer device
    segment below its per-layer kernel sum (one dispatch instead of N),
    so selection records a fused variant and the fused plan is cheaper."""
    m, packed, x = _setup("fashion_mnist")
    table, ec = _mixed_ec(m, packed)
    fused = fuse_configuration(
        m, packed, table, ec, time_source="analytic"
    )
    multi = [
        (s, e) for (s, e) in device_spans(ec) if e - s > 1
    ]
    assert multi
    chosen = {(s, e): name for s, e, name, _ in fused.fused_segments}
    for span in multi:
        assert span in chosen
    base = build_plan(ec, mode="segments")
    plan = build_plan(fused, mode="segments")
    assert (
        plan.expected_time_per_example
        < base.expected_time_per_example
    )
    # per-layer attribution is untouched by fusion
    assert fused.per_layer_kernel_times == ec.per_layer_kernel_times
    assert fused.expected_time_per_example == ec.expected_time_per_example


def test_selection_ignores_variants_missing_from_registry():
    m, packed, x = _setup("fashion_mnist")
    table, ec = _mixed_ec(m, packed)
    spans = device_spans(ec)
    profile_segment_variants(
        m, packed, table, spans=spans, batch_sizes=(2,),
        time_source="analytic",
    )
    # poison the table with a variant no registry knows
    (start, stop) = spans[0]
    table.add_segment_row(2, start, stop, {"seg_ghost": 1e-12})
    fused = select_fused_segments(ec, table)
    assert all(
        name != "seg_ghost" for _, _, name, _ in fused.fused_segments
    )


def test_fused_execution_end_to_end_measured():
    """Measured path: profile segment variants, select, build the
    segments plan — fused nodes resolve through the registry and the
    full chain stays bit-exact."""
    m, packed, x = _setup("fashion_mnist")
    table, ec = _mixed_ec(m, packed, time_source="measured")
    fused = fuse_configuration(
        m, packed, table, ec, time_source="measured", repeats=1
    )
    want = np.asarray(forward_packed(m.specs, packed, x))
    out = x
    for node, fn in build_segment_fns(m, packed, fused):
        out = fn(out)
    assert np.array_equal(want, np.asarray(out))


def test_ec_json_roundtrip_with_fused_segments():
    m, packed, x = _setup("fashion_mnist")
    table, ec = _mixed_ec(m, packed)
    fused = fuse_configuration(
        m, packed, table, ec, time_source="analytic"
    )
    assert fused.fused_segments
    back = EfficientConfiguration.from_json(fused.to_json())
    assert back == fused
    # the key is emitted only when selection chose something, so
    # unfused configurations keep their exact legacy JSON shape
    d = json.loads(ec.to_json())
    assert "fused_segments" not in d
    assert EfficientConfiguration.from_json(
        ec.to_json()
    ).fused_segments == ()


def test_layer_scope_variant_rejected_as_fused():
    import dataclasses

    m, packed, x = _setup("fashion_mnist")
    table, ec = _mixed_ec(m, packed)
    (start, stop) = device_spans(ec)[0]
    bad = dataclasses.replace(
        ec, fused_segments=((start, stop, "xla_fused", 1e-6),)
    )
    plan = build_plan(bad, mode="segments")
    with pytest.raises(ValueError, match="scope"):
        build_node_fns(m, packed, bad, plan)
