"""Elastic BNN subsystem (docs/ARCHITECTURE.md §15): nested-width
subnet slicing (property-tested bit-exact against an independent pack
of the sliced fp weights), level-tagged store keys that never collide,
per-level planning (warm-start and predictor-estimated), the
ElasticEngine's batch-boundary level switches, the QualityController's
hysteresis state machine (pure fakes, no jax), and the cluster
controller's degrade-width-before-scale-up preference.
"""

import math
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.api import TenantPlan, map_model
from repro.bnn.layers import parse_notation
from repro.bnn.models import (
    BNNModel, forward_packed, pack_params, prepare_input_packed,
)
from repro.cluster import Cluster
from repro.elastic import (
    ElasticEngine,
    ElasticPlan,
    ElasticSpec,
    SubnetFamily,
    level_name,
    plan_family,
    slice_params_fp,
)
from repro.fleet.router import QualityController, Tenant
from repro.store import ProfileStore, model_signature

from tests._hypothesis_compat import given, settings, st
from tests.fixtures import FakeClock, flat_table
from tests.test_cluster import FakeEngine, fake_tenant

# 8x8 input, both convs above the 32-lane clamp so every fraction
# genuinely narrows them, two pool stages so the FC-after-FLAT slice
# exercises the strided (per-spatial-position) path
SMALL_NOTATION = (
    "C64", "MP4", "S", "C64", "MP2", "S", "FLAT", "FC128", "S", "FC10",
)


def small_model(name="elastic-small"):
    specs = tuple(parse_notation(SMALL_NOTATION, (8, 8), 1, 10))
    return BNNModel(name, specs, (8, 8), 1, 10)


def _family(m=None, packed=None, fractions=(1.0, 0.5), seed=0):
    m = m if m is not None else small_model()
    if packed is None:
        packed = pack_params(m.specs, m.init(jax.random.PRNGKey(seed)))
    return SubnetFamily.build(
        m, packed, ElasticSpec(fractions=fractions)
    )


# ---------------------------------------------------------------------------
# subnet slicing: the bit-exactness property
# ---------------------------------------------------------------------------


@settings(max_examples=6)
@given(
    fraction=st.sampled_from([0.75, 0.5, 0.25]),
    seed=st.integers(min_value=0, max_value=3),
)
def test_prefix_slice_bit_exact_vs_fresh_pack(fraction, seed):
    """The subsystem's core contract: slicing the *packed* words must
    equal packing the sliced *fp* weights — for every tensor, and for
    the end-to-end packed forward — at any fraction and any weights."""
    m = small_model()
    params = m.init(jax.random.PRNGKey(seed))
    packed = pack_params(m.specs, params)
    family = _family(m, packed, fractions=(1.0, fraction))
    narrow = family.level(1)
    fresh = pack_params(
        narrow.model.specs,
        slice_params_fp(m.specs, params, narrow.model.specs),
    )
    for i, (a, b) in enumerate(zip(narrow.packed, fresh)):
        assert set(a) == set(b), f"layer {i}: param keys diverge"
        for k in a:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), (
                f"layer {i} [{k}]: sliced packed != freshly packed"
            )
    x01 = jax.random.uniform(
        jax.random.PRNGKey(seed + 100), (2, 8, 8, 1)
    )
    xw = prepare_input_packed(x01)
    assert np.array_equal(
        np.asarray(forward_packed(narrow.model.specs, narrow.packed, xw)),
        np.asarray(forward_packed(narrow.model.specs, fresh, xw)),
    )


def test_family_levels_nest_and_level0_is_base():
    m = small_model()
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    family = _family(m, packed, fractions=(1.0, 0.5, 0.25))
    assert len(family) == 3
    assert family.base.model is m                 # same object, no copy
    assert family.base.packed[0] is packed[0]
    widths = [
        tuple(s.units for s in lvl.model.specs) for lvl in family
    ]
    for wide, narrow in zip(widths, widths[1:]):
        assert all(n <= w for w, n in zip(wide, narrow))
        assert narrow != wide
    # narrower conv weights are views into the base words (no copies)
    base_conv = np.asarray(family.base.packed[0]["w_words"])
    l1_conv = np.asarray(family.level(1).packed[0]["w_words"])
    assert l1_conv.shape[0] < base_conv.shape[0]


def test_family_rejects_fraction_that_clamps_to_duplicate_widths():
    # 0.25 and 0.2 both clamp every layer to the 32-lane floor
    with pytest.raises(ValueError, match="same widths"):
        _family(fractions=(1.0, 0.25, 0.2))


def test_elastic_spec_validates_fractions():
    with pytest.raises(ValueError, match="start at 1.0"):
        ElasticSpec(fractions=(0.5, 0.25))
    with pytest.raises(ValueError, match="decreasing"):
        ElasticSpec(fractions=(1.0, 0.5, 0.5))
    with pytest.raises(ValueError, match=r"\(0, 1\]"):
        ElasticSpec(fractions=(1.0, -0.5))
    with pytest.raises(ValueError, match="min_units"):
        ElasticSpec(fractions=(1.0, 0.5), min_units=48)


# ---------------------------------------------------------------------------
# level-tagged store keys
# ---------------------------------------------------------------------------


def test_level_store_keys_never_collide():
    family = _family(fractions=(1.0, 0.5, 0.25))
    assert family.names() == (
        "elastic-small", "elastic-small#L1", "elastic-small#L2",
    )
    assert level_name("m", 0) == "m"
    store = ProfileStore("mem://elastic-keys", fingerprint="fp")
    sigs = [model_signature(lvl.model) for lvl in family]
    assert len(set(sigs)) == len(sigs)
    prof_keys = {store.profile_key(s, (4,)) for s in sigs}
    map_keys = {store.mapping_key(s, "dp", 4) for s in sigs}
    assert len(prof_keys) == len(sigs) and len(map_keys) == len(sigs)
    # all K mappings live side by side in one store
    for lvl in family:
        store.save_mapping(
            map_model(flat_table(lvl.model, batch=4), policy="dp")
        )
    for lvl in family:
        got = store.load_mapping(lvl.model, policy="dp", batch=4)
        assert got is not None and got.model_name == lvl.model.name


# ---------------------------------------------------------------------------
# per-level planning
# ---------------------------------------------------------------------------


def test_plan_family_warm_starts_every_level_from_store():
    family = _family(fractions=(1.0, 0.5))
    store = ProfileStore("mem://elastic-warm", fingerprint="fp")
    for lvl in family:
        store.save_profile(flat_table(lvl.model, batch=4))
    plan = plan_family(family, batch_sizes=(4,), store=store)
    # every level's profile was a cache hit: zero profiling sweeps
    assert store.stats()["hits"] >= 2
    assert plan.predicted == (False, False)
    assert len(plan) == 2 and plan.batch == 4
    assert [tp.name for tp in plan.levels] == list(family.names())
    assert all(c.proper_batch_size == 4 for c in plan.configs)
    # mappings were persisted under their level-tagged keys
    for lvl in family:
        assert store.load_mapping(
            lvl.model, policy="dp", batch=4
        ) is not None


def test_plan_family_rejects_base_plan_for_other_model():
    family = _family()
    other = small_model(name="not-in-family")
    t = flat_table(other, batch=4)
    base = TenantPlan(
        name=other.name, model=other, packed=[], table=t,
        config=map_model(t),
    )
    with pytest.raises(ValueError, match="different model"):
        plan_family(family, base=base)


def test_plan_family_estimate_prices_narrow_levels_via_predictor():
    family = _family(fractions=(1.0, 0.5))
    store = ProfileStore("mem://elastic-est", fingerprint="fp")
    store.save_profile(flat_table(family.base.model, batch=4))

    predicted_names = []

    class _FakePredictor:
        def predict_table(self, model, batch_sizes, *, registry=None,
                          configs=None):
            predicted_names.append(model.name)
            return flat_table(model, batch=batch_sizes[0])

    store.load_predictor = lambda: _FakePredictor()
    plan = plan_family(
        family, batch_sizes=(4,), store=store, estimate=True
    )
    # level 0 is always real; the narrow level came from the predictor
    assert plan.predicted == (False, True)
    assert predicted_names == [family.level(1).model.name]
    # the predicted level's mapping persists, but no profile must ever
    # masquerade as measured under its store key
    assert store.load_mapping(
        family.level(1).model, policy="dp", batch=4
    ) is not None
    assert store.load_profile(family.level(1).model, (4,)) is None


def test_plan_family_estimate_falls_back_without_predictor():
    family = _family(fractions=(1.0, 0.5))
    store = ProfileStore("mem://elastic-fallback", fingerprint="fp")
    for lvl in family:
        store.save_profile(flat_table(lvl.model, batch=4))
    plan = plan_family(
        family, batch_sizes=(4,), store=store, estimate=True
    )
    assert plan.predicted == (False, False)   # real (warm) profiles


# ---------------------------------------------------------------------------
# ElasticEngine: level switches at batch boundaries
# ---------------------------------------------------------------------------


def _tiny_plan(batch=2):
    family = _family(fractions=(1.0, 0.5))
    levels = []
    for lvl in family:
        t = flat_table(lvl.model, batch=batch)
        levels.append(TenantPlan(
            name=lvl.model.name, model=lvl.model, packed=lvl.packed,
            table=t, config=map_model(t),
        ))
    return ElasticPlan(
        family=family, levels=tuple(levels), predicted=(False, False)
    )


def _refs(plan, xw):
    return [
        np.asarray(forward_packed(tp.model.specs, tp.packed, xw))
        for tp in plan.levels
    ]


def _engine(plan, batch=2, **kwargs):
    return ElasticEngine(
        plan, allowed_batch_sizes=(batch,), max_wait_s=0.0, **kwargs
    )


def test_engine_requires_two_levels():
    plan = _tiny_plan()
    single = ElasticPlan(
        family=plan.family, levels=plan.levels[:1], predicted=(False,)
    )
    with pytest.raises(ValueError, match="two subnet levels"):
        _engine(single)


def test_engine_set_level_publishes_and_serves_bit_exact():
    plan = _tiny_plan(batch=2)
    engine = _engine(plan)
    engine.warm()
    x01 = jax.random.uniform(jax.random.PRNGKey(5), (2, 8, 8, 1))
    xw = np.asarray(prepare_input_packed(x01))
    refs = _refs(plan, xw)
    for k in (0, 1, 0):                       # down and back up
        assert engine.set_level(k) is True
        assert engine.level == k
        assert engine.model.name == plan.levels[k].name
        reqs = [engine.submit(x) for x in xw]
        engine.step(force=True)
        for j, r in enumerate(reqs):
            assert np.array_equal(r.wait(timeout=30.0), refs[k][j]), (
                f"level {k}: response {j} not bit-exact"
            )
    assert engine.level_switches == 2
    assert 0.0 < engine.degraded_share < 1.0  # one of three steps


def test_engine_enforces_quality_floor_at_actuator():
    engine = _engine(_tiny_plan(), quality_floor=0)
    assert engine.quality_floor == 0
    assert not engine.can_degrade()
    with pytest.raises(ValueError, match="quality_floor"):
        engine.set_level(1)
    with pytest.raises(ValueError, match="outside"):
        engine.set_level(5)
    with pytest.raises(ValueError, match="quality_floor"):
        _engine(_tiny_plan(), quality_floor=7)


def test_engine_defers_level_switch_mid_step():
    engine = _engine(_tiny_plan())
    engine.warm()
    engine._in_step = True                     # simulate in-flight wave
    assert engine.set_level(1) is False
    assert engine.level == 0 and engine._pending_level == 1
    engine._in_step = False
    engine.step(force=True)                    # empty queue: boundary
    assert engine.level == 1 and engine._pending_level is None


def test_engine_routes_swap_by_model_name():
    plan = _tiny_plan(batch=2)
    engine = _engine(plan)
    new_l1 = map_model(
        flat_table(plan.levels[1].model, batch=2), policy="greedy"
    )
    assert engine.swap_configuration(new_l1) is True   # dormant level
    assert engine.level_config(1) is new_l1
    assert engine.config is engine.level_config(0)     # live untouched
    stranger = map_model(
        flat_table(small_model("stranger"), batch=2)
    )
    with pytest.raises(ValueError, match="no subnet level"):
        engine.swap_configuration(stranger)
    rebatched = map_model(flat_table(plan.levels[1].model, batch=4))
    with pytest.raises(ValueError, match="batch size"):
        engine.swap_configuration(rebatched)


# ---------------------------------------------------------------------------
# QualityController: hysteresis over pure fakes (no jax)
# ---------------------------------------------------------------------------


class _FakeElastic:
    """Duck-typed ElasticEngine: just the level axis, no serving."""

    def __init__(self, *, levels=3, floor=2, step_s=1.0, batch=4):
        self.quality_floor = floor
        self.level = 0
        self.level_switches = 0
        self.telemetry = None
        # narrower levels cost proportionally less, like a real plan
        self._configs = [
            SimpleNamespace(
                expected_time_per_example=step_s / (2 ** k),
                proper_batch_size=batch,
                segments=tuple,
            )
            for k in range(levels)
        ]
        self.batcher = SimpleNamespace(
            pending=lambda: 0, max_batch=batch
        )

    @property
    def config(self):
        return self._configs[self.level]

    def can_degrade(self):
        return self.level < self.quality_floor

    def can_restore(self):
        return self.level > 0

    def level_config(self, k):
        return self._configs[k]

    def set_level(self, k):
        self.level = int(k)
        self.level_switches += 1
        return True


def _quality_rig(*, deadline_s=math.inf, **engine_kwargs):
    engine = _FakeElastic(**engine_kwargs)
    tenant = Tenant(name="t", engine=engine, deadline_s=deadline_s)
    router = SimpleNamespace(tenants=lambda: (tenant,))
    return engine, tenant, router


def _tick(qc, router, tenant, *, shed=0):
    tenant.rejected += shed
    return qc.observe(router)


def test_quality_degrades_after_exact_hysteresis_count():
    engine, tenant, router = _quality_rig()
    qc = QualityController(
        degrade_after=3, restore_after=2, clock=FakeClock()
    )
    assert _tick(qc, router, tenant, shed=2) == []
    assert _tick(qc, router, tenant, shed=1) == []
    assert engine.level == 0                  # 2 < degrade_after
    (rec,) = _tick(qc, router, tenant, shed=4)
    assert engine.level == 1
    assert rec.action == "degrade" and rec.applied is True
    assert (rec.from_level, rec.to_level) == (0, 1)
    assert rec.shed_delta == 4 and rec.tenant == "t"
    # the streak reset: the next shed round does not degrade again
    assert _tick(qc, router, tenant, shed=1) == []


def test_quality_holds_at_floor_and_journals_it():
    engine, tenant, router = _quality_rig(floor=1)
    engine.level = 1                          # already at the floor
    qc = QualityController(
        degrade_after=1, restore_after=9, clock=FakeClock()
    )
    (rec,) = _tick(qc, router, tenant, shed=5)
    assert rec.action == "floor_hold" and rec.applied is False
    assert rec.to_level == 1 == engine.level  # floor honored, shed
    assert engine.level_switches == 0


def test_quality_restore_gated_by_headroom_then_restores():
    engine, tenant, router = _quality_rig(
        deadline_s=7.0, step_s=1.0, batch=4
    )
    engine.level = 1
    qc = QualityController(
        degrade_after=1, restore_after=2, headroom=0.5,
        clock=FakeClock(),
    )
    # wider step = 1.0 * 4 = 4.0s > 0.5 * 7.0 — calm rounds alone
    # must not restore into a step that would instantly shed again
    for _ in range(4):
        assert _tick(qc, router, tenant) == []
    assert engine.level == 1
    tenant.deadline_s = math.inf              # headroom opens up
    (rec,) = _tick(qc, router, tenant)
    assert rec.action == "restore" and engine.level == 0
    assert (rec.from_level, rec.to_level) == (1, 0)


def test_quality_shed_resets_restore_streak():
    engine, tenant, router = _quality_rig()
    engine.level = 1
    qc = QualityController(
        degrade_after=9, restore_after=3, clock=FakeClock()
    )
    _tick(qc, router, tenant)
    _tick(qc, router, tenant)
    _tick(qc, router, tenant, shed=1)         # resets the calm streak
    _tick(qc, router, tenant)
    assert _tick(qc, router, tenant) == []
    assert engine.level == 1                  # only 2 of 3 calm rounds
    (rec,) = _tick(qc, router, tenant)
    assert rec.action == "restore" and engine.level == 0


def test_quality_ignores_non_elastic_tenants_and_validates_knobs():
    tenant = Tenant(name="t", engine=FakeEngine(
        fake_tenant("t").config
    ))
    router = SimpleNamespace(tenants=lambda: (tenant,))
    qc = QualityController(degrade_after=1, clock=FakeClock())
    tenant.rejected = 50
    assert qc.observe(router) == [] and qc.journal == []
    with pytest.raises(ValueError):
        QualityController(degrade_after=0)
    with pytest.raises(ValueError):
        QualityController(restore_after=0)
    with pytest.raises(ValueError):
        QualityController(headroom=0.0)
    with pytest.raises(ValueError):
        QualityController(headroom=1.5)


# ---------------------------------------------------------------------------
# cluster controller: degrade width before adding hosts
# ---------------------------------------------------------------------------


class _ElasticFakeEngine(FakeEngine):
    """FakeEngine with the level axis the cluster's width hooks use."""

    def __init__(self, config, *, clock=None, step_cost_s=0.0,
                 quality_floor=1):
        super().__init__(config, clock=clock, step_cost_s=step_cost_s)
        self.quality_floor = quality_floor
        self.level = 0
        self.level_switches = 0
        self.degraded_share = 0.0

    def can_degrade(self):
        return self.level < self.quality_floor

    def can_restore(self):
        return self.level > 0

    def level_config(self, k):
        return self.config

    def set_level(self, k):
        self.level = int(k)
        self.level_switches += 1
        return True


def _elastic_cluster(*, n_hosts=1, floor=1, step_cost_s=0.5, **elastic):
    tenants = [fake_tenant("a")]
    clock = FakeClock()

    def factory(tp, config, **_kw):
        return _ElasticFakeEngine(
            config, clock=clock, step_cost_s=step_cost_s,
            quality_floor=floor,
        )

    cluster = Cluster(
        tenants, n_hosts=n_hosts, engine_factory=factory, clock=clock,
        batch_sizes=(4,), elastic=elastic,
    )
    return clock, cluster


def _engines(cluster):
    return [
        t.engine
        for h in cluster.active_hosts()
        for t in h.router.tenants()
    ]


def test_cluster_prefers_width_degradation_then_scales_up():
    clock, cluster = _elastic_cluster(
        floor=1, high_water=0.5, low_water=0.01, sustain=2, max_hosts=4,
    )
    for _ in range(6):
        for i in range(8):
            cluster.submit("a", i)
        cluster.step(force=True)
        clock.advance(0.01)
    actions = [r.action for r in cluster.elastic.journal]
    # first hot window narrows the tenant instead of adding a host;
    # only once the floor is exhausted does the pool grow
    assert "degrade_width" in actions and "scale_up" in actions
    assert actions.index("degrade_width") < actions.index("scale_up")
    deg = next(
        r for r in cluster.elastic.journal
        if r.action == "degrade_width"
    )
    assert deg.n_active_after == deg.n_active_before  # no new host
    assert deg.moved_tenants == ("a@h0:L1",)
    assert any(e.level == 1 for e in _engines(cluster))
    cluster.drain()


def test_cluster_restores_width_before_draining_a_host():
    clock, cluster = _elastic_cluster(
        n_hosts=2, step_cost_s=0.0,
        high_water=0.9, low_water=0.2, sustain=2, min_hosts=1,
    )
    for e in _engines(cluster):
        e.level = 1                            # planted quality debt
    for _ in range(2):
        cluster.step()
        clock.advance(0.1)
    actions = [r.action for r in cluster.elastic.journal]
    assert actions[0] == "restore_width"       # debt paid back first
    assert all(e.level == 0 for e in _engines(cluster))
    assert len(cluster.active_hosts()) == 2    # no host touched yet
    for _ in range(2):                         # still idle: now shrink
        cluster.step()
        clock.advance(0.1)
    assert "drain" in [r.action for r in cluster.elastic.journal]
    cluster.drain()
