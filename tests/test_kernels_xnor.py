"""xnor GEMM kernel: pallas (interpret) + all 7 aspect variants vs the
pure-jnp oracle, across shape/block sweeps; packing properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.bnn.binarize import (
    np_pack_bits, pack_bits, unpack_bits, packed_len
)
from repro.kernels.ops import xnor_gemm, binary_conv2d
from repro.kernels.ref import xnor_gemm_ref
from repro.kernels.variants import xnor_gemm_variant

ALL_ASPECTS = [
    ("X",), ("Y",), ("Z",), ("X", "Y"), ("X", "Z"), ("Y", "Z"),
    ("X", "Y", "Z"),
]


def _packed_operands(key, b, p, k_bits, n):
    """Random ±1 operands in both packed and unpacked form."""
    ka, kw = jax.random.split(key)
    a_pm1 = jnp.where(jax.random.bernoulli(ka, 0.5, (b, p, k_bits)), 1.0, -1.0)
    w_pm1 = jnp.where(jax.random.bernoulli(kw, 0.5, (n, k_bits)), 1.0, -1.0)
    a_words = pack_bits(a_pm1, pad_bit=0)
    w_words = pack_bits(w_pm1, pad_bit=1)
    return a_pm1, w_pm1, a_words, w_words


@pytest.mark.parametrize("b,p,k_bits,n", [
    (1, 1, 32, 1),        # minimal
    (2, 9, 33, 5),        # tail lanes
    (3, 50, 64, 64),
    (4, 17, 100, 10),     # paper-ish FC tail
    (2, 128, 288, 32),    # conv C32 (9*32)
])
def test_xnor_matches_float_dot(b, p, k_bits, n):
    a_pm1, w_pm1, a_words, w_words = _packed_operands(
        jax.random.PRNGKey(b * 1000 + n), b, p, k_bits, n
    )
    want = jnp.einsum("bpk,nk->bpn", a_pm1, w_pm1).astype(jnp.int32)
    got = xnor_gemm_ref(a_words, w_words, k_bits)
    assert np.array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("aspects", ALL_ASPECTS)
def test_variants_match_ref(aspects):
    _, _, a_words, w_words = _packed_operands(
        jax.random.PRNGKey(7), 3, 21, 70, 13
    )
    ref = xnor_gemm_ref(a_words, w_words, 70)
    got = xnor_gemm_variant(a_words, w_words, 70, frozenset(aspects))
    assert np.array_equal(np.asarray(ref), np.asarray(got))


@pytest.mark.parametrize("aspects", ALL_ASPECTS)
@pytest.mark.parametrize("p_blk,n_blk", [(8, 8), (16, 32), (128, 128)])
def test_pallas_matches_ref(aspects, p_blk, n_blk):
    _, _, a_words, w_words = _packed_operands(
        jax.random.PRNGKey(11), 2, 24, 96, 48
    )
    ref = xnor_gemm_ref(a_words, w_words, 96)
    got = xnor_gemm(
        a_words, w_words, k_true=96, aspects=aspects,
        backend="pallas", interpret=True, p_blk=p_blk, n_blk=n_blk,
    )
    assert np.array_equal(np.asarray(ref), np.asarray(got))


def test_pallas_ragged_tiles():
    """P, N not multiples of the block sizes."""
    _, _, a_words, w_words = _packed_operands(
        jax.random.PRNGKey(13), 2, 37, 65, 29
    )
    ref = xnor_gemm_ref(a_words, w_words, 65)
    got = xnor_gemm(
        a_words, w_words, k_true=65, aspects=("X", "Z"),
        backend="pallas", interpret=True, p_blk=16, n_blk=16,
    )
    assert np.array_equal(np.asarray(ref), np.asarray(got))


@pytest.mark.parametrize("backend", ["ref", "variant", "pallas"])
def test_binary_conv_matches_fp_conv(backend):
    key = jax.random.PRNGKey(3)
    b, h, w, cin, cout = 2, 8, 8, 33, 17
    kx, kw = jax.random.split(key)
    x_pm1 = jnp.where(jax.random.bernoulli(kx, 0.5, (b, h, w, cin)), 1.0, -1.0)
    wt = jnp.where(
        jax.random.bernoulli(kw, 0.5, (3, 3, cin, cout)), 1.0, -1.0
    )
    xp = jnp.pad(x_pm1, ((0, 0), (1, 1), (1, 1), (0, 0)), constant_values=-1.0)
    want = jax.lax.conv_general_dilated(
        xp, wt, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ).astype(jnp.int32)

    x_words = pack_bits(x_pm1, pad_bit=0)
    wt_np = np.transpose(np.asarray(wt), (3, 0, 1, 2)).reshape(cout, 9, cin)
    w_words = jnp.asarray(np_pack_bits(wt_np, pad_bit=1).reshape(cout, -1))
    got = binary_conv2d(
        x_words, w_words, k_true=9 * cin, backend=backend,
        aspects=("Y", "Z"), interpret=True, p_blk=16, n_blk=8,
    )
    assert np.array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# Packing properties (hypothesis)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    x = np.where(rng.random((3, n)) < 0.5, -1.0, 1.0).astype(np.float32)
    words = pack_bits(jnp.asarray(x))
    back = unpack_bits(words, n)
    assert np.array_equal(np.asarray(back), x)
    assert words.shape[-1] == packed_len(n)


@settings(max_examples=30, deadline=None)
@given(
    k_bits=st.integers(1, 97),
    seed=st.integers(0, 2**31 - 1),
)
def test_xnor_dot_exact_vs_float(k_bits, seed):
    """Property: packed dot == float dot for any K (tail correctness)."""
    rng = np.random.default_rng(seed)
    a = np.where(rng.random((1, 1, k_bits)) < 0.5, -1.0, 1.0)
    w = np.where(rng.random((2, k_bits)) < 0.5, -1.0, 1.0)
    want = (a[0] @ w.T).astype(np.int64)
    got = xnor_gemm_ref(
        pack_bits(jnp.asarray(a), 0), pack_bits(jnp.asarray(w), 1), k_bits
    )
    assert np.array_equal(want, np.asarray(got)[0])


def test_np_jnp_pack_agree():
    rng = np.random.default_rng(0)
    x = np.where(rng.random((4, 77)) < 0.5, -1.0, 1.0).astype(np.float32)
    assert np.array_equal(
        np_pack_bits(x, 1), np.asarray(pack_bits(jnp.asarray(x), 1))
    )
    assert np.array_equal(
        np_pack_bits(x, 0), np.asarray(pack_bits(jnp.asarray(x), 0))
    )
