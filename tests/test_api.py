"""The ``repro.api`` facade: canonical verb set, deprecation shims
(bit-exact, warn once per call site), and the Deployment object
(docs/ARCHITECTURE.md §13)."""

import warnings

import jax
import numpy as np
import pytest

import repro.api as api
from repro._compat import reset_warned
from repro.bnn.models import (
    build_model, forward_packed, pack_params, prepare_input_packed,
)
from repro.core.parallel_config import CPU

from tests.fixtures import tied_table


@pytest.fixture(autouse=True)
def _fresh_warn_sites():
    reset_warned()
    yield
    reset_warned()


@pytest.fixture(scope="module")
def small():
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(3)
    x01 = rng.integers(0, 2, size=(8, 28, 28, 1)).astype(np.float32)
    xw = np.asarray(prepare_input_packed(x01))
    ref = np.asarray(forward_packed(m.specs, packed, xw))
    return m, packed, xw, ref


# ---------------------------------------------------------------------------
# the verb set
# ---------------------------------------------------------------------------


def test_verb_set_is_published():
    for verb in (
        "profile_model", "autotune_model", "map_model", "map_fleet",
        "map_all_device", "price_mapping", "fuse_mapping",
        "plan_single", "plan_fleet", "Deployment",
    ):
        assert verb in api.__all__
        assert callable(getattr(api, verb))


def test_aliases_are_the_implementations():
    from repro.core.mapper import map_efficient_configuration
    from repro.core.profiler import autotune_bnn_model, profile_bnn_model

    assert api.profile_model is profile_bnn_model
    assert api.autotune_model is autotune_bnn_model
    assert api.map_model is map_efficient_configuration


# ---------------------------------------------------------------------------
# deprecation shims: bit-exact with the facade, warn once per site
# ---------------------------------------------------------------------------


def test_configuration_from_mapping_shim_bit_exact():
    table = tied_table("m")
    mapping = [CPU] * len(table.layer_labels)
    from repro.core import configuration_from_mapping

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        old = configuration_from_mapping(table, 4, mapping)
    assert old == api.price_mapping(table, 4, mapping)
    msgs = [w for w in caught if w.category is DeprecationWarning]
    assert len(msgs) == 1
    assert "price_mapping" in str(msgs[0].message)


def test_all_device_configuration_shim_bit_exact():
    table = tied_table("m")
    from repro.fleet import all_device_configuration

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        old = all_device_configuration(table)
    assert old == api.map_all_device(table)
    assert sum(
        w.category is DeprecationWarning for w in caught
    ) == 1


def test_fuse_configuration_shim_bit_exact(small):
    m, packed, _, _ = small
    from tests.fixtures import flat_table
    from repro.core.plan import fuse_configuration

    table = flat_table(m)
    config = api.price_mapping(
        table, 4, [CPU] * len(table.layer_labels)
    )
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        old = fuse_configuration(
            m, packed, table, config, time_source="analytic", repeats=1
        )
    new = api.fuse_mapping(
        m, packed, flat_table(m), config,
        time_source="analytic", repeats=1,
    )
    assert old == new
    assert any(
        w.category is DeprecationWarning for w in caught
    )


def test_shim_warns_once_per_call_site():
    table = tied_table("m")
    mapping = [CPU] * len(table.layer_labels)
    from repro.core import configuration_from_mapping

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        for _ in range(5):   # one site, many calls
            configuration_from_mapping(table, 4, mapping)
        configuration_from_mapping(table, 4, mapping)  # second site
    msgs = [w for w in caught if w.category is DeprecationWarning]
    assert len(msgs) == 2


# ---------------------------------------------------------------------------
# planning helpers
# ---------------------------------------------------------------------------


def test_plan_single_maps_and_persists(tmp_path, small, monkeypatch):
    m, packed, _, _ = small
    from repro.store import ProfileStore

    store = ProfileStore(tmp_path)
    tp = api.plan_single(
        m, packed, batch_sizes=(4,), store=store,
        time_source="analytic", repeats=1,
    )
    assert tp.config.proper_batch_size == 4
    assert tp.expected_s_per_example > 0
    assert store.load_profile(m, (4,)) is not None
    assert store.load_mapping(m, policy="dp", batch=4) is not None

    # warm start: the second plan performs zero profiling passes
    def boom(*a, **k):
        raise AssertionError("profiled on a warm start")

    monkeypatch.setattr(api, "profile_model", boom)
    tp2 = api.plan_single(
        m, packed, batch_sizes=(4,), store=store,
        time_source="analytic", repeats=1,
    )
    assert tp2.config == tp.config


def test_plan_fleet_returns_contention_priced_tenants(small):
    m, packed, _, _ = small
    tenants, plan = api.plan_fleet(
        {"a": (m, packed), "b": (m, packed)},
        batch_sizes=(4,), time_source="analytic", repeats=1,
    )
    assert set(tenants) == {"a", "b"}
    assert plan.joint_makespan_s <= plan.baseline_makespan_s + 1e-12
    for name, tp in tenants.items():
        assert tp.name == name
        assert tp.config.proper_batch_size == 4


def test_plan_fleet_rejects_empty():
    with pytest.raises(ValueError, match="at least one"):
        api.plan_fleet({})


# ---------------------------------------------------------------------------
# Deployment
# ---------------------------------------------------------------------------


def test_deployment_single_serves_bit_exact(small):
    m, packed, xw, ref = small
    dep = api.Deployment.plan(
        (m, packed), batch_sizes=(4,),
        time_source="analytic", repeats=1,
    )
    assert dep.mode == "single"
    dep.serve(max_batch=4)
    reqs = [dep.submit(xw[i]) for i in range(8)]
    assert dep.drain() == 8
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.result), ref[i])
    s = dep.stats()
    assert s["mode"] == "single" and s["served"] == 8


def test_deployment_fleet_serves_both_tenants(small):
    m, packed, xw, ref = small
    dep = api.Deployment.plan(
        {"a": (m, packed), "b": (m, packed)},
        batch_sizes=(4,), time_source="analytic", repeats=1,
    )
    assert dep.mode == "fleet"
    dep.serve(max_batch=4)
    with pytest.raises(ValueError, match="tenant"):
        dep.submit(xw[0])
    reqs = {
        n: [dep.submit(xw[i], tenant=n) for i in range(4)]
        for n in ("a", "b")
    }
    assert dep.drain() == {"a": 4, "b": 4}
    for rs in reqs.values():
        for i, r in enumerate(rs):
            np.testing.assert_array_equal(np.asarray(r.result), ref[i])
    s = dep.stats()
    assert s["mode"] == "fleet"
    assert set(s["tenants"]) == {"a", "b"}
    assert "ledger" in s


def test_deployment_cluster_mode(small):
    m, packed, xw, ref = small
    dep = api.Deployment.plan(
        {"a": (m, packed), "b": (m, packed)},
        hosts=2, batch_sizes=(4,), time_source="analytic", repeats=1,
    )
    assert dep.mode == "cluster"
    dep.serve(max_batch=4)
    assert dep.cluster_plan.n_hosts == 2
    reqs = [dep.submit(xw[i], tenant="a") for i in range(4)]
    dep.submit(xw[0], tenant="b")
    served = dep.drain()
    assert served["a"] == 4 and served["b"] == 1
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.result), ref[i])
    s = dep.stats()
    assert s["mode"] == "cluster" and s["n_active"] == 2


def test_deployment_requires_serve_before_submit(small):
    m, packed, xw, _ = small
    dep = api.Deployment.plan(
        (m, packed), batch_sizes=(4,),
        time_source="analytic", repeats=1,
    )
    with pytest.raises(RuntimeError, match="serve"):
        dep.submit(xw[0])
    with pytest.raises(RuntimeError, match="serve"):
        dep.step()


def test_deployment_configuration_accessor(small):
    m, packed, _, _ = small
    dep = api.Deployment.plan(
        {"a": (m, packed), "b": (m, packed)},
        batch_sizes=(4,), time_source="analytic", repeats=1,
    )
    assert dep.configuration("a").model_name == m.name
    with pytest.raises(ValueError, match="name one"):
        dep.configuration()


def test_deployment_validates_hosts(small):
    m, packed, _, _ = small
    with pytest.raises(ValueError, match="hosts"):
        api.Deployment.plan((m, packed), hosts=0)


# ---------------------------------------------------------------------------
# the facade is the only path examples need
# ---------------------------------------------------------------------------


def test_examples_avoid_internal_entrypoints():
    """Serving examples go through ``repro.api`` — no direct imports
    of the profiler or fleet-scheduler internals."""
    import pathlib

    root = pathlib.Path(__file__).resolve().parent.parent / "examples"
    for name in ("serve_mapped.py", "serve_fleet.py"):
        text = (root / name).read_text()
        assert "repro.core.profiler" not in text, name
        assert "repro.fleet.scheduler" not in text, name
        assert "repro.api" in text or "from repro import api" in text, name
