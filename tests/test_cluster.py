"""Cluster tier: placement, dispatch, elastic pool control, and the
drain path's bit-exactness guarantee (docs/ARCHITECTURE.md §13)."""

import jax
import numpy as np
import pytest

from repro.api import TenantPlan
from repro.bnn.models import (
    build_model, forward_packed, pack_params, prepare_input_packed,
)
from repro.cluster import (
    DRAINING, RETIRED,
    Cluster, ConsistentHash, ElasticController, LeastLoaded,
    ScaleRecord, latency_quantile, make_policy, place_tenants,
)
from repro.core.mapper import price_mapping
from repro.core.parallel_config import CPU

from tests.fixtures import FakeClock, tied_table


# ---------------------------------------------------------------------------
# fakes: a serving engine the router accepts, without jax in the loop
# ---------------------------------------------------------------------------


class _FakeBatcher:
    def __init__(self, max_batch=4):
        self.max_batch = max_batch
        self.queue = []

    def submit(self, x):
        self.queue.append(x)
        return x

    def pending(self):
        return len(self.queue)

    def ready(self):
        return len(self.queue) >= self.max_batch

    def migrate_to(self, other):
        moving, self.queue = self.queue, []
        other.queue.extend(moving)
        return len(moving)


class FakeEngine:
    """Duck-typed ServingEngine: queues requests, serves one batch per
    step, burns `step_cost_s` of fake wall time on the host clock."""

    def __init__(self, config, *, clock=None, step_cost_s=0.0):
        self.config = config
        self.batcher = _FakeBatcher(config.proper_batch_size)
        self.telemetry = None
        self.served = 0
        self.steps = 0
        self.swaps = 0
        self._clock = clock
        self.step_cost_s = step_cost_s

    def submit(self, x):
        return self.batcher.submit(x)

    def step(self, *, force=False):
        n = min(len(self.batcher.queue), self.batcher.max_batch)
        if not n or (not force and not self.batcher.ready()):
            return 0
        del self.batcher.queue[:n]
        if self._clock is not None:
            self._clock.advance(self.step_cost_s)
        self.served += n
        self.steps += 1
        return n

    def swap_configuration(self, config):
        assert config.proper_batch_size == self.config.proper_batch_size
        self.config = config
        self.swaps += 1
        return True


def fake_tenant(name, *, cpu=1.0, gpu=0.9, weight=1.0):
    table = tied_table(name, cpu=cpu, gpu=gpu)
    config = price_mapping(
        table, 4, [CPU] * len(table.layer_labels)
    )
    return TenantPlan(
        name=name, model=None, packed=[], table=table, config=config,
        weight=weight,
    )


def fake_cluster(tenants, *, n_hosts=2, clock=None, step_cost_s=0.0,
                 **kwargs):
    clock = clock if clock is not None else FakeClock()

    def factory(tp, config, **_kw):
        return FakeEngine(config, clock=clock, step_cost_s=step_cost_s)

    return clock, Cluster(
        tenants, n_hosts=n_hosts, engine_factory=factory, clock=clock,
        batch_sizes=(4,), **kwargs,
    )


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------


def test_placement_spreads_tenants_across_hosts():
    tenants = [fake_tenant("a"), fake_tenant("b")]
    plan = place_tenants(tenants, 2, batch_sizes=(4,))
    assert plan.n_hosts == 2
    assert {plan.host_of("a"), plan.host_of("b")} == {0, 1}
    # one tenant per host: the cluster makespan is a solo makespan,
    # strictly below any co-located (contention-priced) packing
    solo = place_tenants(tenants, 1, batch_sizes=(4,))
    assert plan.makespan_s < solo.makespan_s


def test_placement_configs_are_jointly_mapped():
    tenants = [fake_tenant("a"), fake_tenant("b"), fake_tenant("c")]
    plan = place_tenants(tenants, 2, batch_sizes=(4,))
    # every tenant got a config priced at the serving batch
    for t in tenants:
        cfg = plan.config_of(t.name)
        assert cfg.proper_batch_size == 4
        assert cfg.model_name == t.name
    # co-located tenants on the shared host split processors (the
    # near-tied tables make all-same-processor strictly worse)
    shared = max(plan.assignments, key=lambda a: len(a.tenant_names))
    assert len(shared.tenant_names) == 2
    placements = {
        tuple(c == CPU for c in plan.config_of(n).layer_configs)
        for n in shared.tenant_names
    }
    assert len(placements) == 2


def test_placement_validates_host_count():
    with pytest.raises(ValueError, match="n_hosts"):
        place_tenants([fake_tenant("a")], 0)


# ---------------------------------------------------------------------------
# dispatch policies
# ---------------------------------------------------------------------------


def test_least_loaded_routes_to_emptiest_replica():
    t = fake_tenant("a")
    _, cluster = fake_cluster([t], n_hosts=1)
    host0 = cluster.hosts[0]
    host1, _ = cluster.scale_up()      # replica of "a" on both hosts
    for _ in range(3):
        host0.submit("a", 0)
    cluster.submit("a", 1)
    assert host1.pending() == 1        # went to the empty replica
    cluster.drain()


def test_consistent_hash_key_affinity_and_fallback():
    t = fake_tenant("a")
    _, cluster = fake_cluster([t], n_hosts=1,
                              policy=ConsistentHash(replicas=8))
    cluster.scale_up()
    hosts = cluster.active_hosts()
    picks = {
        k: cluster.policy.choose(hosts, "a", key=k)
        for k in ("k1", "k2", "k3", "k4")
    }
    # deterministic: same key, same host, every time
    for k, h in picks.items():
        assert cluster.policy.choose(hosts, "a", key=k) is h
    # keyless requests fall back to least-loaded instead of pinning
    hosts[0].submit("a", 0)
    assert cluster.policy.choose(hosts, "a") is hosts[1]


def test_consistent_hash_moves_few_keys_on_scale_up():
    t = fake_tenant("a")
    _, cluster = fake_cluster([t], n_hosts=1,
                              policy=ConsistentHash(replicas=32))
    cluster.scale_up()
    cluster.scale_up()
    hosts3 = cluster.active_hosts()
    keys = [f"key{i}" for i in range(200)]
    before = {k: cluster.policy.choose(hosts3, "a", key=k).host_id
              for k in keys}
    cluster.scale_up()
    hosts4 = cluster.active_hosts()
    after = {k: cluster.policy.choose(hosts4, "a", key=k).host_id
             for k in keys}
    moved = sum(before[k] != after[k] for k in keys)
    # ideal churn is 1/4 of keys; allow slack but far below "all"
    assert moved <= len(keys) // 2


def test_make_policy_resolves_names_and_rejects_unknown():
    assert isinstance(make_policy("least_loaded"), LeastLoaded)
    assert isinstance(make_policy("consistent_hash"), ConsistentHash)
    custom = LeastLoaded()
    assert make_policy(custom) is custom
    with pytest.raises(ValueError, match="unknown routing policy"):
        make_policy("random")


def test_draining_host_excluded_from_dispatch():
    t = fake_tenant("a")
    _, cluster = fake_cluster([t], n_hosts=1)
    host0 = cluster.hosts[0]
    cluster.scale_up()
    host0.submit("a", 0)               # host0 is the loaded one
    cluster.start_drain(cluster.hosts[1])
    cluster.submit("a", 1)             # only host0 accepts now
    assert host0.pending() == 2
    with pytest.raises(RuntimeError, match="draining"):
        cluster.hosts[1].submit("a", 2)
    cluster.drain()


# ---------------------------------------------------------------------------
# elastic control loop
# ---------------------------------------------------------------------------


def surge(cluster, tenants, n=8):
    for tp in tenants:
        for i in range(n):
            cluster.submit(tp.name, i)


def test_elastic_scales_up_on_sustained_high_water():
    tenants = [fake_tenant("a"), fake_tenant("b")]
    clock, cluster = fake_cluster(
        tenants, n_hosts=2, step_cost_s=0.5,
        elastic={"high_water": 0.6, "low_water": 0.01, "sustain": 2,
                 "max_hosts": 4},
    )
    assert len(cluster.active_hosts()) == 2
    for _ in range(3):
        surge(cluster, tenants)
        cluster.step(force=True)
        clock.advance(0.01)
    assert len(cluster.active_hosts()) == 3
    ups = [r for r in cluster.elastic.journal if r.action == "scale_up"]
    assert len(ups) >= 1
    rec = ups[0]
    assert isinstance(rec, ScaleRecord)
    assert rec.n_active_after == rec.n_active_before + 1
    assert rec.moved_tenants            # replicated someone
    assert "occupancy" in rec.to_dict()["reason"]
    cluster.drain()


def test_elastic_one_up_per_sustain_window():
    tenants = [fake_tenant("a")]
    clock, cluster = fake_cluster(
        tenants, n_hosts=1, step_cost_s=0.5,
        elastic={"high_water": 0.5, "low_water": 0.01, "sustain": 3,
                 "max_hosts": 8},
    )
    for _ in range(6):
        surge(cluster, tenants)
        cluster.step(force=True)
        clock.advance(0.01)
    # 6 hot ticks with sustain=3 → exactly 2 scale-ups, not 4
    ups = [r for r in cluster.elastic.journal if r.action == "scale_up"]
    assert len(ups) == 2
    cluster.drain()


def test_elastic_drains_then_retires_on_low_water():
    tenants = [fake_tenant("a")]
    clock, cluster = fake_cluster(
        tenants, n_hosts=2, step_cost_s=0.0,
        elastic={"high_water": 0.9, "low_water": 0.2, "sustain": 2,
                 "min_hosts": 1},
    )
    # idle ticks: no load, occupancy 0
    for _ in range(2):
        cluster.step()
        clock.advance(0.1)
    states = [h.status for h in cluster.hosts]
    assert DRAINING in states
    actions = [r.action for r in cluster.elastic.journal]
    assert actions[0] == "drain"
    # drained host is empty → next tick retires it
    cluster.step()
    assert [h.status for h in cluster.hosts].count(RETIRED) == 1
    assert [r.action for r in cluster.elastic.journal] == [
        "drain", "retire"
    ]
    assert len(cluster.active_hosts()) == 1
    # tenant kept service throughout
    cluster.submit("a", 0)
    assert cluster.pending() == 1
    cluster.drain()


def test_scale_decision_during_drain_defers():
    tenants = [fake_tenant("a"), fake_tenant("b")]
    clock, cluster = fake_cluster(
        tenants, n_hosts=2, step_cost_s=0.5,
        elastic={"high_water": 0.5, "low_water": 0.01, "sustain": 1,
                 "max_hosts": 4},
    )
    victim = cluster.hosts[0]
    name = victim.tenant_names()[0]
    cluster.start_drain(victim)
    # plant work the drain hand-off cannot move (already dispatched to
    # the engine after the queue migration ran)
    victim.router.tenant(name).engine.submit(0)
    n_before = len(cluster.hosts)
    surge(cluster, tenants)
    # manually tick the controller against a hot pool while the
    # victim still holds work: the triggered scale-up must defer
    for h in cluster.active_hosts():
        h.step(force=True)
    clock.advance(0.01)
    rec = cluster.elastic.observe(cluster)
    assert rec is not None and rec.action == "deferred"
    assert "scale_up" in rec.reason
    assert len(cluster.hosts) == n_before       # nothing acted
    # drain completes → retire; the hot streak then fires for real
    cluster.drain()
    rec = cluster.elastic.observe(cluster)
    assert rec.action == "retire"
    surge(cluster, tenants)
    for h in cluster.active_hosts():
        h.step(force=True)
    clock.advance(0.01)
    rec = cluster.elastic.observe(cluster)
    assert rec.action == "scale_up"


def test_elastic_validates_knobs():
    with pytest.raises(ValueError, match="low_water"):
        ElasticController(high_water=0.2, low_water=0.5)
    with pytest.raises(ValueError, match="sustain"):
        ElasticController(sustain=0)
    with pytest.raises(ValueError, match="min_hosts"):
        ElasticController(min_hosts=5, max_hosts=2)


def test_cannot_drain_last_active_host():
    tenants = [fake_tenant("a")]
    _, cluster = fake_cluster(tenants, n_hosts=1)
    with pytest.raises(RuntimeError, match="last active host"):
        cluster.start_drain(cluster.hosts[0])


def test_retire_refuses_with_inflight_work():
    tenants = [fake_tenant("a")]
    _, cluster = fake_cluster(tenants, n_hosts=1)
    host = cluster.hosts[0]
    host.submit("a", 0)
    host.start_drain()
    with pytest.raises(RuntimeError, match="in-flight"):
        host.retire()


def test_replication_hot_swaps_residents_never_rebuilds():
    # adding a co-runner to a host re-maps the residents jointly;
    # engines that change mapping swap at a batch boundary
    tenants = [fake_tenant("a"), fake_tenant("b")]
    _, cluster = fake_cluster(tenants, n_hosts=2)
    host0 = cluster.hosts[0]
    resident = host0.tenant_names()[0]
    engine_before = host0.router.tenant(resident).engine
    other = [t for t in tenants if t.name != resident][0]
    cluster._replicate(other, host0)
    assert host0.router.tenant(resident).engine is engine_before
    # near-tied tables: the resident's solo mapping can't survive a
    # co-runner unchanged, so the swap path actually ran
    assert engine_before.swaps == 1


# ---------------------------------------------------------------------------
# drain path with REAL engines: bit-exactness of in-flight work
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def real_pair():
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(7)
    x01 = rng.integers(0, 2, size=(8, 28, 28, 1)).astype(np.float32)
    xw = np.asarray(prepare_input_packed(x01))
    ref = np.asarray(forward_packed(m.specs, packed, xw))
    return m, packed, xw, ref


def test_draining_host_finishes_inflight_bit_exact(real_pair):
    m, packed, xw, ref = real_pair
    from tests.fixtures import flat_table

    table = flat_table(m)
    config = price_mapping(
        table, 4, [CPU] * len(table.layer_labels)
    )
    tp = TenantPlan(name=m.name, model=m, packed=packed,
                    table=table, config=config)
    cluster = Cluster([tp], n_hosts=2, batch_sizes=(4,))
    # both hosts serve the tenant; load one, then drain it
    host0 = cluster.plan.host_of(m.name)
    victim = cluster.hosts[host0]
    reqs = [victim.submit(m.name, xw[i]) for i in range(8)]
    moved = cluster.start_drain(victim)
    assert victim.status == DRAINING
    assert m.name in moved              # sole replica was replicated
    # the queued (never-dispatched) backlog migrated to the replica —
    # the victim has nothing left to serve and retires immediately
    assert victim.pending() == 0
    assert victim.drain() == {}
    victim.retire()
    assert victim.status == RETIRED
    replica = cluster._hosts_for(m.name)[0]
    assert replica.pending() == 8
    served = cluster.drain()
    assert served == {m.name: 8}
    # every migrated request completed on the replica with the
    # reference forward's exact bits — the same Request objects the
    # caller holds, FIFO order preserved across the migration
    for i, r in enumerate(reqs):
        assert r.done_t is not None
        np.testing.assert_array_equal(np.asarray(r.result), ref[i])
    # new work flows to the replica
    r = cluster.submit(m.name, xw[0])
    assert cluster.pending() == 1
    cluster.drain()
    np.testing.assert_array_equal(np.asarray(r.result), ref[0])


def test_drain_handoff_migrates_queued_keeps_dispatched(real_pair):
    """The PR 8 residual, both halves: queued requests move to the
    replica at drain time; work already dispatched to an engine stays
    and finishes on the draining host."""
    m, packed, xw, ref = real_pair
    from tests.fixtures import flat_table

    table = flat_table(m)
    config = price_mapping(
        table, 4, [CPU] * len(table.layer_labels)
    )
    tp = TenantPlan(name=m.name, model=m, packed=packed,
                    table=table, config=config)
    cluster = Cluster([tp], n_hosts=2, batch_sizes=(4,))
    victim = cluster.hosts[cluster.plan.host_of(m.name)]
    queued = [victim.submit(m.name, xw[i]) for i in range(4)]
    cluster.start_drain(victim)
    # planted after the hand-off ran: this models a batch the engine
    # had already popped — migration must not touch it
    stuck = victim.router.tenant(m.name).engine.submit(xw[4])
    assert victim.pending() == 1
    assert victim.drain() == {m.name: 1}
    victim.retire()
    np.testing.assert_array_equal(np.asarray(stuck.result), ref[4])
    cluster.drain()
    for i, r in enumerate(queued):
        np.testing.assert_array_equal(np.asarray(r.result), ref[i])


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def test_latency_quantile_nearest_rank():
    xs = list(range(1, 101))
    assert latency_quantile(xs, 0.99) == 99
    assert latency_quantile(xs, 0.5) == 50
    assert latency_quantile([], 0.99) == 0.0
    assert latency_quantile([3.0], 0.99) == 3.0
