"""Hypothesis when available, a deterministic fixed-example fallback
when not.

The property tests import ``given``/``settings``/``st`` from here
instead of from ``hypothesis`` directly, so the suite still *collects
and runs* in minimal containers (the fallback replays a small fixed set
of examples per test — boundary values first, then seeded-random draws
— rather than a real shrinking search).  Install ``hypothesis`` (see
``requirements-dev.txt``) to get full property-based coverage.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10  # cap per test; keeps the suite fast

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng, i):
            return self._draw(rng, i)

    class _strategies:
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            def draw(rng, i):
                if i == 0:
                    return min_value
                if i == 1:
                    return max_value
                return rng.randint(min_value, max_value)

            return _Strategy(draw)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)

            def draw(rng, i):
                return seq[i % len(seq)]

            return _Strategy(draw)

        @staticmethod
        def booleans():
            return _strategies.sampled_from([False, True])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            def draw(rng, i):
                if i == 0:
                    return min_value
                if i == 1:
                    return max_value
                return rng.uniform(min_value, max_value)

            return _Strategy(draw)

    st = _strategies

    def given(**param_strategies):
        def decorate(fn):
            # zero-arg wrapper so pytest does not mistake the drawn
            # parameters for fixtures
            def wrapper():
                n = min(
                    getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES,
                )
                rng = random.Random(
                    zlib.crc32(fn.__qualname__.encode("utf-8"))
                )
                for i in range(n):
                    drawn = {
                        name: strat.example(rng, i)
                        for name, strat in param_strategies.items()
                    }
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return decorate

    def settings(max_examples=_FALLBACK_EXAMPLES, **_):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn

        return decorate
