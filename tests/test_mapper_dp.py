"""Transfer-aware DP mapper: optimality vs greedy, transfer-elision
accounting, and the extended EfficientConfiguration JSON round-trip."""

import json

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.bnn import build_model
from repro.bnn.models import pack_params
from repro.core.mapper import (
    EfficientConfiguration,
    map_efficient_configuration,
)
from repro.core.parallel_config import CONFIGS, CPU
from repro.core.profiler import ProfileTable, profile_bnn_model


def _random_split_table(rng, n_layers=6, batches=(1, 2, 4)):
    """A ProfileTable with independent kernel and boundary components,
    totals assembled the way the profiler does."""
    kernel, times, h2d, d2h = {}, {}, {}, {}
    for b in batches:
        kernel[b], times[b], h2d[b], d2h[b] = [], [], [], []
        for _ in range(n_layers):
            krow = {c: float(rng.uniform(1e-6, 1e-3)) for c in CONFIGS}
            up = float(rng.uniform(1e-6, 5e-4))
            down = float(rng.uniform(1e-6, 5e-4))
            trow = {
                c: krow[c] if c == CPU else krow[c] + up + down
                for c in CONFIGS
            }
            kernel[b].append(krow)
            times[b].append(trow)
            h2d[b].append(up)
            d2h[b].append(down)
    return ProfileTable(
        "synthetic", tuple(batches),
        tuple(f"L{i+1}:C64" for i in range(n_layers)), times,
        kernel_times=kernel, h2d_times=h2d, d2h_times=d2h,
    )


def _fused_cost(table, batch, mapping):
    """Independent reference implementation of the fused cost model:
    kernel per layer + boundary only at host<->device placement
    changes (model starts and ends on the host)."""
    total = 0.0
    prev_dev = False
    for i, c in enumerate(mapping):
        dev = c != CPU
        if dev and not prev_dev:
            total += table.h2d(batch, i)
        if prev_dev and not dev:
            total += table.d2h(batch, i - 1)
        total += table.kernel_time(batch, i, c)
        prev_dev = dev
    if prev_dev:
        total += table.d2h(batch, len(mapping) - 1)
    return total


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dp_no_worse_than_greedy(seed):
    table = _random_split_table(np.random.default_rng(seed))
    dp = map_efficient_configuration(table, policy="dp")
    greedy = map_efficient_configuration(table, policy="greedy")
    assert (
        dp.expected_time_per_example
        <= greedy.expected_time_per_example + 1e-12
    )


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dp_total_matches_fused_cost_of_its_mapping(seed):
    table = _random_split_table(np.random.default_rng(seed))
    dp = map_efficient_configuration(table, policy="dp")
    b = dp.proper_batch_size
    assert dp.expected_time_per_example == pytest.approx(
        _fused_cost(table, b, dp.layer_configs), rel=1e-9
    )
    # per-layer attribution sums back to the total
    assert sum(dp.per_layer_times) == pytest.approx(
        dp.expected_time_per_example, rel=1e-9
    )
    assert all(
        t == pytest.approx(k + bd, rel=1e-9)
        for t, k, bd in zip(
            dp.per_layer_times,
            dp.per_layer_kernel_times,
            dp.per_layer_boundary_times,
        )
    )


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dp_beats_every_mapping_exhaustively(seed):
    """On a tiny instance, Viterbi must equal brute force over all
    2-config-per-layer paths (CPU vs one device config)."""
    import itertools

    table = _random_split_table(
        np.random.default_rng(seed), n_layers=4, batches=(1,)
    )
    dp = map_efficient_configuration(
        table, policy="dp", configs=("CPU", "XYZ")
    )
    brute = min(
        _fused_cost(table, 1, m)
        for m in itertools.product(("CPU", "XYZ"), repeat=4)
    )
    assert dp.expected_time_per_example == pytest.approx(brute, rel=1e-9)


def test_elision_credited_only_across_placement_changes():
    """Force a device-device-device sandwich: interior boundaries must
    not be charged; entry h2d and exit d2h must."""
    batches = (1,)
    n = 3
    kernel = {1: [{c: 1.0 if c == CPU else 0.1 for c in CONFIGS}
                  for _ in range(n)]}
    h2d = {1: [0.01, 0.02, 0.04]}
    d2h = {1: [0.001, 0.002, 0.004]}
    times = {1: [
        {c: kernel[1][i][c] + (0.0 if c == CPU else h2d[1][i] + d2h[1][i])
         for c in CONFIGS}
        for i in range(n)
    ]}
    table = ProfileTable(
        "sandwich", batches, ("L1:C1", "L2:C2", "L3:C3"), times,
        kernel_times=kernel, h2d_times=h2d, d2h_times=d2h,
    )
    dp = map_efficient_configuration(table, policy="dp")
    assert all(c != CPU for c in dp.layer_configs)
    # 3 kernels + entry h2d of layer 0 + exit d2h of layer 2, nothing else
    assert dp.expected_time_per_example == pytest.approx(
        0.3 + 0.01 + 0.004, rel=1e-9
    )
    assert dp.per_layer_boundary_times[0] == pytest.approx(0.01)
    assert dp.per_layer_boundary_times[1] == 0.0
    assert dp.per_layer_boundary_times[2] == pytest.approx(0.004)


def test_dp_on_legacy_table_equals_greedy():
    """Without the kernel/boundary split every boundary reads as zero
    and the DP must reproduce the greedy mapping's total."""
    rng = np.random.default_rng(7)
    times = {
        b: [
            {c: float(rng.uniform(1e-6, 1e-3)) for c in CONFIGS}
            for _ in range(5)
        ]
        for b in (1, 2)
    }
    table = ProfileTable(
        "legacy", (1, 2), tuple(f"L{i+1}:C64" for i in range(5)), times
    )
    dp = map_efficient_configuration(table, policy="dp")
    greedy = map_efficient_configuration(table, policy="greedy")
    assert dp.expected_time_per_example == pytest.approx(
        greedy.expected_time_per_example, rel=1e-12
    )
    assert dp.layer_configs == greedy.layer_configs


def test_unknown_policy_rejected():
    table = _random_split_table(np.random.default_rng(0))
    with pytest.raises(ValueError, match="policy"):
        map_efficient_configuration(table, policy="simulated-annealing")


def test_json_roundtrip_with_split_fields():
    table = _random_split_table(np.random.default_rng(3))
    for policy in ("greedy", "dp"):
        ec = map_efficient_configuration(table, policy=policy)
        back = EfficientConfiguration.from_json(ec.to_json())
        assert back == ec
        d = json.loads(ec.to_json())
        assert d["policy"] == policy
        assert all(
            "kernel_time_per_example" in x
            and "boundary_time_per_example" in x
            for x in d["layers"]
        )


def test_json_legacy_load_without_split_fields():
    """JSON written before the split must still load (kernel/boundary
    default to empty, policy to greedy)."""
    legacy = json.dumps({
        "model": "m",
        "proper_batch_size": 4,
        "layers": [
            {"layer": "L1:C64", "config": "XYZ", "time_per_example": 1e-4},
            {"layer": "L2:FC10", "config": "CPU", "time_per_example": 2e-4},
        ],
        "expected_time_per_example": 3e-4,
    })
    ec = EfficientConfiguration.from_json(legacy)
    assert ec.policy == "greedy"
    assert ec.layer_configs == ("XYZ", "CPU")
    assert ec.per_layer_kernel_times == ()
    assert ec.per_layer_boundary_times == ()


def test_dp_strictly_better_on_seed_model_analytic():
    """Acceptance: strict improvement on a real seed model under the
    analytic v5e profile — the greedy mapper over-charges device
    placements by the full per-layer roundtrip and misses the fused
    schedule the DP finds."""
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = profile_bnn_model(
        m, packed, batch_sizes=(1, 16, 128), time_source="analytic"
    )
    dp = map_efficient_configuration(table, policy="dp")
    greedy = map_efficient_configuration(table, policy="greedy")
    assert (
        dp.expected_time_per_example < greedy.expected_time_per_example
    )


def test_measured_profile_carries_split():
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = profile_bnn_model(m, packed, batch_sizes=(1,), repeats=1)
    assert table.kernel_times is not None
    for i in range(len(table.layer_labels)):
        assert table.h2d(1, i) > 0
        assert table.d2h(1, i) > 0
        for c in CONFIGS:
            want = table.kernel_time(1, i, c) + (
                0.0 if c == CPU else table.h2d(1, i) + table.d2h(1, i)
            )
            assert table.times[1][i][c] == pytest.approx(want, rel=1e-9)
    dp = map_efficient_configuration(table, policy="dp")
    greedy = map_efficient_configuration(table, policy="greedy")
    assert (
        dp.expected_time_per_example
        <= greedy.expected_time_per_example + 1e-12
    )
