"""Fleet co-serving: contention inflation of profile tables, the
joint mapper's never-worse-than-all-GPU guarantee, device-time ledger
accounting, and the SLO router's admission/priority/dispatch — ending
in a two-tenant co-serve that is bit-exact per model."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax

from repro.bnn import build_model
from repro.bnn.models import (
    forward_packed, pack_params, prepare_input_packed,
)
from repro.core.cost_model import contention_inflation, inflate_profile
from repro.core.mapper import (
    HOST,
    configuration_from_mapping,
    map_efficient_configuration,
)
from repro.core.parallel_config import CONFIGS, CPU, FULL_GPU
from repro.core.profiler import ProfileTable
from repro.fleet import (
    DeviceTimeLedger,
    FleetRouter,
    all_device_configuration,
    device_configs,
    joint_makespan,
    map_fleet,
    tenant_inflations,
)
from repro.adapt import SegmentTelemetry
from repro.serving import ServingEngine, canonical_mixed_mapping

from fixtures import (
    FakeClock,
    observe_segments,
    random_split_table as _random_split_table,
    tied_table as _tied_table,
)


# ---------------------------------------------------------------------------
# contention inflation
# ---------------------------------------------------------------------------


def test_contention_inflation_is_monotone_and_validates():
    assert contention_inflation(0.0) == 1.0
    assert contention_inflation(1.0) == 2.0
    assert contention_inflation(1.0, gamma=0.5) == 1.5
    assert contention_inflation(-3.0) == 1.0          # clamped below
    xs = [contention_inflation(s) for s in (0.0, 0.3, 0.7, 2.0)]
    assert xs == sorted(xs)
    with pytest.raises(ValueError):
        contention_inflation(0.5, gamma=-1.0)


def test_inflate_profile_scales_by_placement():
    rng = np.random.default_rng(0)
    t = _random_split_table(rng)
    out = inflate_profile(t, host_factor=3.0, device_factor=2.0)
    for b in t.batch_sizes:
        for i in range(len(t.layer_labels)):
            assert out.h2d(b, i) == pytest.approx(2.0 * t.h2d(b, i))
            assert out.d2h(b, i) == pytest.approx(2.0 * t.d2h(b, i))
            for c in t.configs_for(b, i):
                f = 3.0 if c == CPU else 2.0
                assert out.kernel_time(b, i, c) == pytest.approx(
                    f * t.kernel_time(b, i, c)
                )
                expect = out.kernel_time(b, i, c) + (
                    0.0 if c == CPU
                    else out.h2d(b, i) + out.d2h(b, i)
                )
                assert out.times[b][i][c] == pytest.approx(expect)
    # identity factors share the original object (no copy)
    assert inflate_profile(t) is t
    with pytest.raises(ValueError):
        inflate_profile(t, host_factor=0.0)


def test_placement_shares_sum_to_one():
    t = _tied_table("m")
    ec = configuration_from_mapping(
        t, 4, (CPU, FULL_GPU, FULL_GPU, CPU)
    )
    host, dev = ec.placement_shares()
    assert host + dev == pytest.approx(1.0)
    assert 0.0 < host < 1.0 and 0.0 < dev < 1.0
    all_host = configuration_from_mapping(t, 4, (CPU,) * 4)
    assert all_host.placement_shares() == (1.0, 0.0)


def test_tenant_inflations_sum_co_runners_only():
    shares = [(0.25, 0.75), (1.0, 0.0), (0.0, 1.0)]
    host_f, dev_f = tenant_inflations(shares, 0, gamma=1.0)
    assert host_f == pytest.approx(2.0)     # 1 + (1.0 + 0.0)
    assert dev_f == pytest.approx(2.0)      # 1 + (0.0 + 1.0)
    host_f, dev_f = tenant_inflations(shares, 1, gamma=2.0)
    assert host_f == pytest.approx(1.5)     # 1 + 2*(0.25 + 0.0)
    assert dev_f == pytest.approx(4.5)      # 1 + 2*(0.75 + 1.0)


# ---------------------------------------------------------------------------
# joint mapper
# ---------------------------------------------------------------------------


def test_all_device_configuration_places_everything_on_device():
    t = _tied_table("m", cpu=0.1, gpu=5.0)  # CPU strictly better solo
    assert CPU not in device_configs(t)
    ec = all_device_configuration(t)
    assert all(c != CPU for c in ec.layer_configs)
    # and the unconstrained DP would have chosen CPU — the restriction
    # is what makes this the all-GPU baseline, not the optimum
    free = map_efficient_configuration(t, policy="dp")
    assert all(c == CPU for c in free.layer_configs)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_map_fleet_never_worse_than_all_gpu(seed):
    """The acceptance property: on any pair of tables, the joint plan's
    makespan under the inflated cost model is <= the all-GPU fleet
    assignment's on the same tables (descent seeds there and only
    accepts improvements)."""
    rng = np.random.default_rng(seed)
    tables = [
        _random_split_table(rng, name="a"),
        _random_split_table(rng, name="b"),
    ]
    gamma = float(rng.uniform(0.2, 2.0))
    plan = map_fleet(tables, gamma=gamma)
    all_gpu = [all_device_configuration(t) for t in tables]
    baseline = joint_makespan(tables, all_gpu, gamma=gamma)
    assert plan.baseline_makespan_s == pytest.approx(baseline)
    assert plan.joint_makespan_s <= baseline + 1e-12
    assert plan.vs_all_gpu <= 1.0 + 1e-9
    # the plan prices itself consistently with joint_makespan
    assert plan.joint_makespan_s == pytest.approx(
        joint_makespan(tables, plan.configs, gamma=gamma)
    )
    assert max(t.makespan_s for t in plan.tenants) == pytest.approx(
        plan.joint_makespan_s
    )


def test_map_fleet_splits_near_tied_tenants_across_processors():
    """Two tenants whose solo optimum is the same device must not
    both stay there when the host is near-tied: the joint plan
    separates them and strictly beats all-GPU."""
    tables = [_tied_table("a"), _tied_table("b")]
    plan = map_fleet(tables, gamma=1.0)
    assert plan.converged
    assert plan.joint_makespan_s < plan.baseline_makespan_s * 0.75
    placements = [
        {HOST if c == CPU else "device" for c in t.config.layer_configs}
        for t in plan.tenants
    ]
    # each tenant is internally uniform, and they differ
    assert all(len(p) == 1 for p in placements)
    assert placements[0] != placements[1]
    # solo-vs-inflated bookkeeping: the device tenant runs uncontended
    for t in plan.tenants:
        assert t.inflated_expected_s >= t.solo_expected_s - 1e-12


def test_map_fleet_single_tenant_degenerates_to_solo_dp():
    t = _tied_table("solo", cpu=0.5)        # CPU wins outright
    plan = map_fleet([t])
    solo = map_efficient_configuration(t, policy="dp")
    assert plan.tenants[0].config.layer_configs == solo.layer_configs
    assert plan.tenants[0].host_inflation == 1.0
    assert plan.tenants[0].device_inflation == 1.0


def test_map_fleet_measured_shares_override_demand():
    """A ledger that says one tenant is actually idle (zero shares)
    removes its contention: the other tenant keeps its solo device
    mapping instead of fleeing to the host."""
    tables = [_tied_table("a"), _tied_table("b")]
    plan = map_fleet(
        tables, shares=[(0.0, 0.0), None], gamma=1.0
    )
    # tenant b sees no co-runner on the device -> stays all-device
    assert all(c != CPU for c in plan.tenants[1].config.layer_configs)
    assert plan.tenants[1].device_inflation == 1.0


def test_map_fleet_validates():
    t = _tied_table("a")
    with pytest.raises(ValueError):
        map_fleet([])
    with pytest.raises(ValueError, match="names"):
        map_fleet([t], names=("a", "b"))
    with pytest.raises(ValueError, match="shares"):
        map_fleet([t], shares=[(0, 1), (0, 1)])
    with pytest.raises(ValueError, match="weights"):
        map_fleet([t], weights=(1.0, 2.0))
    host_only = ProfileTable(
        "h", (4,), ("L1:C64",),
        {4: [{CPU: 1.0}]}, kernel_times={4: [{CPU: 1.0}]},
        h2d_times={4: [0.0]}, d2h_times={4: [0.0]},
    )
    with pytest.raises(ValueError, match="device"):
        device_configs(host_only)


def test_fleet_weights_shift_the_bottleneck():
    """The makespan is weighted: a tenant serving 10x the traffic
    dominates, so the plan optimizes around it."""
    tables = [_tied_table("a"), _tied_table("b")]
    plan = map_fleet(tables, weights=(10.0, 1.0))
    # the heavy tenant's weighted time is the makespan
    assert plan.joint_makespan_s == pytest.approx(
        max(t.makespan_s for t in plan.tenants)
    )
    heavy = plan.tenants[0]
    assert heavy.makespan_s >= plan.tenants[1].makespan_s


# ---------------------------------------------------------------------------
# device-time ledger
# ---------------------------------------------------------------------------


class _Seg:
    def __init__(self, placement):
        self.placement = placement


def test_ledger_accounts_per_tenant_and_placement():
    led = DeviceTimeLedger()
    obs_a = led.observer("a")
    obs_a(0, _Seg(HOST), 1.0, 4)
    obs_a(1, _Seg("device"), 3.0, 4)
    led.close_step("a")
    led.record("b", "device", 2.0)
    led.close_step("b")
    ua, ub = led.usage("a"), led.usage("b")
    assert (ua.host_s, ua.device_s, ua.steps) == (1.0, 3.0, 1)
    assert (ub.host_s, ub.device_s) == (0.0, 2.0)
    assert led.shares()["a"] == (pytest.approx(0.25), pytest.approx(0.75))
    assert led.co_runner_share("a", "device") == pytest.approx(1.0)
    assert led.co_runner_share("b", HOST) == pytest.approx(0.25)
    assert led.co_runner_share("b", "device") == pytest.approx(0.75)
    snap = led.snapshot()
    assert snap["a"]["device_share"] == pytest.approx(0.75)
    led.reset("a")
    assert led.tenants() == ("b",)
    led.reset()
    assert led.tenants() == ()


def test_ledger_window_bounds_history():
    led = DeviceTimeLedger(window=4)
    for i in range(10):
        led.record("a", HOST if i < 8 else "device", 1.0)
        led.close_step("a")
    u = led.usage("a")
    assert u.steps == 4                      # only the window retained
    assert u.host_s == 2.0 and u.device_s == 2.0
    with pytest.raises(ValueError):
        DeviceTimeLedger(window=0)


def test_ledger_open_step_is_visible_and_idle_tenant_shares_zero():
    led = DeviceTimeLedger()
    led.record("a", HOST, 2.0)               # step not yet closed
    assert led.usage("a").host_s == 2.0
    assert led.usage("idle").share(HOST) == 0.0
    led.close_step("idle")                   # no-op, nothing open
    assert "idle" not in led.snapshot()


# ---------------------------------------------------------------------------
# SLO router
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def two_tenants():
    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    labels = tuple(f"L{s.idx}:{s.notation}" for s in m.specs)
    table = ProfileTable(
        m.name, (4,), labels,
        times={4: [{c: 1e-4 for c in CONFIGS} for _ in m.specs]},
        kernel_times={4: [{c: 1e-4 for c in CONFIGS} for _ in m.specs]},
        h2d_times={4: [1e-5] * len(m.specs)},
        d2h_times={4: [1e-5] * len(m.specs)},
    )
    ec = configuration_from_mapping(table, 4, canonical_mixed_mapping(m))
    return m, packed, table, ec


def test_router_admission_sheds_past_deadline(two_tenants):
    m, packed, table, ec = two_tenants
    router = FleetRouter()
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(),
    )
    step_s = ec.expected_time_per_example * ec.proper_batch_size
    t = router.add_tenant("a", engine, deadline_s=1.5 * step_s)
    xw = np.zeros_like(
        np.asarray(prepare_input_packed(
            jax.random.uniform(jax.random.PRNGKey(0), (1, 28, 28, 1))
        ))[0]
    )
    # one batch fits the deadline; the 5th request implies two batches
    got = [router.submit("a", xw) for _ in range(5)]
    assert all(r is not None for r in got[:4]) and got[4] is None
    assert (t.admitted, t.rejected) == (4, 1)
    stats = router.stats()["a"]
    assert stats["rejected"] == 1 and stats["admitted"] == 4
    # an infinite deadline never sheds, whatever the backlog
    relaxed = router.add_tenant("b", ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(),
    ))
    assert math.isinf(relaxed.deadline_s)
    assert all(
        router.submit("b", xw) is not None for _ in range(20)
    )
    with pytest.raises(ValueError):
        router.add_tenant("a", engine)       # duplicate name
    with pytest.raises(ValueError):
        router.add_tenant("c", engine, deadline_s=0.0)


def test_router_dispatch_order_priority_then_deadline(two_tenants):
    m, packed, table, ec = two_tenants

    def engine():
        # injected clock: `ready()` must stay false on partial batches
        # no matter how slowly a loaded CI runner reaches the assert
        return ServingEngine(
            m, packed, ec, allowed_batch_sizes=table.batch_sizes,
            clock=FakeClock(),
        )

    router = FleetRouter()
    router.add_tenant("low", engine(), priority=0, deadline_s=1.0)
    router.add_tenant("hi", engine(), priority=5)
    router.add_tenant("tight", engine(), priority=0, deadline_s=0.5)
    xw = np.asarray(prepare_input_packed(
        jax.random.uniform(jax.random.PRNGKey(1), (1, 28, 28, 1))
    ))[0]
    for name in ("low", "hi", "tight"):
        router.tenant(name).engine.submit(xw)
    order = [t.name for t in router._dispatch_order(force=True)]
    assert order == ["hi", "tight", "low"]
    # nothing ready without force (partial batches, frozen clock)
    assert router._dispatch_order(force=False) == []


def test_router_co_serves_two_models_bit_exact(two_tenants):
    """End to end: two tenants behind one router + ledger, interleaved
    traffic, per-tenant outputs bit-exact, ledger metered both."""
    m, packed, table, ec = two_tenants
    m2 = build_model("fashion_mnist", scale=0.375)
    packed2 = pack_params(m2.specs, m2.init(jax.random.PRNGKey(1)))
    labels2 = tuple(f"L{s.idx}:{s.notation}" for s in m2.specs)
    table2 = ProfileTable(
        m2.name, (4,), labels2,
        times={4: [{c: 1e-4 for c in CONFIGS} for _ in m2.specs]},
        kernel_times={4: [{c: 1e-4 for c in CONFIGS} for _ in m2.specs]},
        h2d_times={4: [1e-5] * len(m2.specs)},
        d2h_times={4: [1e-5] * len(m2.specs)},
    )
    ec2 = configuration_from_mapping(
        table2, 4, canonical_mixed_mapping(m2)
    )
    ledger = DeviceTimeLedger()
    router = FleetRouter(ledger=ledger)
    for name, (mm, pp, tt, cc) in {
        "small": (m, packed, table, ec),
        "large": (m2, packed2, table2, ec2),
    }.items():
        router.add_tenant(name, ServingEngine(
            mm, pp, cc, allowed_batch_sizes=tt.batch_sizes,
            observer=ledger.observer(name),
        ), priority=1 if name == "small" else 0)

    n = 8
    xs = {
        "small": np.asarray(prepare_input_packed(jax.random.uniform(
            jax.random.PRNGKey(2), (n, 28, 28, 1)))),
        "large": np.asarray(prepare_input_packed(jax.random.uniform(
            jax.random.PRNGKey(3), (n, 28, 28, 1)))),
    }
    refs = {
        "small": np.asarray(forward_packed(m.specs, packed, xs["small"])),
        "large": np.asarray(
            forward_packed(m2.specs, packed2, xs["large"])
        ),
    }
    reqs = {"small": [], "large": []}
    for i in range(n):
        for name in ("small", "large"):
            r = router.submit(name, xs[name][i])
            assert r is not None
            reqs[name].append(r)
    served = router.drain()
    assert served == {"small": n, "large": n}
    for name in ("small", "large"):
        for i, r in enumerate(reqs[name]):
            assert np.array_equal(r.wait(timeout=5.0), refs[name][i])
    # the ledger metered both tenants, host and device both nonzero
    # (canonical mixed mapping alternates placements)
    for name in ("small", "large"):
        u = ledger.usage(name)
        assert u.steps >= 1
        assert u.host_s > 0.0 and u.device_s > 0.0
    assert sum(
        v for v in (
            ledger.co_runner_share("small", HOST),
            ledger.co_runner_share("small", "device"),
        )
    ) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# live-telemetry admission
# ---------------------------------------------------------------------------


def _telemetry_tenant(two_tenants, *, deadline_mult=1.5, min_samples=3):
    """A one-tenant router whose engine carries SegmentTelemetry and a
    frozen clock: admission math is fully deterministic and the tests
    feed telemetry directly (no real engine steps)."""
    m, packed, table, ec = two_tenants
    tel = SegmentTelemetry(warmup=0, tenant="a")
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        telemetry=tel, clock=FakeClock(),
    )
    step_s = ec.expected_time_per_example * ec.proper_batch_size
    router = FleetRouter()
    tenant = router.add_tenant(
        "a", engine, deadline_s=deadline_mult * step_s,
        live_min_samples=min_samples,
    )
    return router, tenant, tel, ec, step_s


def test_router_admission_falls_back_to_profiled_when_cold(two_tenants):
    router, tenant, tel, ec, step_s = _telemetry_tenant(two_tenants)
    # cold telemetry: no live estimate, profiled admission
    assert tenant.live_step_s() is None
    assert tenant.step_expected_s() == pytest.approx(step_s)
    assert router.stats()["a"]["admission"] == "profiled"
    # below live_min_samples stays cold; crossing it goes live
    observe_segments(tel, ec, {}, n=2)
    assert tenant.live_step_s() is None
    observe_segments(tel, ec, {}, n=1)
    live = tenant.live_step_s()
    assert live == pytest.approx(step_s, rel=1e-6)
    assert router.stats()["a"]["admission"] == "live"
    # a telemetry reset (what a hot swap does) drops back to profiled
    tel.reset()
    assert tenant.live_step_s() is None
    assert router.stats()["a"]["admission"] == "profiled"


def test_router_admission_stable_when_telemetry_quiet(two_tenants):
    """Live admission with telemetry matching the profile must shed
    exactly like profiled admission: 4 requests fit one batch and the
    deadline, the 5th implies two batches and sheds."""
    router, tenant, tel, ec, _ = _telemetry_tenant(two_tenants)
    observe_segments(tel, ec, {}, n=4)
    assert router.stats()["a"]["admission"] == "live"
    xw = np.asarray(prepare_input_packed(
        jax.random.uniform(jax.random.PRNGKey(0), (1, 28, 28, 1))
    ))[0]
    got = [router.submit("a", xw) for _ in range(5)]
    assert all(r is not None for r in got[:4]) and got[4] is None
    assert (tenant.admitted, tenant.rejected) == (4, 1)


def test_router_admission_sheds_under_drift_profiled_would_admit(
    two_tenants,
):
    """The regression the live estimate exists for: segments running
    ~9x slower than profiled (EWMA of sustained 10x) must shed the
    *first* request — profiled admission would have admitted it and
    served it hopelessly late."""
    router, tenant, tel, ec, step_s = _telemetry_tenant(two_tenants)
    observe_segments(tel, ec, {}, n=1)           # seed EWMA at 1x
    all_slow = {i: 10.0 for i in range(len(ec.segments()))}
    observe_segments(tel, ec, all_slow, n=8)
    live = tenant.live_step_s()
    assert live is not None and live > 5.0 * step_s
    # profiled estimate says one backlog batch makes the deadline;
    # the live estimate knows it cannot
    assert 1 * step_s <= tenant.deadline_s
    xw = np.asarray(prepare_input_packed(
        jax.random.uniform(jax.random.PRNGKey(0), (1, 28, 28, 1))
    ))[0]
    assert router.submit("a", xw) is None
    assert (tenant.admitted, tenant.rejected) == (0, 1)
    # recovery: sustained return to profiled speed re-admits
    observe_segments(tel, ec, {}, n=24)
    assert tenant.live_step_s() == pytest.approx(step_s, rel=0.1)
    assert router.submit("a", xw) is not None


def test_router_add_tenant_validates_live_min_samples(two_tenants):
    m, packed, table, ec = two_tenants
    engine = ServingEngine(
        m, packed, ec, allowed_batch_sizes=table.batch_sizes,
        clock=FakeClock(),
    )
    with pytest.raises(ValueError, match="live_min_samples"):
        FleetRouter().add_tenant("a", engine, live_min_samples=0)
