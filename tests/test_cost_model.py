"""Analytic TPU cost model: structural properties the mapper relies on."""


from repro.bnn import build_model
from repro.core import cost_model as cm
from repro.core.parallel_config import CONFIGS


def test_grid_order_changes_traffic():
    """The aspect choice must change modeled HBM traffic (reuse
    distance) — otherwise the TPU-target mapping would be degenerate."""
    dims = cm.GemmDims(b=16, p=1024, n=512, kw=72)
    traffic = {c: cm.gemm_hbm_traffic(dims, c) for c in
               ("X", "Y", "Z", "XY", "XZ", "YZ", "XYZ")}
    assert len(set(traffic.values())) > 1
    # lower bound: every operand moved at least once
    lo = dims.a_bytes + dims.w_bytes + dims.o_bytes
    assert all(t >= lo for t in traffic.values())


def test_times_positive_and_cpu_differs():
    dims = cm.GemmDims(b=4, p=64, n=64, kw=8)
    for c in CONFIGS:
        t = cm.gemm_time_tpu(dims, c)
        assert t > 0
    assert cm.gemm_time_tpu(dims, "CPU") != cm.gemm_time_tpu(dims, "XYZ")


def test_analytic_mapper_keeps_small_layers_on_host():
    """On the analytic v5e model, tiny layers must stay on CPU (the
    transfer+dispatch overhead dominates) while big conv layers go to
    a parallel config — the paper's core qualitative claim."""
    m = build_model("cifar10", scale=0.5)
    small = [s for s in m.specs if s.kind in ("mp", "step", "flat")]
    big = [s for s in m.specs if s.kind == "conv"][2:]  # later convs
    for s in small:
        t_cpu = cm.layer_time_tpu(s, "CPU", batch=16)
        t_gpu = cm.layer_time_tpu(s, "XYZ", batch=16)
        assert t_cpu < t_gpu, f"{s.notation}: cpu {t_cpu} gpu {t_gpu}"
    assert any(
        cm.layer_time_tpu(s, "XYZ", batch=128)
        < cm.layer_time_tpu(s, "CPU", batch=128)
        for s in big
    ), "no large conv benefits from the accelerator in the model"


def test_gemm_dims_for_conv_and_fc():
    m = build_model("fashion_mnist")
    conv = next(s for s in m.specs if s.kind == "conv")
    fc = next(s for s in m.specs if s.kind == "fc")
    dc = cm.gemm_dims_for(conv, batch=8)
    assert dc.p == 28 * 28 and dc.b == 8
    df = cm.gemm_dims_for(fc, batch=8)
    assert df.p == 1 and df.n == fc.units
    mp = next(s for s in m.specs if s.kind == "mp")
    assert cm.gemm_dims_for(mp, 8) is None
