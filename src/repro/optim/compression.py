"""Gradient compression for cross-pod all-reduce.

Two schemes used by the distributed train step:

* **bf16 all-reduce** — cast grads to bfloat16 before the cross-pod
  all-reduce, halving inter-pod ICI bytes at negligible quality cost
  (the standard MaxText-style trick). Pure functions so they compose
  inside pjit.
* **int8 + error feedback** — quantize to int8 with a per-tensor scale
  and carry the quantization error into the next step (1-bit-Adam-style
  error feedback, adapted). 4x byte reduction on the wire.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


class Int8ErrorFeedback(NamedTuple):
    """Carries per-leaf residual error between steps."""

    residual: Any

    @staticmethod
    def init(grads) -> "Int8ErrorFeedback":
        return Int8ErrorFeedback(
            jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
        )

    def compress(self, grads):
        """Return (int8 payload, scales, new_state). Payload is what goes
        over the wire (all-reduced in int32 accumulate then rescaled)."""

        def one(g, r):
            g = g.astype(jnp.float32) + r
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            err = g - q.astype(jnp.float32) * scale
            return q, scale, err

        flat, tdef = jax.tree.flatten(grads)
        flat_r = tdef.flatten_up_to(self.residual)
        out = [one(g, r) for g, r in zip(flat, flat_r)]
        payload = tdef.unflatten([o[0] for o in out])
        scales = tdef.unflatten([o[1] for o in out])
        new_state = Int8ErrorFeedback(tdef.unflatten([o[2] for o in out]))
        return payload, scales, new_state

    @staticmethod
    def decompress(payload, scales):
        return jax.tree.map(
            lambda q, s: q.astype(jnp.float32) * s, payload, scales
        )
