"""Optimizers (from scratch — no optax in this environment), schedules,
ZeRO-1 state sharding, and gradient compression."""

from repro.optim.optimizers import (
    OptState,
    adamw,
    sgd,
    lion,
    Optimizer,
    clip_by_global_norm,
)
from repro.optim.schedules import (
    cosine_schedule,
    linear_warmup_cosine,
    constant_schedule,
)
from repro.optim.compression import (
    compress_bf16,
    decompress_bf16,
    Int8ErrorFeedback,
)
