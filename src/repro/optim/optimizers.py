"""Minimal, pytree-generic optimizers with an optax-like
(init, update) interface.

Each optimizer is a factory returning an :class:`Optimizer` of pure
functions, so states are plain pytrees that shard/checkpoint like any
other array tree (ZeRO-1 sharding is applied by the caller via
PartitionSpecs on these trees — see ``repro.parallel.sharding``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: Any  # optimizer-specific pytree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]
    # update(grads, state, params) -> (new_params, new_state)


def _tree_zeros_like(tree, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), tree
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw(
    lr: float | Callable[[jax.Array], jax.Array],
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    state_dtype=jnp.float32,
) -> Optimizer:
    """AdamW. ``state_dtype`` lets callers halve optimizer memory
    (bf16 m/v) — a distributed-memory trick surfaced as a config knob."""

    def sched(step):
        return lr(step) if callable(lr) else jnp.asarray(lr)

    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32),
            inner={
                "m": _tree_zeros_like(params, state_dtype),
                "v": _tree_zeros_like(params, state_dtype),
            },
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched(step)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr_t * delta
            return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.inner["m"])
        flat_v = tdef.flatten_up_to(state.inner["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, inner={"m": new_m, "v": new_v})

    return Optimizer(init, update)


def sgd(
    lr: float | Callable[[jax.Array], jax.Array],
    *,
    momentum: float = 0.0,
    nesterov: bool = False,
) -> Optimizer:
    def sched(step):
        return lr(step) if callable(lr) else jnp.asarray(lr)

    def init(params):
        inner = _tree_zeros_like(params) if momentum else None
        return OptState(step=jnp.zeros((), jnp.int32), inner=inner)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched(step)
        if momentum:
            new_mom = jax.tree.map(
                lambda b, g: momentum * b + g, state.inner, grads
            )
            eff = (
                jax.tree.map(lambda g, b: g + momentum * b, grads, new_mom)
                if nesterov
                else new_mom
            )
        else:
            new_mom, eff = None, grads
        new_p = jax.tree.map(lambda p, g: p - lr_t * g, params, eff)
        return new_p, OptState(step=step, inner=new_mom)

    return Optimizer(init, update)


def lion(
    lr: float | Callable[[jax.Array], jax.Array],
    *,
    b1: float = 0.9,
    b2: float = 0.99,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Lion — sign-based update; optimizer state is a single momentum
    tree (half of Adam's), relevant for the memory roofline at scale."""

    def sched(step):
        return lr(step) if callable(lr) else jnp.asarray(lr)

    def init(params):
        return OptState(
            step=jnp.zeros((), jnp.int32), inner=_tree_zeros_like(params)
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched(step)

        def upd(g, m, p):
            c = b1 * m + (1 - b1) * g
            newp = p - lr_t * (jnp.sign(c) + weight_decay * p)
            newm = b2 * m + (1 - b2) * g
            return newp.astype(p.dtype), newm

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.inner)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (
            tdef.unflatten([o[0] for o in out]),
            OptState(step=step, inner=tdef.unflatten([o[1] for o in out])),
        )

    return Optimizer(init, update)
