"""Binarization + bit-packing primitives.

Bit convention: bit 1 encodes +1, bit 0 encodes -1. Packing is along the
last axis, 32 values per int32 word, LSB first. Tail lanes (when the axis
length is not a multiple of 32) are padded with ``pad_bit``: activations
use 0, weights use 1, so that `xnor` tail lanes are identically 0 and
``2 * popcount(xnor(a, w)) - K`` equals the exact {-1,+1} dot product over
the K true lanes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PACK_W = 32  # bits per packed word


def binarize(x: jax.Array) -> jax.Array:
    """Hard sign into {-1, +1}; ties (x == 0) go to +1 (paper's `>` is
    strict on the shifted form, equivalent to >= 0 here)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


@jax.custom_vjp
def binarize_ste(x: jax.Array) -> jax.Array:
    """Sign forward, clipped straight-through estimator backward
    (gradient passes where |x| <= 1, i.e. the Hard-Tanh STE of the paper's
    training recipe [Hubara et al. 2016])."""
    return binarize(x)


def _ste_fwd(x):
    return binarize(x), x


def _ste_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


binarize_ste.defvjp(_ste_fwd, _ste_bwd)


def packed_len(n: int) -> int:
    return (n + PACK_W - 1) // PACK_W


def pack_bits(x: jax.Array, pad_bit: int = 0) -> jax.Array:
    """Pack a {-1,+1} (or {0,1} boolean) array along the last axis into
    int32 words.

    Accepts float/int arrays in {-1,+1} or bool arrays; bit = (x > 0) for
    numeric inputs, x itself for bool.
    """
    if x.dtype == jnp.bool_:
        bits = x
    else:
        bits = x >= 0  # ties -> +1, matching binarize()
    n = bits.shape[-1]
    n_words = packed_len(n)
    pad = n_words * PACK_W - n
    if pad:
        fill = jnp.full(bits.shape[:-1] + (pad,), bool(pad_bit))
        bits = jnp.concatenate([bits, fill], axis=-1)
    bits = bits.reshape(bits.shape[:-1] + (n_words, PACK_W))
    shifts = jnp.arange(PACK_W, dtype=jnp.uint32)
    words = jnp.sum(
        bits.astype(jnp.uint32) << shifts, axis=-1, dtype=jnp.uint32
    )
    return words.astype(jnp.int32)


def unpack_bits(words: jax.Array, n: int) -> jax.Array:
    """Unpack int32 words into a float32 {-1,+1} array of last-axis
    length ``n`` (tail lanes dropped)."""
    w = words.astype(jnp.uint32)
    shifts = jnp.arange(PACK_W, dtype=jnp.uint32)
    bits = (w[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(bits.shape[:-2] + (bits.shape[-2] * PACK_W,))
    flat = flat[..., :n]
    return jnp.where(flat == 1, 1.0, -1.0).astype(jnp.float32)


def popcount(x: jax.Array) -> jax.Array:
    """Population count on int32 words, result int32."""
    return jax.lax.population_count(x.astype(jnp.uint32)).astype(jnp.int32)


def xnor_dot_words(a_words: jax.Array, w_words: jax.Array, k_true: int) -> jax.Array:
    """Exact {-1,+1} dot product of two packed vectors (last axis =
    words): ``2 * sum(popcount(~(a ^ w))) - k_true``.

    Relies on the tail-padding convention (a tail bit 0, w tail bit 1)
    making xnor tail lanes 0.
    """
    agree = jnp.sum(
        popcount(~(a_words ^ w_words)), axis=-1, dtype=jnp.int32
    )
    # popcount(xnor) counts only true-lane agreements (tail lanes are 0 by
    # the padding convention), so dot = agree - (k_true - agree).
    return 2 * agree - k_true


def np_pack_bits(x: np.ndarray, pad_bit: int = 0) -> np.ndarray:
    """NumPy twin of pack_bits for host-side weight preparation."""
    bits = (x >= 0) if x.dtype != np.bool_ else x
    n = bits.shape[-1]
    n_words = packed_len(n)
    pad = n_words * PACK_W - n
    if pad:
        fill = np.full(bits.shape[:-1] + (pad,), bool(pad_bit))
        bits = np.concatenate([bits, fill], axis=-1)
    bits = bits.reshape(bits.shape[:-1] + (n_words, PACK_W)).astype(np.uint32)
    shifts = np.arange(PACK_W, dtype=np.uint32)
    words = np.sum(bits << shifts, axis=-1, dtype=np.uint64).astype(np.uint32)
    return words.view(np.int32).reshape(words.shape)
