"""BNN substrate: bit-packing, binary layers, paper models, STE training.

Conventions (shared with kernels/ and core/):
  * A binary value is conceptually in {-1, +1}; the stored bit is 1 for +1
    and 0 for -1.
  * Packed tensors are int32 with 32 bits packed along the LAST axis,
    least-significant bit first.
  * Activation words pad their tail lanes with bit 0, weight words with
    bit 1, so xnor tail lanes are always 0 and popcount counts only true
    lanes; `dot = 2 * popcount(xnor) - K_true` is then exact.
  * Integer (pre-activation) tensors are int32.
"""

from repro.bnn.binarize import (
    pack_bits,
    unpack_bits,
    binarize,
    binarize_ste,
    PACK_W,
)
from repro.bnn.layers import (
    LayerSpec,
    parse_notation,
    init_bnn_params,
)
from repro.bnn.models import (
    FASHION_MNIST_NOTATION,
    CIFAR10_NOTATION,
    BNNModel,
    build_model,
)
