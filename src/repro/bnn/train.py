"""STE training for the paper's BNN models.

Latent fp32 weights, binarized on the forward pass (clipped STE
backward), fp batch-norm with running stats, AdamW on the latent
weights with post-update clipping to [-1, 1] (standard BNN recipe —
keeps latent weights in the STE's pass-through region).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.bnn import layers as L
from repro.bnn.models import BNNModel
from repro.optim import adamw, clip_by_global_norm
from repro.optim.optimizers import OptState


class TrainState(NamedTuple):
    params: list  # full per-layer dicts (trainable + bn state)
    opt: OptState
    step: jax.Array


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - picked)


def init_train_state(model: BNNModel, key: jax.Array, lr: float = 1e-3):
    params = model.init(key)
    opt = adamw(lr)
    trainable, _ = L.split_trainable(params)
    return TrainState(params, opt.init(trainable), jnp.zeros((), jnp.int32)), opt


@partial(jax.jit, static_argnums=(0, 1))
def train_step(model: BNNModel, opt, state: TrainState, x01, labels):
    """One STE step. Returns (new_state, metrics)."""
    trainable, bn_state = L.split_trainable(state.params)

    def loss_fn(trainable):
        params = L.merge_params(trainable, bn_state)
        logits, new_params = model.apply_fp(params, x01, train=True)
        return cross_entropy(logits.astype(jnp.float32), labels), (
            logits,
            new_params,
        )

    (loss, (logits, new_params)), grads = jax.value_and_grad(
        loss_fn, has_aux=True
    )(trainable)
    grads, gnorm = clip_by_global_norm(grads, 1.0)
    new_trainable, new_opt = opt.update(grads, state.opt, trainable)
    # clip latent weights into the STE pass-through region
    new_trainable = jax.tree.map(
        lambda p: jnp.clip(p, -1.0, 1.0), new_trainable
    )
    _, new_bn = L.split_trainable(new_params)
    merged = L.merge_params(new_trainable, new_bn)
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return (
        TrainState(merged, new_opt, state.step + 1),
        {"loss": loss, "acc": acc, "grad_norm": gnorm},
    )


@partial(jax.jit, static_argnums=(0,))
def eval_step(model: BNNModel, params, x01, labels):
    logits, _ = model.apply_fp(params, x01, train=False)
    return jnp.mean(jnp.argmax(logits, -1) == labels)
