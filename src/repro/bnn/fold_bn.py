"""Fold batch-norm + sign into integer thresholds (Sari et al. 2019,
as used by the paper's step layers).

For integer pre-activation y:
    sign(gamma * (y - mean) / sqrt(var + eps) + beta) == +1
        gamma > 0:  y >= t  where t = mean - beta * sqrt(var+eps) / gamma
                    <=> y > ceil(t) - 1          (strict int compare)
        gamma < 0:  y <= t  <=> not (y > floor(t))
        gamma == 0: constant sign(beta)  (beta >= 0 -> +1)

The packed step layer computes ``bit = (y > T) ^ flip``.
"""

from __future__ import annotations

import numpy as np

from repro.bnn.layers import BN_EPS

_BIG = np.int32(2**30)


def fold_bn(
    gamma: np.ndarray,
    beta: np.ndarray,
    mean: np.ndarray,
    var: np.ndarray,
    eps: float = BN_EPS,
) -> tuple[np.ndarray, np.ndarray]:
    """Return per-channel (threshold int32, flip bool)."""
    gamma = np.asarray(gamma, np.float64)
    beta = np.asarray(beta, np.float64)
    mean = np.asarray(mean, np.float64)
    var = np.asarray(var, np.float64)
    sd = np.sqrt(var + eps)

    with np.errstate(divide="ignore", invalid="ignore"):
        t = mean - beta * sd / gamma

    thresh = np.empty(gamma.shape, np.int64)
    flip = np.zeros(gamma.shape, bool)

    pos = gamma > 0
    neg = gamma < 0
    zero = gamma == 0

    thresh[pos] = np.ceil(t[pos]).astype(np.int64) - 1
    thresh[neg] = np.floor(t[neg]).astype(np.int64)
    flip[neg] = True
    # gamma == 0: output is constant sign(beta); beta >= 0 -> always fire
    bz = beta[zero] >= 0
    thresh[zero] = np.where(bz, -_BIG, _BIG)

    return np.clip(thresh, -_BIG, _BIG).astype(np.int32), flip
