"""Paper BNN models (Tables I & II) + packed-inference parameter
preparation.

`build_model` returns a :class:`BNNModel` whose `specs` drive both the
fp-sim training forward and the per-layer packed inference used by the
HEP mapper.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.bnn import layers as L
from repro.bnn.binarize import np_pack_bits, pack_bits
from repro.bnn.fold_bn import fold_bn

# Table II — FashionMNIST BNN (10 layers)
FASHION_MNIST_NOTATION = (
    "C64", "MP14", "S", "C64", "MP7", "S", "FLAT", "FC2048", "S", "FC2048",
)
# Table I — CIFAR-10 BNN (19 layers)
CIFAR10_NOTATION = (
    "C64", "S", "C64", "MP16", "S", "C256", "S", "C256", "MP8", "S",
    "C512", "S", "C512", "MP4", "S", "FLAT", "FC1024", "S", "FC1024",
)


@dataclasses.dataclass(frozen=True)
class BNNModel:
    name: str
    specs: tuple
    input_hw: tuple
    in_channels: int
    n_classes: int

    def init(self, key: jax.Array) -> list[dict]:
        return L.init_bnn_params(key, self.specs)

    def apply_fp(self, params, x01, *, train=False):
        """[0,1] images -> logits (fp-sim path)."""
        x = L.binarize_input(x01)
        return L.forward_fp(self.specs, params, x, train=train)


_REGISTRY = {
    "fashion_mnist": (FASHION_MNIST_NOTATION, (28, 28), 1, 10),
    "cifar10": (CIFAR10_NOTATION, (32, 32), 3, 10),
}


def build_model(name: str, *, scale: float = 1.0) -> BNNModel:
    """Build a paper model. ``scale`` < 1 shrinks channel/unit counts
    (for smoke tests) while preserving the layer structure."""
    notation, hw, cin, ncls = _REGISTRY[name]
    if scale != 1.0:
        def shrink(tok: str) -> str:
            import re
            if m := re.fullmatch(r"(C|FC)(\d+)", tok):
                n = max(32, int(int(m.group(2)) * scale))
                n = (n // 32) * 32  # keep word-aligned
                return f"{m.group(1)}{n}"
            return tok
        notation = tuple(shrink(t) for t in notation)
    specs = tuple(L.parse_notation(notation, hw, cin, ncls))
    return BNNModel(name, specs, hw, cin, ncls)


# ---------------------------------------------------------------------------
# Packed-inference parameter preparation
# ---------------------------------------------------------------------------


def pack_params(specs: Sequence[L.LayerSpec], params: list[dict]) -> list[dict]:
    """Quantize trained fp params into packed inference params.

    conv:  w (3,3,Cin,Cout) -> words (Cout, 9*ceil(Cin/32)), tail bit 1
    fc:    w (Din,Dout)     -> words (Dout, ceil(Din/32)),   tail bit 1
    step:  gamma/beta/mean/var -> (thresh int32, flip bool) per channel
    """
    packed: list[dict] = []
    for spec, p in zip(specs, params):
        if spec.kind == "conv":
            w = np.asarray(p["w"])              # (3,3,Cin,Cout)
            cin, cout = w.shape[2], w.shape[3]
            # (Cout, 9, Cin): patch order must match extract_patch_words
            # (dy-major, dx-minor), i.e. w[dy,dx] for dy in 0..2, dx in 0..2
            wt = np.transpose(w, (3, 0, 1, 2)).reshape(cout, 9, cin)
            words = np_pack_bits(np.sign(wt) + 0.5, pad_bit=1)
            packed.append(
                {"w_words": jnp.asarray(words.reshape(cout, -1)),
                 "k_true": 9 * cin}
            )
        elif spec.kind == "fc":
            w = np.asarray(p["w"])              # (Din, Dout)
            words = np_pack_bits(np.sign(w.T) + 0.5, pad_bit=1)
            packed.append(
                {"w_words": jnp.asarray(words), "k_true": w.shape[0]}
            )
        elif spec.kind == "step":
            t, f = fold_bn(p["gamma"], p["beta"], p["mean"], p["var"])
            packed.append({"thresh": jnp.asarray(t), "flip": jnp.asarray(f)})
        else:
            packed.append({})
    return packed


def prepare_input_packed(x01: jax.Array) -> jax.Array:
    """[0,1] images (B,H,W,C) -> packed words (B,H,W,ceil(C/32)),
    matching the fp path's binarize_input (threshold 0.5, ties -> +1)."""
    return pack_bits(x01 - 0.5 >= 0)


def forward_packed(
    specs: Sequence[L.LayerSpec], packed: list[dict], x_words: jax.Array
) -> jax.Array:
    """Reference packed inference (the 'CPU' implementation end to end).
    Returns int32 class scores."""
    x = x_words
    for spec, p in zip(specs, packed):
        if spec.kind == "conv":
            x = L.conv_packed(x, p["w_words"], p["k_true"])
        elif spec.kind == "mp":
            x = L.maxpool_packed(x)
        elif spec.kind == "step":
            x = L.step_packed(x, p["thresh"], p["flip"])
        elif spec.kind == "flat":
            x = L.flat_packed(x, spec.in_shape[-1])
        elif spec.kind == "fc":
            x = L.fc_packed(x, p["w_words"], p["k_true"])
    return x
