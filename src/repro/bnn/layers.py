"""BNN layer specs, parameter init, fp-sim (training) and packed-integer
(inference) per-layer implementations.

Two execution domains:

* **fp-sim** (training): values are float32 in {-1,+1} between layers,
  integers-as-floats for pre-activations; weights are latent fp32
  binarized on the forward pass with the straight-through estimator.
* **packed** (inference): binary tensors are bit-packed int32 words
  (see ``repro.bnn.binarize``); pre-activations are int32; step layers
  use batch-norm folded into integer thresholds (``repro.bnn.fold_bn``).

The packed per-layer functions here are the **CPU implementation** in the
paper's sense — the sequential reference. The parallel X/Y/Z variants
live in ``repro.kernels`` and are selected per layer by the HEP mapper.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.bnn.binarize import (
    PACK_W,
    binarize,
    binarize_ste,
    pack_bits,
    popcount,
)

BN_EPS = 1e-5
BN_MOMENTUM = 0.1


# ---------------------------------------------------------------------------
# Layer specs / notation parsing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Static description of one layer in paper notation."""

    idx: int            # 1-based position, as in the paper's tables
    kind: str           # 'conv' | 'mp' | 'step' | 'flat' | 'fc'
    notation: str       # e.g. 'C64', 'MP16', 'S', 'FLAT', 'FC1024'
    in_shape: tuple     # per-example logical shape (no batch), unpacked
    out_shape: tuple    # per-example logical shape (no batch), unpacked
    # conv/fc: number of output units; step: channel count
    units: int = 0

    @property
    def reduce_dim(self) -> int:
        """Reduction length K for conv (9*Cin) / fc (Din)."""
        if self.kind == "conv":
            return 9 * self.in_shape[-1]
        if self.kind == "fc":
            return int(np.prod(self.in_shape))
        return 0


def parse_notation(
    notation: Sequence[str],
    input_hw: tuple,
    in_channels: int,
    n_classes: int,
) -> list[LayerSpec]:
    """Build LayerSpecs from paper notation.

    The final FC layer maps its input to ``n_classes`` (the paper's
    trailing '-> 10'); every other FCx maps to x units. Convs are 3x3,
    SAME (pad value -1); maxpool is 2x2/2 with MPx asserting output x.
    """
    specs: list[LayerSpec] = []
    h, w = input_hw
    shape: tuple = (h, w, in_channels)
    last_fc = max(
        i for i, s in enumerate(notation) if s.startswith("FC")
    )
    for i, token in enumerate(notation):
        idx = i + 1
        if m := re.fullmatch(r"C(\d+)", token):
            cout = int(m.group(1))
            out = (shape[0], shape[1], cout)
            specs.append(LayerSpec(idx, "conv", token, shape, out, cout))
        elif m := re.fullmatch(r"MP(\d+)", token):
            tgt = int(m.group(1))
            out = (shape[0] // 2, shape[1] // 2, shape[2])
            if out[0] != tgt:
                raise ValueError(
                    f"{token} at layer {idx}: 2x2 pool of {shape} gives "
                    f"{out[0]}, expected {tgt}"
                )
            specs.append(LayerSpec(idx, "mp", token, shape, out, shape[2]))
        elif token == "S":
            specs.append(
                LayerSpec(idx, "step", token, shape, shape, shape[-1])
            )
        elif token == "FLAT":
            out = (int(np.prod(shape)),)
            specs.append(LayerSpec(idx, "flat", token, shape, out))
        elif m := re.fullmatch(r"FC(\d+)", token):
            din = int(np.prod(shape))
            dout = n_classes if i == last_fc else int(m.group(1))
            if i == last_fc and int(m.group(1)) != din:
                # paper notation: trailing FCx names its input width
                pass
            out = (dout,)
            specs.append(LayerSpec(idx, "fc", token, (din,), out, dout))
        else:
            raise ValueError(f"unknown layer token {token!r}")
        shape = specs[-1].out_shape
    return specs


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_bnn_params(key: jax.Array, specs: Sequence[LayerSpec]) -> list[dict]:
    """One dict per layer. Trainable: conv/fc 'w' (latent fp32), step
    'gamma'/'beta'. State: step 'mean'/'var' (running stats)."""
    params: list[dict] = []
    for spec in specs:
        if spec.kind == "conv":
            cin = spec.in_shape[-1]
            key, sub = jax.random.split(key)
            scale = 1.0 / np.sqrt(9 * cin)
            params.append(
                {"w": jax.random.uniform(
                    sub, (3, 3, cin, spec.units), jnp.float32, -scale, scale
                )}
            )
        elif spec.kind == "fc":
            din = spec.in_shape[0]
            key, sub = jax.random.split(key)
            scale = 1.0 / np.sqrt(din)
            params.append(
                {"w": jax.random.uniform(
                    sub, (din, spec.units), jnp.float32, -scale, scale
                )}
            )
        elif spec.kind == "step":
            c = spec.units
            params.append(
                {
                    "gamma": jnp.ones((c,), jnp.float32),
                    "beta": jnp.zeros((c,), jnp.float32),
                    "mean": jnp.zeros((c,), jnp.float32),
                    "var": jnp.ones((c,), jnp.float32),
                }
            )
        else:
            params.append({})
    return params


TRAINABLE_KEYS = {"w", "gamma", "beta"}


def split_trainable(params: list[dict]) -> tuple[list[dict], list[dict]]:
    train = [
        {k: v for k, v in p.items() if k in TRAINABLE_KEYS} for p in params
    ]
    state = [
        {k: v for k, v in p.items() if k not in TRAINABLE_KEYS}
        for p in params
    ]
    return train, state


def merge_params(train: list[dict], state: list[dict]) -> list[dict]:
    return [dict(**t, **s) for t, s in zip(train, state)]


# ---------------------------------------------------------------------------
# fp-sim (training) per-layer forwards
# ---------------------------------------------------------------------------


def conv_fp(x: jax.Array, w_latent: jax.Array) -> jax.Array:
    """3x3 SAME binary conv on {-1,+1} inputs; pad value -1 (binary
    domain has no zero). Output is integer-valued float32."""
    wb = binarize_ste(w_latent)
    xp = jnp.pad(
        x, ((0, 0), (1, 1), (1, 1), (0, 0)), constant_values=-1.0
    )
    return jax.lax.conv_general_dilated(
        xp, wb, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def maxpool_fp(x: jax.Array) -> jax.Array:
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def step_fp(
    x: jax.Array, p: dict, *, train: bool
) -> tuple[jax.Array, dict]:
    """Batch norm + binary activation (Hard-Tanh STE). Returns output and
    updated running-stat dict."""
    axes = tuple(range(x.ndim - 1))
    if train:
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_state = {
            "mean": (1 - BN_MOMENTUM) * p["mean"] + BN_MOMENTUM * mean,
            "var": (1 - BN_MOMENTUM) * p["var"] + BN_MOMENTUM * var,
        }
    else:
        mean, var = p["mean"], p["var"]
        new_state = {"mean": p["mean"], "var": p["var"]}
    y = (x - mean) * jax.lax.rsqrt(var + BN_EPS) * p["gamma"] + p["beta"]
    return binarize_ste(y), new_state


def fc_fp(x: jax.Array, w_latent: jax.Array) -> jax.Array:
    return x @ binarize_ste(w_latent)


def forward_fp(
    specs: Sequence[LayerSpec],
    params: list[dict],
    x_pm1: jax.Array,
    *,
    train: bool = False,
) -> tuple[jax.Array, list[dict]]:
    """Full fp-sim forward on a {-1,+1} input batch (B,H,W,C). Returns
    (logits, params-with-updated-bn-state)."""
    new_params = []
    x = x_pm1
    for spec, p in zip(specs, params):
        if spec.kind == "conv":
            x = conv_fp(x, p["w"])
            new_params.append(p)
        elif spec.kind == "mp":
            x = maxpool_fp(x)
            new_params.append(p)
        elif spec.kind == "step":
            x, new_state = step_fp(x, p, train=train)
            new_params.append({**p, **new_state})
        elif spec.kind == "flat":
            x = x.reshape(x.shape[0], -1)
            new_params.append(p)
        elif spec.kind == "fc":
            x = fc_fp(x, p["w"])
            new_params.append(p)
    return x, new_params


def binarize_input(x01: jax.Array) -> jax.Array:
    """Map images in [0,1] to {-1,+1} (threshold 0.5)."""
    return binarize(x01 - 0.5)


# ---------------------------------------------------------------------------
# Packed-integer (inference) per-layer forwards — the 'CPU' implementation
# ---------------------------------------------------------------------------


def extract_patch_words(x_words: jax.Array) -> jax.Array:
    """(B,H,W,Cw) packed -> (B,H,W,9*Cw) 3x3 SAME patches. Spatial pad
    words are 0 == all -1 pixels (the binary-domain pad value)."""
    b, h, w, cw = x_words.shape
    xp = jnp.pad(x_words, ((0, 0), (1, 1), (1, 1), (0, 0)))
    offs = [
        xp[:, dy : dy + h, dx : dx + w, :]
        for dy in range(3)
        for dx in range(3)
    ]
    return jnp.concatenate(offs, axis=-1)


def conv_packed(
    x_words: jax.Array, w_words: jax.Array, k_true: int
) -> jax.Array:
    """Packed binary conv. x_words (B,H,W,Cw); w_words (Cout, 9*Cw);
    output int32 (B,H,W,Cout) with exact {-1,+1} conv values."""
    patches = extract_patch_words(x_words)          # (B,H,W,9Cw)
    # xnor each patch against each output channel's weight words, sum
    # popcounts over the word axis
    xn = ~(patches[:, :, :, None, :] ^ w_words[None, None, None, :, :])
    agree = jnp.sum(popcount(xn), axis=-1, dtype=jnp.int32)
    return 2 * agree - k_true


def maxpool_packed(x: jax.Array) -> jax.Array:
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def step_packed(
    x_int: jax.Array, thresh: jax.Array, flip: jax.Array
) -> jax.Array:
    """int32 pre-activations -> packed bits via per-channel integer
    threshold: bit = (x > T) ^ flip."""
    bits = (x_int > thresh) ^ flip
    return pack_bits(bits)


def flat_packed(x_words: jax.Array, channels: int) -> jax.Array:
    """(B,h,w,Cw) -> (B, h*w*Cw). Requires channels % 32 == 0 so no tail
    lanes interleave (true for all paper models at the FLAT position)."""
    if channels % PACK_W != 0:
        raise ValueError("flatten of packed words needs C % 32 == 0")
    return x_words.reshape(x_words.shape[0], -1)


def fc_packed(
    x_words: jax.Array, w_words: jax.Array, k_true: int
) -> jax.Array:
    """Packed binary FC. x (B, Kw); w (Dout, Kw); out int32 (B, Dout)."""
    xn = ~(x_words[:, None, :] ^ w_words[None, :, :])
    agree = jnp.sum(popcount(xn), axis=-1, dtype=jnp.int32)
    return 2 * agree - k_true
