"""Architecture configuration dataclasses."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int            # routed experts
    top_k: int
    n_shared: int = 0         # always-on shared experts
    d_expert: int = 0         # per-expert FFN width (0 -> use d_ff)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4
    n_groups: int = 1
    chunk: int = 128          # SSD chunk length


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    attn_every: int = 6       # shared attn block after every k ssm layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp_type: str = "silu"    # silu (gated) | gelu (plain)
    norm: str = "rms"         # rms | nonparam (olmo)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # modality frontend stubs (vlm/audio): number of precomputed
    # frame/patch embeddings prepended to the token sequence
    n_frontend_embeds: int = 0
    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # true sub-quadratic context support (ssm/hybrid) — gates long_500k
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    def n_params(self) -> int:
        """Total parameter count (for 6ND roofline math)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.hd
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            per = self._ssm_layer_params()
            return emb + self.n_layers * per
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.qkv_bias:
            attn += hd * (self.n_heads + 2 * self.n_kv_heads)
        if self.moe:
            fe = self.moe.d_expert or f
            mlp = self.moe.n_experts * 3 * d * fe + d * self.moe.n_experts
            mlp += self.moe.n_shared * 3 * d * fe
        elif self.mlp_type == "silu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        norms = 2 * d if self.norm == "rms" else 0
        if self.family == "hybrid":
            ssm_per = self._ssm_layer_params()
            n_shared_blocks = 1
            shared = attn + 3 * d * f + (2 * d if self.norm == "rms" else 0)
            return emb + self.n_layers * ssm_per + n_shared_blocks * shared
        return emb + self.n_layers * (attn + mlp + norms)

    def _ssm_layer_params(self) -> int:
        s = self.ssm
        d = self.d_model
        din = self.d_inner
        gn = s.n_groups * s.d_state
        h = self.ssm_heads
        in_proj = d * (2 * din + 2 * gn + h)
        conv = s.conv_kernel * (din + 2 * gn)
        extras = 3 * h + din  # A_log, D, dt_bias, gated-norm
        out_proj = din * d
        norm = d if self.norm == "rms" else 0
        return in_proj + conv + extras + out_proj + norm

    def n_active_params(self) -> int:
        """Active params per token (MoE: shared + top-k routed only)."""
        if not self.moe:
            return self.n_params()
        d, v = self.d_model, self.vocab
        fe = self.moe.d_expert or self.d_ff
        hd = self.hd
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        active_mlp = (self.moe.top_k + self.moe.n_shared) * 3 * d * fe
        router = d * self.moe.n_experts
        norms = 2 * d if self.norm == "rms" else 0
        return emb + self.n_layers * (attn + active_mlp + router + norms)
