"""Mixture-of-Experts layer: shared + routed experts with top-k routing
and GROUPED capacity-based dispatch (SPMD-friendly).

Dispatch is computed independently per token group (group = one batch
row), so every routing primitive (cumsum for position-in-expert,
scatter into the expert buffer) is local to a group and parallelizes
over the data axis — a global flat-token cumsum would force GSPMD to
replicate the whole token stream (observed: ~60 TiB/dev collectives on
grok before this design; EXPERIMENTS.md §Perf iteration 0).

Flow per group g (vmapped over G groups):
  1. router logits -> top-k (expert_id, gate)
  2. position-within-expert via per-group cumulative one-hot counts
  3. scatter token activations into a (E, C_g, d) buffer (overflow
     dropped — DeepSeek's shared experts still cover dropped tokens)
  4. buffers stacked (G, E, C_g, d), sharding-constrained to
     (data, model/EP, None, None) -> XLA inserts the all-to-all
  5. batched expert FFN einsum over (E@model)
  6. gather back per group, combine with gates

Shared experts are fused into one wider gated MLP (mathematically
identical to summing n_shared experts of width d_expert).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.constrain import constrain


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    m = cfg.moe
    c = int(tokens_per_group * m.top_k * m.capacity_factor / m.n_experts)
    return max(4, -(-c // 4) * 4)


def route(router_logits: jax.Array, top_k: int):
    """(T, E) -> normalized gates (T, k) + expert ids (T, k)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, ids


def _dispatch_group(x_g, ids_g, C: int, E: int):
    """x_g (Tg, d); ids_g (Tg, k). Returns (buf (E, C, d), keep (Tg*k,),
    safe_e, safe_c) for one group."""
    Tg, d = x_g.shape
    k = ids_g.shape[1]
    flat_ids = ids_g.reshape(-1)                       # (Tg*k,)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0) - onehot, flat_ids[:, None], axis=1
    )[:, 0]
    keep = pos < C
    safe_e = jnp.where(keep, flat_ids, 0)
    safe_c = jnp.where(keep, pos, C)                   # C = trash column
    xk = jnp.repeat(x_g, k, axis=0)                    # (Tg*k, d)
    buf = jnp.zeros((E, C + 1, d), x_g.dtype).at[safe_e, safe_c].add(xk)
    return buf[:, :C, :], keep, safe_e, safe_c


def moe_ffn(
    x: jax.Array,           # (G, Tg, d) grouped tokens (G = batch rows)
    p: dict,                # router (d,E); wg/wu (E,d,Fe); wd (E,Fe,d)
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (G, Tg, d), aux load-balance loss)."""
    m = cfg.moe
    G, Tg, d = x.shape
    E, k = m.n_experts, m.top_k
    C = capacity(cfg, Tg)

    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gates, ids = route(logits.reshape(G * Tg, E), k)
    gates = gates.reshape(G, Tg, k)
    ids = ids.reshape(G, Tg, k)

    buf, keep, safe_e, safe_c = jax.vmap(
        lambda xg, ig: _dispatch_group(xg, ig, C, E)
    )(x, ids)                                          # buf (G,E,C,d)
    # EP boundary: groups over data, experts over model (all-to-all)
    buf = constrain(buf, ("pod", "data"), "model", None, None)

    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"]))
    u = jnp.einsum("gecd,edf->gecf", buf, p["wu"])
    y = jnp.einsum("gecf,efd->gecd", g * u, p["wd"])   # (G,E,C,d)
    # combine boundary: bring every expert's outputs back to the
    # owning group's shard BEFORE the local gather (this resharding is
    # the combine all-to-all; gathering across a model-sharded E dim
    # instead makes GSPMD emit token*k*d-sized all-reduces per layer)
    y = constrain(y, ("pod", "data"), None, None, None)

    def gather_group(y_g, keep_g, se, sc, gates_g):
        yk = y_g[se, jnp.minimum(sc, C - 1)]           # (Tg*k, d)
        yk = jnp.where(keep_g[:, None], yk, 0.0)
        yk = yk.reshape(Tg, k, d) * gates_g[..., None].astype(yk.dtype)
        return jnp.sum(yk, axis=1)

    out = jax.vmap(gather_group)(y, keep, safe_e, safe_c, gates)

    # load-balance aux (Switch-style): E * sum_e f_e * P_e
    probs_mean = jnp.mean(
        jax.nn.softmax(logits.reshape(G * Tg, E), -1), axis=0
    )
    frac = jnp.mean(
        jax.nn.one_hot(ids.reshape(G * Tg, k), E, dtype=jnp.float32).sum(1),
        axis=0,
    ) / k
    aux = E * jnp.sum(frac * probs_mean)
    return out.astype(x.dtype), aux
