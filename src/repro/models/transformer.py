"""Unified decoder: dense / MoE / SSM / hybrid / VLM / audio backbones.

Layer stacking always uses ``jax.lax.scan`` over stacked params
(leading L axis) — small HLO, per-layer remat, and decode caches ride
the scan as xs/ys. The hybrid (zamba2) family scans 6-layer Mamba
segments with a weight-shared attention block applied between segments.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import modules as M
from repro.models.config import ModelConfig
from repro.models.mamba2 import mamba_block
from repro.models.moe import moe_ffn
from repro.parallel.constrain import (
    attn_kv_parallel_enabled, constrain_kv,
    pin_batch, sp_residual_enabled,
)

_BATCH_AXES = ("pod", "data")


def _pin_residual(x: jax.Array) -> jax.Array:
    """Pin the residual stream to (batch@data-axes, seq, d replicated).
    Without this GSPMD may trade the batch sharding away to satisfy
    ZeRO-3 weight shardings, replicating (L,B,S,d)-sized backward
    residuals per device (observed on grok: +96 GiB/dev). Batch axes
    follow the scheme policy (small archs fold 'model' in). Under
    sequence parallelism the seq dim additionally shards over 'model'
    (saved residuals /16; GSPMD inserts the SP all-gather before
    projections)."""
    seq_ax = "model" if sp_residual_enabled() else None
    return pin_batch(x, seq_ax, None)

Cache = dict  # {'k','v','len'} or {'conv','ssd','len'} or hybrid union


# ---------------------------------------------------------------------------
# Parameter shape definitions (shared by init_params / param_specs)
# ---------------------------------------------------------------------------


def _attn_block_shapes(cfg: ModelConfig, prefix_l: tuple) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    sh = {
        "wq": prefix_l + (d, H * hd),
        "wk": prefix_l + (d, Hkv * hd),
        "wv": prefix_l + (d, Hkv * hd),
        "wo": prefix_l + (H * hd, d),
    }
    if cfg.qkv_bias:
        sh |= {
            "bq": prefix_l + (H * hd,),
            "bk": prefix_l + (Hkv * hd,),
            "bv": prefix_l + (Hkv * hd,),
        }
    return sh


def _mlp_shapes(cfg: ModelConfig, prefix_l: tuple, d_ff: int) -> dict:
    d = cfg.d_model
    if cfg.mlp_type == "silu":
        return {
            "wg": prefix_l + (d, d_ff),
            "wu": prefix_l + (d, d_ff),
            "wd": prefix_l + (d_ff, d),
        }
    return {"wu": prefix_l + (d, d_ff), "wd": prefix_l + (d_ff, d)}


def _mamba_shapes(cfg: ModelConfig, prefix_l: tuple) -> dict:
    """Projections kept SEPARATE (not fused) so each output dim shards
    cleanly over the model axis without split-point resharding."""
    s = cfg.ssm
    d, din = cfg.d_model, cfg.d_inner
    gn = s.n_groups * s.d_state
    H = cfg.ssm_heads
    return {
        "in_z": prefix_l + (d, din),
        "in_x": prefix_l + (d, din),
        "in_bc": prefix_l + (d, 2 * gn),
        "in_dt": prefix_l + (d, H),
        "conv_x_w": prefix_l + (s.conv_kernel, din),
        "conv_x_b": prefix_l + (din,),
        "conv_bc_w": prefix_l + (s.conv_kernel, 2 * gn),
        "conv_bc_b": prefix_l + (2 * gn,),
        "A_log": prefix_l + (H,),
        "D": prefix_l + (H,),
        "dt_bias": prefix_l + (H,),
        "gnorm": prefix_l + (din,),
        "out_proj": prefix_l + (din, d),
    }


def _shape_tree(cfg: ModelConfig) -> dict:
    d, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    lp = (L,)
    tree: dict = {"embed": (V, d)}
    if not cfg.tie_embeddings:
        tree["lm_head"] = (d, V)
    if cfg.norm == "rms":
        tree["final_norm"] = (d,)

    if cfg.family == "ssm":
        blocks = {"mamba": _mamba_shapes(cfg, lp)}
        if cfg.norm == "rms":
            blocks["ln1"] = lp + (d,)
        tree["blocks"] = blocks
        return tree

    if cfg.family == "hybrid":
        blocks = {"mamba": _mamba_shapes(cfg, lp)}
        if cfg.norm == "rms":
            blocks["ln1"] = lp + (d,)
        tree["blocks"] = blocks
        shared = {
            "attn": _attn_block_shapes(cfg, ()),
            "mlp": _mlp_shapes(cfg, (), cfg.d_ff),
        }
        if cfg.norm == "rms":
            shared["ln1"] = (d,)
            shared["ln2"] = (d,)
        tree["shared"] = shared
        return tree

    blocks: dict = {"attn": _attn_block_shapes(cfg, lp)}
    if cfg.norm == "rms":
        blocks["ln1"] = lp + (d,)
        blocks["ln2"] = lp + (d,)
    if cfg.moe:
        fe = cfg.moe.d_expert or cfg.d_ff
        E = cfg.moe.n_experts
        blocks["moe"] = {
            "router": lp + (d, E),
            "wg": lp + (E, d, fe),
            "wu": lp + (E, d, fe),
            "wd": lp + (E, fe, d),
        }
        if cfg.moe.n_shared:
            blocks["mlp"] = _mlp_shapes(cfg, lp, cfg.moe.n_shared * fe)
    else:
        blocks["mlp"] = _mlp_shapes(cfg, lp, cfg.d_ff)
    tree["blocks"] = blocks
    return tree


def param_specs(cfg: ModelConfig) -> Any:
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda sh: jax.ShapeDtypeStruct(sh, dt),
        _shape_tree(cfg),
        is_leaf=lambda x: isinstance(x, tuple),
    )


def init_params(cfg: ModelConfig, key: jax.Array) -> Any:
    """Real initialization (smoke tests / examples; dry-run never calls
    this). Scaled-normal for matmuls, ones for norm scales, SSD-specific
    init for A_log/dt_bias."""
    dt = jnp.dtype(cfg.dtype)
    shapes = _shape_tree(cfg)
    leaves, treedef = jax.tree.flatten(
        shapes, is_leaf=lambda x: isinstance(x, tuple)
    )
    paths = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple)
    )[0]
    keys = jax.random.split(key, len(leaves))

    def init_one(path, sh, k):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("ln1", "ln2", "final_norm", "gnorm"):
            return jnp.ones(sh, dt)
        if name in ("conv_b", "bq", "bk", "bv", "D"):
            return jnp.zeros(sh, dt) if name != "D" else jnp.ones(sh, dt)
        if name == "A_log":
            # A in [1, 16) as in mamba2 reference init
            u = jax.random.uniform(k, sh, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dt)
        if name == "dt_bias":
            # dt ~ U[1e-3, 1e-1] through softplus-inverse
            u = jax.random.uniform(k, sh, jnp.float32, 1e-3, 1e-1)
            return jnp.log(jnp.expm1(u)).astype(dt)
        fan_in = sh[-2] if len(sh) >= 2 else sh[-1]
        return (
            jax.random.normal(k, sh, jnp.float32) / math.sqrt(fan_in)
        ).astype(dt)

    inits = [
        init_one(p, sh, k) for (p, sh), k in zip(paths, keys)
    ]
    return jax.tree.unflatten(treedef, inits)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _proj_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = M.rope(q, positions, cfg.rope_theta)
    k = M.rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_full(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    """Train / prefill attention. Returns (out, (k, v)).

    The kv returned for the cache are sharding-constrained COPIES —
    constraining the values the attention itself consumes would
    back-propagate the cache layout into the chunked softmax (see
    constrain_kv)."""
    q, k, v = _proj_qkv(cfg, p, x, positions)
    if attn_kv_parallel_enabled():
        o = M.chunked_attention_kv_parallel(
            q, k, v, causal=True,
            q_chunk=cfg.attn_q_chunk, remat_chunks=cfg.remat,
        )
    else:
        o = M.chunked_attention(
            q, k, v, causal=True,
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            remat_chunks=cfg.remat,
        )
    B, S = x.shape[:2]
    out = o.reshape(B, S, -1) @ p["wo"]
    return out, (constrain_kv(k), constrain_kv(v))


def attn_decode(
    cfg: ModelConfig, p: dict, x: jax.Array,
    cache_k: jax.Array, cache_v: jax.Array, cache_len: jax.Array,
):
    """Single-token decode against a (B, Smax, Hkv, hd) cache.
    Grouped einsum avoids materializing repeated KV heads."""
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = H // Hkv
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    q, k, v = _proj_qkv(cfg, p, x, positions)
    new_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, cache_len, 0, 0)
    )
    new_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, cache_len, 0, 0)
    )
    qg = q.reshape(B, Hkv, g, hd)
    s = jnp.einsum(
        "bkgd,bskd->bkgs",
        qg.astype(jnp.float32), new_k.astype(jnp.float32),
    ) * (hd ** -0.5)                              # (B,Hkv,g,Smax)
    kpos = jnp.arange(new_k.shape[1])
    s = jnp.where(kpos[None, None, None, :] <= cache_len, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgs,bskd->bkgd", w, new_v.astype(jnp.float32)
    ).astype(x.dtype)
    out = o.reshape(B, 1, H * hd) @ p["wo"]
    return out, (new_k, new_v)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _mlp_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp_type == "silu":
        return M.gated_mlp(x, p["wg"], p["wu"], p["wd"])
    if cfg.mlp_type == "relu2":
        return M.relu2_mlp(x, p["wu"], p["wd"])
    return M.gelu_mlp(x, p["wu"], p["wd"])


def attn_block_apply(
    cfg: ModelConfig, bp: dict, x: jax.Array, positions,
    *, cache: Optional[dict] = None, cache_len=None,
):
    """One attention block. Returns (x, kv_for_cache, aux_loss)."""
    x = _pin_residual(x)
    h = M.apply_norm(cfg.norm, x, bp.get("ln1"))
    if cache is None:
        a, kv = attn_full(cfg, bp["attn"], h, positions)
    else:
        a, kv = attn_decode(
            cfg, bp["attn"], h, cache["k"], cache["v"], cache_len
        )
    x = x + a
    h2 = M.apply_norm(cfg.norm, x, bp.get("ln2"))
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        # groups = batch rows: dispatch stays local per data shard
        m, aux = moe_ffn(h2, bp["moe"], cfg)
        if cfg.moe.n_shared:
            m = m + _mlp_apply(cfg, bp["mlp"], h2)
    else:
        m = _mlp_apply(cfg, bp["mlp"], h2)
    return x + m, kv, aux


def mamba_block_apply(
    cfg: ModelConfig, bp: dict, x: jax.Array,
    *, cache: Optional[dict] = None,
):
    x = _pin_residual(x)
    h = M.apply_norm(cfg.norm, x, bp.get("ln1"))
    out, new_cache = mamba_block(cfg, h, bp["mamba"], cache=cache)
    return x + out, new_cache


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params, tokens, frontend_embeds):
    x = params["embed"][tokens].astype(cfg.dtype)
    if frontend_embeds is not None:
        x = jnp.concatenate(
            [frontend_embeds.astype(cfg.dtype), x], axis=1
        )
    return x


def _unembed(cfg: ModelConfig, params, x):
    x = M.apply_norm(cfg.norm, x, params.get("final_norm"))
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    return (x @ head).astype(jnp.float32)


def forward(
    cfg: ModelConfig,
    params: Any,
    tokens: jax.Array,
    *,
    frontend_embeds: Optional[jax.Array] = None,
    cache: Optional[Cache] = None,
    return_cache: bool = False,
    last_only: bool = False,
):
    """Returns (logits, new_cache_or_None, moe_aux_loss).

    cache=None             -> train / prefill over the full sequence
    cache + tokens (B,1)   -> single-token decode
    last_only=True         -> unembed only the final position (prefill:
                              avoids materializing (B,S,V) logits)
    """
    x = _pin_residual(_embed(cfg, params, tokens, frontend_embeds))
    B, S, _ = x.shape
    decode = cache is not None and S == 1
    if decode:
        cache_len = cache["len"]
        positions = None  # decode blocks derive positions from cache_len
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    if cfg.family == "ssm":
        x, new_cache = _forward_ssm(cfg, params, x, cache, decode)
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "hybrid":
        x, new_cache, aux = _forward_hybrid(
            cfg, params, x, positions, cache, decode
        )
    else:
        x, new_cache, aux = _forward_attn(
            cfg, params, x, positions, cache, decode, return_cache
        )

    if last_only:
        x = x[:, -1:, :]
    logits = _unembed(cfg, params, x)
    if new_cache is not None:
        new_cache["len"] = (cache["len"] if decode else 0) + (
            1 if decode else S
        )
    if not (return_cache or decode):
        new_cache = None
    return logits, new_cache, aux


def _forward_attn(cfg, params, x, positions, cache, decode, return_cache):
    aux0 = jnp.zeros((), jnp.float32)

    if decode:
        cache_len = cache["len"]

        def body(carry, xs):
            h, aux = carry
            bp, ck, cv = xs
            h, (nk, nv), a = attn_block_apply(
                cfg, bp, h, None,
                cache={"k": ck, "v": cv}, cache_len=cache_len,
            )
            return (h, aux + a), (nk, nv)

        (x, aux), (ks, vs) = jax.lax.scan(
            body, (x, aux0), (params["blocks"], cache["k"], cache["v"])
        )
        return x, {"k": ks, "v": vs}, aux

    def body(carry, bp):
        h, aux = carry
        h, kv, a = attn_block_apply(cfg, bp, h, positions)
        return (h, aux + a), kv if return_cache else None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), kvs = jax.lax.scan(body, (x, aux0), params["blocks"])
    new_cache = (
        {"k": kvs[0], "v": kvs[1]} if return_cache else None
    )
    return x, new_cache, aux


_SSM_CACHE_KEYS = ("conv_x", "conv_bc", "ssd")


def _forward_ssm(cfg, params, x, cache, decode):
    if decode:
        def body(h, xs):
            bp, ck = xs
            h, nc = mamba_block_apply(cfg, bp, h, cache=ck)
            return h, nc

        x, ncache = jax.lax.scan(
            body, x,
            (params["blocks"], {k: cache[k] for k in _SSM_CACHE_KEYS}),
        )
        return x, ncache

    def body(h, bp):
        h, nc = mamba_block_apply(cfg, bp, h)
        return h, nc

    if cfg.remat:
        body = jax.checkpoint(body)
    x, ncache = jax.lax.scan(body, x, params["blocks"])
    return x, ncache


def _hybrid_split(cfg: ModelConfig):
    k = cfg.hybrid.attn_every
    n_seg = cfg.n_layers // k
    tail = cfg.n_layers - n_seg * k
    return k, n_seg, tail


def _forward_hybrid(cfg, params, x, positions, cache, decode):
    """Mamba backbone; the weight-shared attention block runs after each
    k-layer segment (its KV cache is stacked over segments)."""
    k, n_seg, tail = _hybrid_split(cfg)
    blocks = params["blocks"]
    seg_blocks = jax.tree.map(
        lambda a: a[: n_seg * k].reshape((n_seg, k) + a.shape[1:]), blocks
    )
    tail_blocks = jax.tree.map(lambda a: a[n_seg * k :], blocks)
    shared = params["shared"]
    aux0 = jnp.zeros((), jnp.float32)
    cache_len = cache["len"] if decode else None

    def mamba_scan(h, bs, caches):
        if decode:
            def inner(hh, xs):
                bp, ck = xs
                hh, nc = mamba_block_apply(cfg, bp, hh, cache=ck)
                return hh, nc

            return jax.lax.scan(inner, h, (bs, caches))

        def inner(hh, bp):
            hh, nc = mamba_block_apply(cfg, bp, hh)
            return hh, nc

        if cfg.remat:
            inner = jax.checkpoint(inner)
        return jax.lax.scan(inner, h, bs)

    def _seg_cache(full):
        return jax.tree.map(
            lambda a: a[: n_seg * k].reshape((n_seg, k) + a.shape[1:]),
            full,
        )

    def seg_body(carry, xs):
        h, aux = carry
        if decode:
            bs, mck, ck, cv = xs
            h, nmc = mamba_scan(h, bs, mck)
            h, (nk, nv), a = attn_block_apply(
                cfg, shared, h, None,
                cache={"k": ck, "v": cv}, cache_len=cache_len,
            )
            return (h, aux + a), (nmc, nk, nv)
        bs = xs
        h, nmc = mamba_scan(h, bs, None)
        h, (kk, vv), a = attn_block_apply(cfg, shared, h, positions)
        return (h, aux + a), (nmc, kk, vv)

    mck_full = (
        {kk: cache[kk] for kk in _SSM_CACHE_KEYS} if decode else None
    )
    if decode:
        (x, aux), (nmc, nk, nv) = jax.lax.scan(
            seg_body, (x, aux0),
            (seg_blocks, _seg_cache(mck_full), cache["k"], cache["v"]),
        )
    else:
        (x, aux), (nmc, nk, nv) = jax.lax.scan(
            seg_body, (x, aux0), seg_blocks
        )
    nmc = jax.tree.map(
        lambda a: a.reshape((n_seg * k,) + a.shape[2:]), nmc
    )

    # tail mamba layers (no shared block after)
    if tail:
        tcache = (
            jax.tree.map(lambda a: a[n_seg * k :], mck_full)
            if decode else None
        )
        x, tmc = mamba_scan(x, tail_blocks, tcache)
        nmc = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), nmc, tmc
        )

    new_cache = dict(nmc)
    new_cache["k"], new_cache["v"] = nk, nv
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


def _cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    out: dict = {}
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        out["conv_x"] = (
            (cfg.n_layers, batch, s.conv_kernel, cfg.d_inner), dt
        )
        out["conv_bc"] = (
            (cfg.n_layers, batch, s.conv_kernel,
             2 * s.n_groups * s.d_state), dt
        )
        out["ssd"] = (
            (cfg.n_layers, batch, cfg.ssm_heads, s.head_dim, s.d_state),
            jnp.float32,
        )
    if cfg.family == "hybrid":
        _, n_seg, _ = _hybrid_split(cfg)
        out["k"] = (
            (n_seg, batch, max_len, cfg.n_kv_heads, cfg.hd), dt
        )
        out["v"] = out["k"]
    elif cfg.family != "ssm":
        out["k"] = (
            (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd), dt
        )
        out["v"] = out["k"]
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    sh = _cache_shapes(cfg, batch, max_len)
    c = {k: jnp.zeros(s, d) for k, (s, d) in sh.items()}
    c["len"] = jnp.zeros((), jnp.int32)
    return c


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    sh = _cache_shapes(cfg, batch, max_len)
    c = {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in sh.items()}
    c["len"] = jax.ShapeDtypeStruct((), jnp.int32)
    return c
