"""Mamba2 SSD (state-space duality) block — chunked parallel form for
train/prefill, O(1) recurrent form for decode.

Chunked SSD (Dao & Gu 2024, §6): split the sequence into chunks of Q
tokens; within a chunk the output is an attention-like quadratic term
(intra), across chunks a (P,N)-state recurrence (inter) propagated with
a lax.scan — sub-quadratic in S and the reason ssm/hybrid archs run the
long_500k shape.

Shapes: x (B,S,H,P) head inputs, dt (B,S,H) softplus'd step sizes,
A (H,) negative decay rates, Bm/Cm (B,S,G,N) input/output projections
(G groups broadcast over H heads), state (B,H,P,N).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.modules import rms_norm


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    *,
    chunk: int,
    h0: jax.Array | None = None,
):
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    rep = H // G
    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(f32)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, Q, G, N), rep, axis=3).astype(f32)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, Q, G, N), rep, axis=3).astype(f32)

    dA = dtc * A.astype(f32)                     # (B,nc,Q,H), negative
    cum = jnp.cumsum(dA, axis=2)                 # inclusive cumsum

    # --- intra-chunk (quadratic within Q) ---
    CB = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,K,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(
        mask[None, None, :, :, None], jnp.exp(diff), 0.0
    ).transpose(0, 1, 4, 2, 3)                   # (B,nc,H,Q,K)
    scores = CB * M * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc)

    # --- chunk-end states ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,nc,Q,H)
    S_chunk = jnp.einsum(
        "bcqhn,bcqh,bcqhp->bchpn", Bc, decay_to_end * dtc, xc
    )                                            # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])      # (B,nc,H)

    # --- inter-chunk recurrence over nc ---
    h_init = (
        jnp.zeros((Bsz, H, P, N), f32) if h0 is None else h0.astype(f32)
    )

    def body(h, inputs):
        s_c, dec = inputs                        # (B,H,P,N), (B,H)
        h_new = dec[..., None, None] * h + s_c
        return h_new, h                          # emit state BEFORE chunk

    (h_final, states_before) = jax.lax.scan(
        body,
        h_init,
        (
            S_chunk.transpose(1, 0, 2, 3, 4),    # (nc,B,H,P,N)
            chunk_decay.transpose(1, 0, 2),      # (nc,B,H)
        ),
    )
    states_before = states_before.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    y_inter = jnp.einsum(
        "bcqhn,bchpn->bcqhp", Cc * jnp.exp(cum)[..., None], states_before
    )

    y = (y_intra + y_inter).reshape(Bsz, nc * Q, H, P)[:, :S]
    return y.astype(x.dtype), h_final


def ssd_decode_step(
    x: jax.Array,        # (B,H,P) single token
    dt: jax.Array,       # (B,H)
    A: jax.Array,        # (H,)
    Bm: jax.Array,       # (B,G,N)
    Cm: jax.Array,       # (B,G,N)
    h: jax.Array,        # (B,H,P,N)
):
    """O(1) recurrent update. Returns (y (B,H,P), new_h)."""
    G = Bm.shape[1]
    H = x.shape[1]
    rep = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(Bm, rep, axis=1).astype(f32)   # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(f32)
    dA = jnp.exp(dt.astype(f32) * A.astype(f32))   # (B,H)
    upd = jnp.einsum(
        "bh,bhp,bhn->bhpn", dt.astype(f32), x.astype(f32), Bh
    )
    h_new = dA[..., None, None] * h.astype(f32) + upd
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch)
    return y.astype(x.dtype), h_new


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array):
    """Depthwise causal conv via shift-add (kernel K small).
    x (B,S,C); w (K,C); b (C,)."""
    K = w.shape[0]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        shift = K - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        y = y + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def conv_decode_step(x: jax.Array, conv_buf: jax.Array, w, b):
    """x (B,C) new input; conv_buf (B,K,C) ring of the last K inputs
    (oldest first). Returns (y (B,C), new_buf)."""
    new_buf = jnp.concatenate([conv_buf[:, 1:], x[:, None, :]], axis=1)
    y = jnp.einsum("bkc,kc->bc", new_buf.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x.dtype), new_buf


def mamba_block(
    cfg: ModelConfig,
    x: jax.Array,          # (B,S,d)
    p: dict,
    *,
    cache: dict | None = None,
):
    """Full Mamba2 block. With cache (decode): S must be 1; returns
    (out, new_cache). Without: returns (out, final_cache_state) where
    final state seeds a decode cache (prefill handoff).

    Projections are separate tensors (z / x / BC / dt) so TP sharding
    of d_inner never crosses a fused split point (see sharding.py)."""
    s = cfg.ssm
    B, S, d = x.shape
    din = cfg.d_inner
    H = cfg.ssm_heads
    P = s.head_dim
    G, N = s.n_groups, s.d_state
    gn = G * N

    z = x @ p["in_z"]                  # (B,S,din)
    xi_raw = x @ p["in_x"]             # (B,S,din)
    bc_raw = x @ p["in_bc"]            # (B,S,2gn)
    dt = x @ p["in_dt"]                # (B,S,H)

    if cache is None:
        xi = jax.nn.silu(causal_conv1d(xi_raw, p["conv_x_w"], p["conv_x_b"]))
        bc = jax.nn.silu(causal_conv1d(bc_raw, p["conv_bc_w"], p["conv_bc_b"]))
        Bm, Cm = jnp.split(bc, [gn], axis=-1)
        dt_sp = jax.nn.softplus(
            dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
        )
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        y, h_final = ssd_chunked(
            xi.reshape(B, S, H, P),
            dt_sp,
            A,
            Bm.reshape(B, S, G, N),
            Cm.reshape(B, S, G, N),
            chunk=s.chunk,
        )
        y = y + p["D"].astype(y.dtype)[None, None, :, None] * xi.reshape(
            B, S, H, P
        )
        y = y.reshape(B, S, din)
        y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                     p["gnorm"])
        out = y @ p["out_proj"]
        # conv tails for decode handoff: the K most recent raw inputs
        # (cache copies constrained like the decode-cache layout)
        from repro.parallel.constrain import constrain, constrain_ssd

        K = s.conv_kernel

        def tail(r):
            t = r[:, -K:, :] if S >= K else jnp.pad(
                r, ((0, 0), (K - S, 0), (0, 0))
            )
            return constrain(t, ("pod", "data"), None, "model")

        return out, {
            "conv_x": tail(xi_raw), "conv_bc": tail(bc_raw),
            "ssd": constrain_ssd(h_final),
        }

    # ---- decode: S == 1 ----
    xi_t, new_conv_x = conv_decode_step(
        xi_raw[:, 0], cache["conv_x"], p["conv_x_w"], p["conv_x_b"]
    )
    bc_t, new_conv_bc = conv_decode_step(
        bc_raw[:, 0], cache["conv_bc"], p["conv_bc_w"], p["conv_bc_b"]
    )
    xi_t = jax.nn.silu(xi_t)
    bc_t = jax.nn.silu(bc_t)
    Bm, Cm = jnp.split(bc_t, [gn], axis=-1)
    dt_t = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_new = ssd_decode_step(
        xi_t.reshape(B, H, P), dt_t, A,
        Bm.reshape(B, G, N), Cm.reshape(B, G, N),
        cache["ssd"],
    )
    y = y + p["D"].astype(y.dtype)[None, :, None] * xi_t.reshape(B, H, P)
    y = y.reshape(B, 1, din)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gnorm"])
    out = y @ p["out_proj"]
    return out, {"conv_x": new_conv_x, "conv_bc": new_conv_bc, "ssd": h_new}
