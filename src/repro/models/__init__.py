"""LM substrate for the assigned architectures.

One flexible decoder covers the five families:
  dense / vlm / audio — GQA attention + (gated) MLP blocks
  moe                 — shared + routed experts (top-k, capacity-based)
  ssm                 — Mamba2 SSD blocks (attention-free)
  hybrid              — Mamba2 backbone + shared attention block (zamba2)

All stacked layers run under ``jax.lax.scan`` (small HLO, fast 512-dev
compiles); attention uses pure-XLA chunked blockwise softmax for long
contexts (the Pallas flash kernel is the TPU drop-in, validated in
tests).
"""

from repro.models.config import ModelConfig, MoEConfig, SSMConfig, HybridConfig
from repro.models.transformer import (
    init_params,
    param_specs,
    forward,
    Cache,
    init_cache,
    cache_specs,
)
from repro.models.steps import (
    make_train_step,
    make_prefill_step,
    make_serve_step,
    loss_fn,
)
