"""Step functions lowered by the launcher / dry-run: train_step,
prefill_step, serve_step.

Distribution notes: these are pure pjit-style functions — all
parallelism comes from in/out shardings (repro.parallel.sharding) and
GSPMD propagation. Gradient cross-replica reduction is implicit in the
sharded-parameter/replicated-parameter contract; the optional
``grad_compression='bf16'`` casts gradients before the (implicit)
all-reduce — halving inter-pod ICI bytes — and back after.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward, init_cache
from repro.optim import clip_by_global_norm
from repro.optim.optimizers import Optimizer

MOE_AUX_WEIGHT = 0.01


def loss_fn(
    cfg: ModelConfig,
    params: Any,
    tokens: jax.Array,
    labels: jax.Array,
    frontend_embeds: Optional[jax.Array] = None,
):
    """Mean next-token cross-entropy (+ MoE aux). When frontend embeds
    are prepended, loss covers only the token region."""
    logits, _, aux = forward(
        cfg, params, tokens, frontend_embeds=frontend_embeds
    )
    if frontend_embeds is not None:
        logits = logits[:, frontend_embeds.shape[1]:, :]
    # shift: predict token t+1 from position t
    lg = logits[:, :-1, :]
    lb = labels[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)
    # masked-sum instead of take_along_axis: elementwise over a
    # vocab-sharded logits dim + small psum, vs. a cross-shard gather
    # that makes GSPMD all-gather the full (B,S,V) logits
    vocab_iota = jnp.arange(lg.shape[-1])[None, None, :]
    picked = jnp.sum(
        jnp.where(vocab_iota == lb[..., None], lg, 0.0), axis=-1
    )
    ce = jnp.mean(lse - picked)
    return ce + MOE_AUX_WEIGHT * aux, (ce, aux)


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    *,
    accum_steps: int = 1,
    grad_compression: str = "none",   # none | bf16
    clip_norm: float = 1.0,
) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state,
    metrics). batch = {'tokens', 'labels'[, 'frontend_embeds']}."""

    def grads_of(params, tokens, labels, fe):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, labels, fe), has_aux=True
        )(params)
        return loss, ce, aux, grads

    def step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        fe = batch.get("frontend_embeds")

        if accum_steps > 1:
            B = tokens.shape[0]
            mb = B // accum_steps

            def body(acc, i):
                def sl(a):
                    return jax.lax.dynamic_slice_in_dim(
                        a, i * mb, mb, axis=0
                    )
                loss, ce, aux, g = grads_of(
                    params, sl(tokens), sl(labels),
                    None if fe is None else sl(fe),
                )
                acc_g, acc_l = acc
                return (
                    jax.tree.map(jnp.add, acc_g, g),
                    acc_l + jnp.stack([loss, ce, aux]),
                ), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(3)), jnp.arange(accum_steps)
            )
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss, ce, aux = lsum / accum_steps
        else:
            loss, ce, aux, grads = grads_of(params, tokens, labels, fe)

        if grad_compression == "bf16":
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads
            )
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {
            "loss": loss, "ce": ce, "moe_aux": aux, "grad_norm": gnorm,
        }

    return step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """fn(params, tokens[, frontend_embeds]) -> (last_logits, cache)."""

    def prefill(params, tokens, frontend_embeds=None):
        logits, cache, _ = forward(
            cfg, params, tokens,
            frontend_embeds=frontend_embeds, return_cache=True,
            last_only=True,
        )
        return logits[:, -1, :], cache

    return prefill


def make_serve_step(cfg: ModelConfig) -> Callable:
    """fn(params, cache, token (B,1)) -> (logits (B,V), new_cache).
    One new token against a pre-filled KV/SSM cache."""

    def serve(params, cache, token):
        logits, new_cache, _ = forward(cfg, params, token, cache=cache)
        return logits[:, -1, :], new_cache

    return serve


def greedy_decode(
    cfg: ModelConfig, params, prompt: jax.Array, n_steps: int,
    max_len: int,
):
    """Reference autoregressive loop (examples/serving tests)."""
    prefill = make_prefill_step(cfg)
    serve = make_serve_step(cfg)
    B, S = prompt.shape
    logits, cache = prefill(params, prompt)
    # move prefill kv into a max_len cache
    full = init_cache(cfg, B, max_len)
    for k in ("k", "v"):
        if k in full:
            full[k] = jax.lax.dynamic_update_slice(
                full[k], cache[k].astype(full[k].dtype), (0, 0, 0, 0, 0)
            )
    for k in ("conv_x", "conv_bc", "ssd"):
        if k in full:
            full[k] = cache[k].astype(full[k].dtype)
    full["len"] = jnp.asarray(S, jnp.int32)

    toks = [jnp.argmax(logits, -1)[:, None]]
    cache = full
    for _ in range(n_steps - 1):
        logits, cache = serve(params, cache, toks[-1])
        toks.append(jnp.argmax(logits, -1)[:, None])
    return jnp.concatenate(toks, axis=1)
