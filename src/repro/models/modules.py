"""Shared neural modules: norms, RoPE, chunked attention, MLPs.

Numerics policy: activations in cfg.dtype (bf16), norms and softmax in
f32, residual stream in bf16.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

_NEG = -1e30


def rms_norm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    return y.astype(x.dtype)


def nonparam_layernorm(x: jax.Array, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no scale, no bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(kind: str, x: jax.Array, scale: jax.Array | None):
    if kind == "rms":
        return rms_norm(x, scale)
    if kind == "nonparam":
        return nonparam_layernorm(x)
    raise ValueError(kind)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, D); positions (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (...,S,half)
    cos = jnp.cos(ang)[..., None, :]                        # (...,S,1,half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
    xr2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_chunk: int,
    kv_chunk: int,
    kv_offset: int = 0,
    remat_chunks: bool = True,
) -> jax.Array:
    """Memory-efficient blockwise-softmax attention in pure XLA (the
    flash pattern; the Pallas kernel in repro.kernels is the TPU
    drop-in with identical semantics, cross-checked in tests).

    q (B,Sq,H,D); k,v (B,Sk,Hkv,D). Causal uses suffix alignment:
    query i attends to keys j <= i + kv_offset (kv_offset = Sk - Sq for
    aligned prefill). Returns (B,Sq,H,D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    group = H // Hkv
    scale = D ** -0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    # pad to multiples
    if nq * q_chunk != Sq:
        q = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)))
    if nk * kv_chunk != Sk:
        pad = nk * kv_chunk - Sk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qc = q.reshape(B, nq, q_chunk, H, D)
    kc = k.reshape(B, nk, kv_chunk, Hkv, D)
    vc = v.reshape(B, nk, kv_chunk, Hkv, D)

    def q_body(_, iq):
        qi = qc[:, iq]  # (B, qc, H, D)

        def kv_body(carry, ik):
            m, l, acc = carry
            ki = kc[:, ik]  # (B, kc, Hkv, D)
            vi = vc[:, ik]
            kg = jnp.repeat(ki, group, axis=2)
            vg = jnp.repeat(vi, group, axis=2)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk",
                qi.astype(jnp.float32),
                kg.astype(jnp.float32),
            ) * scale
            qpos = iq * q_chunk + jnp.arange(q_chunk)
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            valid = (kpos < Sk)[None, None, None, :]
            if causal:
                valid = valid & (
                    kpos[None, None, None, :]
                    <= qpos[None, None, :, None] + kv_offset
                )
            s = jnp.where(valid, s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            pv = jnp.einsum(
                "bhqk,bkhd->bqhd", p, vg.astype(jnp.float32)
            ).transpose(0, 2, 1, 3)          # (B,H,qc,D)
            acc_new = acc * alpha + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk, 1), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), jnp.arange(nk)
        )
        out = (acc / l).transpose(0, 2, 1, 3)  # (B, qc, H, D)
        return None, out.astype(q.dtype)

    body = jax.checkpoint(q_body) if remat_chunks else q_body
    _, out = jax.lax.scan(body, None, jnp.arange(nq))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq]


def chunked_attention_kv_parallel(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_chunk: int,
    n_kv_parts: int = 16,
    remat_chunks: bool = True,
) -> jax.Array:
    """Context-parallel attention: the KV sequence is split into
    `n_kv_parts` parts constrained over the 'model' axis; each part
    computes a blockwise-softmax partial (m, l, acc) and the parts are
    combined with a log-sum-exp merge — the cross-part contraction is
    the ONLY collective (an (B,H,qc,hd)-sized all-reduce per q chunk),
    unlike head-sharded attention with indivisible head counts where
    GSPMD partial-sums every score block (qwen2.5: 40H/16 -> 960 GiB/dev
    per step; EXPERIMENTS.md §Perf qwen iteration 5)."""
    from repro.parallel.constrain import constrain

    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    group = H // Hkv
    scale = D ** -0.5
    assert Sk % n_kv_parts == 0
    kp = Sk // n_kv_parts
    q_chunk = min(q_chunk, Sq)
    nq = -(-Sq // q_chunk)
    if nq * q_chunk != Sq:
        q = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)))
    qc = q.reshape(B, nq, q_chunk, H, D)
    kc = k.reshape(B, n_kv_parts, kp, Hkv, D)
    vc = v.reshape(B, n_kv_parts, kp, Hkv, D)
    kc = constrain(kc, ("pod", "data"), "model", None, None, None)
    vc = constrain(vc, ("pod", "data"), "model", None, None, None)
    kg = jnp.repeat(kc, group, axis=3)
    vg = jnp.repeat(vc, group, axis=3)
    kpos = jnp.arange(Sk).reshape(n_kv_parts, kp)

    def q_body(_, iq):
        qi = qc[:, iq].astype(jnp.float32)          # (B,qc,H,D)
        s = jnp.einsum(
            "bqhd,bnkhd->bnhqk", qi, kg.astype(jnp.float32)
        ) * scale                                    # (B,n,H,qc,kp)
        qpos = iq * q_chunk + jnp.arange(q_chunk)
        valid = kpos[None, :, None, None, :] <= (
            qpos[None, None, None, :, None] + (Sk - Sq)
        ) if causal else jnp.ones((), bool)
        s = jnp.where(valid, s, _NEG)
        m_n = jnp.max(s, axis=-1, keepdims=True)     # (B,n,H,qc,1)
        p = jnp.exp(s - m_n)
        l_n = jnp.sum(p, axis=-1, keepdims=True)
        acc_n = jnp.einsum("bnhqk,bnkhd->bnhqd", p, vg.astype(jnp.float32))
        # log-sum-exp combine across the sharded part dim
        m = jnp.max(m_n, axis=1, keepdims=True)      # (B,1,H,qc,1)
        w = jnp.exp(m_n - m)
        lsum = jnp.sum(l_n * w, axis=1)              # (B,H,qc,1)
        acc = jnp.sum(acc_n * w, axis=1)             # (B,H,qc,D)
        out = (acc / lsum).transpose(0, 2, 1, 3)     # (B,qc,H,D)
        return None, out.astype(q.dtype)

    body = jax.checkpoint(q_body) if remat_chunks else q_body
    _, out = jax.lax.scan(body, None, jnp.arange(nq))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq]


def gated_mlp(x: jax.Array, wg, wu, wd) -> jax.Array:
    """SiLU-gated MLP (llama family)."""
    g = jax.nn.silu(x @ wg)
    return ((g * (x @ wu)) @ wd).astype(x.dtype)


def gelu_mlp(x: jax.Array, wu, wd) -> jax.Array:
    return (jax.nn.gelu(x @ wu) @ wd).astype(x.dtype)


def relu2_mlp(x: jax.Array, wu, wd) -> jax.Array:
    """Squared-ReLU MLP (nemotron/minitron family)."""
    h = jax.nn.relu(x @ wu)
    return ((h * h) @ wd).astype(x.dtype)
