"""Calibrated interference law, fitted from metered co-run slowdowns.

PR 5 priced cross-tenant contention with a single *assumed* linear
law ``1 + gamma * share`` and an uncalibrated ``gamma``.  This module
closes that gap from data the fleet already collects: the
:class:`~repro.fleet.ledger.DeviceTimeLedger` meters every tenant's
per-step host/device occupancy, so each closed step yields an
observed **inflation** (measured occupancy over the solo expectation)
at a known **co-runner share** — exactly the (x, y) pairs the law
maps.

:func:`fit_gamma` recovers the linear coefficient by least squares
through the origin (the law is pinned at ``inflation(0) == 1``);
:meth:`InterferenceFit.fit` optionally refines it into a
piecewise-affine law: observations are bucketed by share, bucket
means are made monotone by pool-adjacent-violators isotonic
regression, and the resulting knots interpolate between ``(0, 1)``
and the largest observed share (linear ``gamma`` extrapolation
beyond).

**Fitted-law contract** (what every consumer may assume, and the
property tests pin): for any observation set, the returned
:class:`FittedInterference` satisfies

* ``inflation(0.0) == 1.0`` — no co-runners, no slowdown;
* ``inflation(s) >= 1.0`` for all ``s >= 0`` — co-runners never
  speed you up;
* ``inflation`` is monotone non-decreasing in the share — the
  property ``map_fleet``'s never-worse-than-all-GPU descent relies
  on.

The fitted law threads through
:func:`repro.core.cost_model.contention_inflation` (``law=`` param),
:func:`repro.fleet.scheduler.map_fleet` and ``TenantPlan``, replacing
the fixed gamma wherever a law is supplied.
"""

from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class InterferenceObservation:
    """One (co-runner share, measured inflation) sample."""

    share: float          # co-runners' summed occupancy share
    inflation: float      # measured_s / solo_expected_s
    placement: str = ""   # "host"/"device" (attribution only)
    tenant: str = ""


def fit_gamma(observations) -> float:
    """Least-squares linear coefficient through the pinned origin
    ``inflation(0) == 1``: ``gamma = sum(s*(f-1)) / sum(s^2)``,
    clamped non-negative (the law's domain)."""
    num = den = 0.0
    for o in observations:
        s = max(0.0, float(o.share))
        num += s * (float(o.inflation) - 1.0)
        den += s * s
    if den <= 0.0:
        return 0.0
    return max(0.0, num / den)


def _isotonic(ys, ws) -> list:
    """Weighted pool-adjacent-violators: the monotone non-decreasing
    sequence closest (weighted L2) to `ys`."""
    blocks: list = []   # [mean, weight, count]
    for y, w in zip(ys, ws):
        blocks.append([float(y), float(w), 1])
        while len(blocks) > 1 and blocks[-2][0] > blocks[-1][0]:
            m2, w2, c2 = blocks.pop()
            m1, w1, c1 = blocks.pop()
            wt = w1 + w2
            blocks.append([(m1 * w1 + m2 * w2) / wt, wt, c1 + c2])
    out: list = []
    for m, _, c in blocks:
        out.extend([m] * c)
    return out


@dataclasses.dataclass(frozen=True)
class FittedInterference:
    """A calibrated inflation law: linear ``1 + gamma*s`` when
    ``knots`` is empty, else piecewise-affine through ``(0, 1)`` and
    the (share, inflation) knots, extrapolating past the last knot at
    slope ``gamma``.  Knots are strictly increasing in share and
    non-decreasing >= 1 in inflation by construction (PAV + clamps in
    :meth:`InterferenceFit.fit`), so the law honors the module's
    fitted-law contract."""

    gamma: float
    knots: tuple = ()
    n_obs: int = 0
    residual: float = 0.0   # RMS of (observed - linear fit)

    def __post_init__(self):
        if self.gamma < 0.0:
            raise ValueError("gamma must be non-negative")

    def inflation(self, share: float) -> float:
        s = max(0.0, float(share))
        if not self.knots:
            return 1.0 + self.gamma * s
        pts = ((0.0, 1.0),) + tuple(
            (float(k[0]), float(k[1])) for k in self.knots
        )
        for (s0, f0), (s1, f1) in zip(pts, pts[1:]):
            if s <= s1:
                if s1 <= s0:
                    return max(f0, f1)
                t = (s - s0) / (s1 - s0)
                return f0 + t * (f1 - f0)
        s_last, f_last = pts[-1]
        return f_last + self.gamma * (s - s_last)

    def to_json(self) -> str:
        return json.dumps(
            {
                "schema": 1,
                "kind": "interference_law",
                "gamma": self.gamma,
                "knots": [[s, f] for s, f in self.knots],
                "n_obs": self.n_obs,
                "residual": self.residual,
            },
            indent=2,
        )

    @staticmethod
    def from_json(s: str) -> "FittedInterference":
        d = json.loads(s)
        if d.get("kind", "interference_law") != "interference_law":
            raise ValueError(
                f"expected an interference_law document, got "
                f"{d.get('kind')!r}"
            )
        return FittedInterference(
            gamma=float(d["gamma"]),
            knots=tuple(
                (float(s_), float(f)) for s_, f in d.get("knots", ())
            ),
            n_obs=int(d.get("n_obs", 0)),
            residual=float(d.get("residual", 0.0)),
        )


class InterferenceFit:
    """Accumulates (share, inflation) observations and fits the law."""

    def __init__(self):
        self._obs: list = []

    def __len__(self) -> int:
        return len(self._obs)

    def observations(self) -> tuple:
        return tuple(self._obs)

    def observe(
        self,
        share: float,
        inflation: float,
        *,
        placement: str = "",
        tenant: str = "",
    ) -> None:
        """Record one sample.  Negative shares and non-positive
        inflations are measurement garbage and dropped."""
        if share < 0.0 or inflation <= 0.0:
            return
        self._obs.append(
            InterferenceObservation(
                share=float(share),
                inflation=float(inflation),
                placement=placement,
                tenant=tenant,
            )
        )

    def add(self, obs: InterferenceObservation) -> None:
        self.observe(
            obs.share, obs.inflation,
            placement=obs.placement, tenant=obs.tenant,
        )

    def add_ledger(
        self,
        ledger,
        expected_step_s: dict,
        *,
        min_expected_s: float = 1e-9,
    ) -> int:
        """Harvest observations from a ``DeviceTimeLedger``.

        ``expected_step_s`` maps tenant name to its **solo** expected
        (host_s, device_s) per engine step — the uninflated
        ``stage_times`` of the served configuration at its batch.
        Each closed step's measured occupancy over that expectation
        is one inflation sample at the tenant's current co-runner
        share on that processor.  Returns the number of observations
        added.  Stages expected to take under `min_expected_s` are
        skipped (a zero-work stage's ratio is noise, not signal).
        """
        from repro.core.mapper import DEVICE, HOST

        added = 0
        for tenant in ledger.tenants():
            expected = expected_step_s.get(tenant)
            if expected is None:
                continue
            exp_host, exp_dev = float(expected[0]), float(expected[1])
            co = {
                HOST: ledger.co_runner_share(tenant, HOST),
                DEVICE: ledger.co_runner_share(tenant, DEVICE),
            }
            for host_s, dev_s in ledger.step_rows(tenant):
                for placement, measured, solo in (
                    (HOST, host_s, exp_host),
                    (DEVICE, dev_s, exp_dev),
                ):
                    if solo < min_expected_s or measured <= 0.0:
                        continue
                    self.observe(
                        co[placement],
                        measured / solo,
                        placement=placement,
                        tenant=tenant,
                    )
                    added += 1
        return added

    @classmethod
    def from_ledger(
        cls, ledger, expected_step_s: dict, **kwargs
    ) -> "InterferenceFit":
        fit = cls()
        fit.add_ledger(ledger, expected_step_s, **kwargs)
        return fit

    def fit(
        self,
        *,
        refine: bool = True,
        max_knots: int = 6,
        min_per_knot: int = 4,
    ) -> FittedInterference:
        """Fit the law from the accumulated observations.

        Always fits the linear ``gamma``; with ``refine``, enough
        positive-share observations also produce isotonic
        piecewise-affine knots (equal-count share buckets, bucket
        means, PAV for monotonicity, clamped >= 1).  With no
        observations the identity law (``gamma=0``) is returned —
        callers keep their fixed-gamma fallback for the cold case.
        """
        gamma = fit_gamma(self._obs)
        n = len(self._obs)
        if n:
            sq = sum(
                (o.inflation - (1.0 + gamma * max(0.0, o.share))) ** 2
                for o in self._obs
            )
            residual = (sq / n) ** 0.5
        else:
            residual = 0.0

        knots: tuple = ()
        if refine:
            pos = sorted(
                (o for o in self._obs if o.share > 1e-9),
                key=lambda o: o.share,
            )
            k = min(int(max_knots), len(pos) // max(1, int(min_per_knot)))
            if k >= 2:
                buckets = [
                    pos[(j * len(pos)) // k: ((j + 1) * len(pos)) // k]
                    for j in range(k)
                ]
                buckets = [b for b in buckets if b]
                shares = [
                    sum(o.share for o in b) / len(b) for b in buckets
                ]
                means = [
                    sum(o.inflation for o in b) / len(b) for b in buckets
                ]
                weights = [float(len(b)) for b in buckets]
                iso = _isotonic(means, weights)
                out: list = []
                for s, f in zip(shares, iso):
                    f = max(1.0, f)
                    if s <= 1e-9 or (out and s <= out[-1][0]):
                        continue
                    out.append((s, f))
                if len(out) >= 2:
                    knots = tuple(out)

        return FittedInterference(
            gamma=gamma, knots=knots, n_obs=n, residual=residual
        )
