"""Learned per-layer latency prediction (nnabla-nas-style estimator).

:class:`LatencyPredictor` fits one log-space linear regression per
:func:`~repro.estimator.features.group_key` — (geometry class,
placement, analytic kind) — over training rows accumulated across
``ProfileStore`` entries, plus per-direction boundary-cost fits and a
coarse fallback chain, and can then synthesize a complete
:class:`~repro.core.profiler.ProfileTable` for a model it has never
seen (:meth:`predict_table`).

The prediction contract is deliberately weaker than profiling — and
that is the point:

* every predicted time is finite and positive (clamped to
  ``[1e-12, 1e6]`` seconds), so a predicted table can **never** crash
  the DP mapper: it always yields a valid mapping, just a possibly
  suboptimal one;
* an unmatched row degrades through the fallback chain (exact group →
  per-class pool → global median) instead of failing — a predictor
  trained on GEMM rows still prices an elementwise layer, badly but
  usably;
* prediction seeds the DP for zero-profiling cold starts, PR-4
  telemetry corrects it online, and every real profile run feeds rows
  back into the store (``ProfileStore.get_or_profile``) so the next
  cold start predicts better.

Predicted tables are marked ``provenance="predicted"`` so consumers
(warm-start logging, bench derived columns) can tell them from
measured/analytic ones.

Fitting is ridge-regularized least squares in log space: the fixed-8
rows make several features collinear (all aspect configs share one
tile size), and the ridge term keeps the minimum-norm solution stable
instead of exploding a coefficient pair the data cannot separate.
"""

from __future__ import annotations

import json
import math

import numpy as np

from repro.core.parallel_config import CONFIGS
from repro.estimator.features import (
    boundary_features,
    feature_vector,
    group_key,
    layer_geometry,
    variant_meta,
)

_MIN_S = 1e-12
_MAX_S = 1e6


def _fit_loglinear(X, y, ridge: float):
    """Ridge-augmented least squares: minimizes ``|Xw - y|^2 +
    ridge * |w|^2`` via lstsq on the stacked system — stable under the
    collinear columns fixed-8 training data produces."""
    X = np.asarray(X, dtype=float)
    y = np.asarray(y, dtype=float)
    d = X.shape[1]
    Xa = np.vstack([X, math.sqrt(ridge) * np.eye(d)])
    ya = np.concatenate([y, np.zeros(d)])
    w, *_ = np.linalg.lstsq(Xa, ya, rcond=None)
    return w


class LatencyPredictor:
    """Per-group log-linear latency regression over training rows."""

    SCHEMA_VERSION = 1

    def __init__(self, *, ridge: float = 1e-6, min_rows: int = 3):
        if ridge <= 0.0:
            raise ValueError("ridge must be positive")
        if min_rows < 1:
            raise ValueError("min_rows must be >= 1")
        self.ridge = ridge
        self.min_rows = min_rows
        self._groups: dict = {}       # group_key -> weight vector
        self._pools: dict = {}        # geometry cls -> weight vector
        self._boundary: dict = {}     # "h2d"/"d2h" -> weight vector
        self._counts: dict = {}       # group_key -> training rows used
        self._default_log_s = math.log(1e-4)
        self.n_rows = 0

    # -- training ----------------------------------------------------
    def fit(self, rows) -> "LatencyPredictor":
        """Fit from training-row dicts (``features.training_rows_*``).
        Returns ``self``.  Rows with non-positive kernel times are
        dropped; boundary fits dedupe the per-layer h2d/d2h values
        (stored once per layer, repeated across that layer's
        configs)."""
        by_group: dict = {}
        by_cls: dict = {}
        boundary: dict = {"h2d": {}, "d2h": {}}
        all_logs: list = []
        n = 0
        for r in rows:
            geom, meta = r["geometry"], r["meta"]
            t = float(r.get("kernel_s", 0.0))
            if not (t > 0.0) or not math.isfinite(t):
                continue
            n += 1
            x = feature_vector(geom, meta)
            logt = math.log(max(t, _MIN_S))
            key = group_key(geom, meta)
            by_group.setdefault(key, ([], []))
            by_group[key][0].append(x)
            by_group[key][1].append(logt)
            by_cls.setdefault(geom["cls"], ([], []))
            by_cls[geom["cls"]][0].append(x)
            by_cls[geom["cls"]][1].append(logt)
            all_logs.append(logt)
            # one boundary sample per (model, layer, batch, direction)
            bkey = (r.get("model", ""), r.get("layer", -1), geom["b"])
            for direction in ("h2d", "d2h"):
                v = float(r.get(f"{direction}_s", 0.0))
                if v > 0.0 and math.isfinite(v):
                    boundary[direction].setdefault(
                        bkey, (boundary_features(geom, direction),
                               math.log(max(v, _MIN_S)))
                    )
        self._groups.clear()
        self._pools.clear()
        self._boundary.clear()
        self._counts.clear()
        for key, (X, y) in by_group.items():
            self._counts[key] = len(y)
            if len(y) >= self.min_rows:
                self._groups[key] = _fit_loglinear(X, y, self.ridge)
        for cls, (X, y) in by_cls.items():
            if len(y) >= self.min_rows:
                self._pools[cls] = _fit_loglinear(X, y, self.ridge)
        for direction, samples in boundary.items():
            if len(samples) >= self.min_rows:
                X = [x for x, _ in samples.values()]
                y = [v for _, v in samples.values()]
                self._boundary[direction] = _fit_loglinear(
                    X, y, self.ridge
                )
        if all_logs:
            self._default_log_s = float(np.median(all_logs))
        self.n_rows = n
        return self

    # -- prediction --------------------------------------------------
    @staticmethod
    def _clamp(log_s: float) -> float:
        if not math.isfinite(log_s):
            return 1e-4
        return min(max(math.exp(log_s), _MIN_S), _MAX_S)

    def predict_kernel_s(self, geometry: dict, meta: dict) -> float:
        """Kernel-only seconds per example for one (layer geometry,
        variant meta) pair — exact group fit, else the geometry
        class's pooled fit, else the global median.  Always finite
        and positive."""
        x = np.asarray(feature_vector(geometry, meta), dtype=float)
        for w in (
            self._groups.get(group_key(geometry, meta)),
            self._pools.get(geometry["cls"]),
        ):
            if w is not None and len(w) == len(x):
                return self._clamp(float(x @ w))
        return self._clamp(self._default_log_s)

    def predict_boundary_s(self, geometry: dict, direction: str) -> float:
        """Per-example seconds for the layer's ``"h2d"``/``"d2h"``
        transfer (0.0 when that direction was never trained)."""
        w = self._boundary.get(direction)
        if w is None:
            return 0.0
        x = np.asarray(boundary_features(geometry, direction), dtype=float)
        return self._clamp(float(x @ w))

    def predict_table(
        self,
        model,
        batch_sizes,
        *,
        registry=None,
        configs=None,
        platform=None,
    ):
        """Synthesize a full ``ProfileTable`` for `model` with zero
        profiling passes.

        Candidates per layer are `configs` (default: the fixed-8
        space) plus, when a `registry` is given, every layer-scope
        variant whose applicability predicate accepts the layer's
        GEMM shape on `platform` — the same space
        ``autotune_bnn_model`` would sweep.  Rows follow profiler
        semantics exactly (per-example seconds; device totals carry
        the full h2d+d2h roundtrip), so the table drops into the DP
        mapper, the store and the serving stack unchanged.
        """
        from repro.core.profiler import ProfileTable

        base = tuple(configs) if configs is not None else CONFIGS
        batch_sizes = tuple(int(b) for b in batch_sizes)
        labels = tuple(f"L{s.idx}:{s.notation}" for s in model.specs)
        times: dict = {}
        kernels: dict = {}
        h2d: dict = {}
        d2h: dict = {}
        for b in batch_sizes:
            per, perk, ph, pd = [], [], [], []
            for spec in model.specs:
                geom = layer_geometry(spec, b)
                cand = list(base)
                if registry is not None and geom["cls"] == "gemm":
                    from repro.kernels.registry import GemmShape

                    shape = GemmShape(
                        b=b, p=geom["p"], n=geom["n"], kw=geom["kw"]
                    )
                    cand += [
                        v.name
                        for v in registry.applicable(shape, platform)
                        if v.name not in cand
                    ]
                lh2d = self.predict_boundary_s(geom, "h2d")
                ld2h = self.predict_boundary_s(geom, "d2h")
                row, krow = {}, {}
                for cfg in cand:
                    meta = variant_meta(cfg, registry)
                    k = self.predict_kernel_s(geom, meta)
                    krow[cfg] = k
                    row[cfg] = (
                        k if meta["placement"] == "host"
                        else k + lh2d + ld2h
                    )
                per.append(row)
                perk.append(krow)
                ph.append(lh2d)
                pd.append(ld2h)
            times[b] = per
            kernels[b] = perk
            h2d[b] = ph
            d2h[b] = pd
        return ProfileTable(
            model_name=model.name,
            batch_sizes=batch_sizes,
            layer_labels=labels,
            times=times,
            kernel_times=kernels,
            h2d_times=h2d,
            d2h_times=d2h,
            provenance="predicted",
        )

    # -- introspection / persistence --------------------------------
    def coverage(self) -> dict:
        """{group_key: training rows seen} — which regions of the
        config space the predictor has actually learned (groups below
        ``min_rows`` counted but unfitted)."""
        return dict(self._counts)

    def to_json(self) -> str:
        def ser(d):
            return {k: [float(v) for v in w] for k, w in d.items()}

        return json.dumps(
            {
                "schema": self.SCHEMA_VERSION,
                "kind": "latency_predictor",
                "ridge": self.ridge,
                "min_rows": self.min_rows,
                "n_rows": self.n_rows,
                "groups": ser(self._groups),
                "pools": ser(self._pools),
                "boundary": ser(self._boundary),
                "counts": dict(self._counts),
                "default_log_s": self._default_log_s,
            },
            indent=2,
        )

    @staticmethod
    def from_json(s: str) -> "LatencyPredictor":
        d = json.loads(s)
        if d.get("schema", 1) > LatencyPredictor.SCHEMA_VERSION:
            raise ValueError(
                "latency_predictor schema is newer than supported"
            )
        if d.get("kind", "latency_predictor") != "latency_predictor":
            raise ValueError(
                f"expected a latency_predictor document, got "
                f"{d.get('kind')!r}"
            )
        p = LatencyPredictor(
            ridge=d.get("ridge", 1e-6), min_rows=d.get("min_rows", 3)
        )
        for attr, key in (
            ("_groups", "groups"),
            ("_pools", "pools"),
            ("_boundary", "boundary"),
        ):
            getattr(p, attr).update(
                {k: np.asarray(w, dtype=float)
                 for k, w in d.get(key, {}).items()}
            )
        p._counts.update(d.get("counts", {}))
        p._default_log_s = float(d.get("default_log_s", math.log(1e-4)))
        p.n_rows = int(d.get("n_rows", 0))
        return p
