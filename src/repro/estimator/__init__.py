"""Learned latency estimation and calibrated interference modeling.

Two fitted models replacing trust-the-profile with fit-from-data
(docs/ARCHITECTURE.md §12):

* :class:`LatencyPredictor` — per-variant-kind log-linear regression
  over training rows the :class:`~repro.store.ProfileStore`
  accumulates from every real profile run; ``predict_table`` gives an
  unseen (model, hardware) key a usable ``ProfileTable`` with zero
  profiling passes.
* :class:`InterferenceFit` / :class:`FittedInterference` — the
  contention law ``map_fleet`` prices with, calibrated from the
  cross-tenant slowdowns the ``DeviceTimeLedger`` meters instead of
  an assumed ``gamma``.
"""

from repro.estimator.features import (
    TRAINING_ROW_SCHEMA,
    boundary_features,
    feature_vector,
    group_key,
    layer_geometry,
    training_rows_from_table,
    variant_meta,
)
from repro.estimator.interference import (
    FittedInterference,
    InterferenceFit,
    InterferenceObservation,
    fit_gamma,
)
from repro.estimator.latency import LatencyPredictor

__all__ = [
    "TRAINING_ROW_SCHEMA",
    "boundary_features",
    "feature_vector",
    "group_key",
    "layer_geometry",
    "training_rows_from_table",
    "variant_meta",
    "FittedInterference",
    "InterferenceFit",
    "InterferenceObservation",
    "fit_gamma",
    "LatencyPredictor",
]
