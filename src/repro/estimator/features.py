"""Feature extraction for the learned latency estimator.

A training row pairs one profiled measurement — a (layer, batch,
config) kernel time plus the layer's boundary costs — with the two
dictionaries prediction needs:

* ``geometry`` — the layer's dispatch shape at the profiled batch
  (:func:`layer_geometry`): the GEMM dims for conv/fc layers, an
  element count for the memory-bound elementwise layers.  Everything
  here derives from the :class:`~repro.bnn.layers.LayerSpec` alone,
  so an *unprofiled* model produces the same geometry and a trained
  predictor can price it sight unseen.
* ``meta`` — the config's registry metadata (:func:`variant_meta`):
  placement, analytic kind, tile sizes, aspect flags.  This is what
  lets one regression generalize across variants of the same kind
  instead of memorizing config names.

Rows are plain JSON-able dicts (``schema`` =
:data:`TRAINING_ROW_SCHEMA`) so the :class:`~repro.store.ProfileStore`
can accumulate them across runs, models and fingerprints
(``save_training_rows``); :func:`training_rows_from_table` extracts
them from any profiled :class:`~repro.core.profiler.ProfileTable`
whose model specs are in hand.

Regression targets are fit in log space, so features are logs of the
multiplicative shape terms plus binary aspect indicators —
:func:`feature_vector` for kernel times, :func:`boundary_features`
for the per-direction transfer costs.  :func:`group_key` names the
regression group a row trains: one weight vector per (geometry class,
placement, analytic kind), the granularity at which the cost surface
is close to a power law.
"""

from __future__ import annotations

import math

from repro.bnn.layers import LayerSpec
from repro.core.cost_model import gemm_dims_for, variant_analytics
from repro.core.parallel_config import CONFIGS, aspects_of, is_host_config

TRAINING_ROW_SCHEMA = 1


def layer_geometry(spec: LayerSpec, batch: int) -> dict:
    """The layer's dispatch shape at `batch`, as a JSON-able dict.

    conv/fc layers report their packed xnor-GEMM dims (``cls="gemm"``:
    b, p, n, kw plus operand/result byte counts); mp/step/flat layers
    report their element count (``cls="ew"``).  Byte counts feed the
    boundary-cost features — the same operand/result sizing the
    analytic cost model's transfer terms use.
    """
    dims = gemm_dims_for(spec, batch)
    if dims is not None:
        return {
            "cls": "gemm",
            "b": int(dims.b),
            "p": int(dims.p),
            "n": int(dims.n),
            "kw": int(dims.kw),
            "in_bytes": int(dims.a_bytes),
            "out_bytes": int(dims.o_bytes),
        }
    elems = int(batch)
    for d in spec.in_shape:
        elems *= int(d)
    return {
        "cls": "ew",
        "b": int(batch),
        "elems": elems,
        "in_bytes": elems * 4,
        "out_bytes": elems * 4,
    }


def _aspects(config: str, registry) -> tuple:
    if registry is not None and config not in CONFIGS and config in registry:
        return tuple(registry.get(config).aspects)
    return aspects_of(config)


def variant_meta(config: str, registry=None) -> dict:
    """Registry metadata for `config`, as a JSON-able dict: placement
    ("host"/"device"), analytic kind ("host"/"tiled"/"fused"), tile
    sizes and the aspect letters.  Raises on unknown names, exactly
    like the placement authority — a typo must not train a group."""
    p_blk, n_blk, analytic = variant_analytics(config, registry)
    host = is_host_config(config, registry)
    aspects = _aspects(config, registry)
    return {
        "config": config,
        "placement": "host" if host else "device",
        "analytic": analytic,
        "p_blk": int(p_blk),
        "n_blk": int(n_blk),
        "aspects": "".join(aspects) or "-",
    }


def group_key(geometry: dict, meta: dict) -> str:
    """The regression group a row belongs to — one fitted weight
    vector per (geometry class, placement, analytic kind)."""
    return f"{geometry['cls']}/{meta['placement']}/{meta['analytic']}"


def _log(v) -> float:
    return math.log(max(float(v), 1.0))


def feature_vector(geometry: dict, meta: dict) -> tuple:
    """Log-space features for a kernel-time regression row.  GEMM rows
    carry the shape and tile logs plus per-aspect indicators (what
    separates X from XYZ at identical shape); elementwise rows carry
    batch and element count only."""
    if geometry["cls"] == "gemm":
        a = meta.get("aspects", "-")
        return (
            1.0,
            _log(geometry["b"]),
            _log(geometry["p"]),
            _log(geometry["n"]),
            _log(geometry["kw"]),
            _log(meta.get("p_blk", 128)),
            _log(meta.get("n_blk", 128)),
            1.0 if "X" in a else 0.0,
            1.0 if "Y" in a else 0.0,
            1.0 if "Z" in a else 0.0,
        )
    return (1.0, _log(geometry["b"]), _log(geometry["elems"]))


def boundary_features(geometry: dict, direction: str) -> tuple:
    """Log-space features for an ``"h2d"``/``"d2h"`` boundary-cost
    row: batch and the bytes crossing the link in that direction."""
    bytes_ = (
        geometry["in_bytes"] if direction == "h2d"
        else geometry["out_bytes"]
    )
    return (1.0, _log(geometry["b"]), _log(bytes_))


def training_rows_from_table(model, table, registry=None) -> list:
    """Extract every (layer, batch, config) measurement in `table` as
    a training row.  Needs the model's specs in hand (geometry is not
    recoverable from the stored labels), so extraction happens where
    profiling does — ``ProfileStore.get_or_profile`` records rows for
    each table it profiles.  Config names the current registry cannot
    resolve (legacy tables) are skipped, not guessed at."""
    rows: list = []
    specs = tuple(getattr(model, "specs", ()))
    if len(specs) != len(table.layer_labels):
        return rows
    for b in table.batch_sizes:
        for i, spec in enumerate(specs):
            geometry = layer_geometry(spec, b)
            h2d_s = float(table.h2d(b, i))
            d2h_s = float(table.d2h(b, i))
            for cfg in table.configs_for(b, i):
                try:
                    meta = variant_meta(cfg, registry)
                except (KeyError, ValueError):
                    continue
                rows.append(
                    {
                        "schema": TRAINING_ROW_SCHEMA,
                        "model": table.model_name,
                        "layer": int(i),
                        "batch": int(b),
                        "config": cfg,
                        "geometry": geometry,
                        "meta": meta,
                        "kernel_s": float(table.kernel_time(b, i, cfg)),
                        "h2d_s": h2d_s,
                        "d2h_s": d2h_s,
                    }
                )
    return rows
