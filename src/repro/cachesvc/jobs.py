"""The cache service's background job kinds — each an
idempotent function returning a JSON-able result dict for the
:class:`~repro.cachesvc.workqueue.JobRecord` journal.

``prewarm``
    Profile + map a (model, hardware, registry) key *ahead of demand*
    so the first real request warm-starts: :func:`prewarm_once` runs
    the store's own ``get_or_profile`` / ``load_mapping`` path, so a
    prewarmed key is byte-identical to one a cold serve would have
    written.

``refit``
    Retrain the learned estimators when enough new training rows
    accumulated since the last persisted fit: :func:`refit_once`
    compares the store's row count against the saved predictor's
    ``source_rows`` stamp and re-fits the
    :class:`~repro.estimator.LatencyPredictor` (and, when ledger
    observations are supplied, the
    :class:`~repro.estimator.interference.FittedInterference` law).

``explore``
    Close the PR 4 residual — *telemetry can only correct placements
    that execute*.  :func:`coverage_report` diffs the profile table's
    candidate placements against per-layer execution counts
    (:func:`execution_counts` over served mappings); for each
    never-or-stale-executed placement, :func:`explore_once`
    re-measures its cheapest candidate off the hot path, folds the
    observed/stored ratio back through the *existing*
    :func:`~repro.adapt.controller.fold_observed` bridge (a one-layer
    shim segment per stale row), re-runs the mapper on the corrected
    table, and persists the new mapping only when it is strictly
    better than the old one repriced under the same correction.  The
    corrected table itself is never persisted — same rule as the
    adaptive runtime (transient conditions must not poison warm
    starts).  ``sweep="frontier"`` re-measures *all* stale candidates
    per row with per-candidate folding instead of the cheapest only.
    Nothing here runs on the serving path.

``flush``
    Push a write-back :class:`~repro.cachesvc.TieredBackend`'s dirty
    keys to its shared back tier (:func:`flush_once`) — enqueued as a
    periodic job on the backend's ``flush_interval_s`` cadence, so
    staleness of the shared tier is bounded by the timer, not by the
    next explicit flush.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core.mapper import (
    DEVICE,
    HOST,
    Segment,
    map_efficient_configuration,
    placement_of,
    price_mapping,
)

_PLACEMENTS = (HOST, DEVICE)


def execution_counts(config, steps: int, into: dict | None = None) -> dict:
    """{(layer_index, config_name): executions} for a mapping served
    for `steps` engine steps — every layer's chosen config runs once
    per step.  Pass ``into`` to accumulate across mappings/engines
    (e.g. before and after a hot swap)."""
    counts = {} if into is None else into
    for layer, cfg in enumerate(config.layer_configs):
        ident = (layer, cfg)
        counts[ident] = counts.get(ident, 0) + int(steps)
    return counts


@dataclasses.dataclass(frozen=True)
class CoverageRow:
    """One under-explored (layer, placement): the profile table offers
    ``candidates`` there, but execution counts show fewer than
    ``min_count`` real executions — its stored rows are unverified by
    telemetry and may be arbitrarily stale."""

    layer: int
    placement: str              # mapper.HOST / mapper.DEVICE
    executed: int               # real executions on this placement
    candidates: tuple           # profiled configs never verified


def coverage_report(
    table,
    batch: int,
    counts: Mapping,
    *,
    min_count: int = 1,
) -> tuple:
    """The exploration frontier: every (layer, placement) the profile
    table prices but telemetry has executed fewer than `min_count`
    times.  ``counts`` is :func:`execution_counts` output (or a merge
    of several)."""
    if batch not in table.batch_sizes:
        raise ValueError(
            f"batch {batch} not profiled (have {table.batch_sizes})"
        )
    rows = []
    for layer in range(len(table.layer_labels)):
        row_configs = table.configs_for(batch, layer)
        for placement in _PLACEMENTS:
            cands = tuple(
                c for c in row_configs if placement_of(c) == placement
            )
            if not cands:
                continue
            executed = sum(
                n for (li, cfg), n in counts.items()
                if li == layer and placement_of(cfg) == placement
            )
            if executed < min_count:
                rows.append(
                    CoverageRow(layer, placement, executed, cands)
                )
    return tuple(rows)


class _ShimConfig:
    """Just enough of an EfficientConfiguration for
    ``fold_observed``: one single-layer segment per explored row, so
    each measured ratio scales exactly that layer's same-placement
    candidates."""

    def __init__(self, rows: Sequence[CoverageRow]):
        self._segments = tuple(
            Segment(
                start=r.layer, stop=r.layer + 1,
                placement=r.placement, configs=(),
            )
            for r in rows
        )

    def segments(self) -> tuple:
        return self._segments


@dataclasses.dataclass(frozen=True)
class _ShimReport:
    segment_index: int
    ratio: float


def _fold_candidates(table, ratios: Mapping, *, min_factor: float):
    """A corrected copy of `table` with **per-candidate** kernel-time
    scaling: ``ratios`` maps ``(layer, config) -> observed/stored``,
    and only those exact rows change (at every profiled batch);
    totals are rebuilt as kernel plus the unchanged boundary.  The
    frontier sweep needs this instead of
    :func:`~repro.adapt.controller.fold_observed`, whose one ratio
    per drifted layer scales *all* same-placement candidates alike —
    correct for a segment-level drift report, wrong for a sweep that
    measured each candidate individually."""
    from repro.core.profiler import ProfileTable

    touched = {layer for layer, _ in ratios}
    times: dict = {}
    kernels: dict = {}
    for b in table.batch_sizes:
        times[b], kernels[b] = [], []
        for i in range(len(table.layer_labels)):
            if i not in touched:
                times[b].append(table.times[b][i])
                kernels[b].append(
                    table.kernel_times[b][i]
                    if table.kernel_times is not None
                    else table.times[b][i]
                )
                continue
            krow, trow = {}, {}
            for cfg in table.configs_for(b, i):
                k = table.kernel_time(b, i, cfg)
                f = ratios.get((i, cfg))
                if f is not None:
                    k *= max(f, min_factor)
                krow[cfg] = k
                trow[cfg] = k + table.boundary_time(b, i, cfg)
            kernels[b].append(krow)
            times[b].append(trow)
    return ProfileTable(
        model_name=table.model_name,
        batch_sizes=table.batch_sizes,
        layer_labels=table.layer_labels,
        times=times,
        kernel_times=kernels,
        h2d_times=table.h2d_times,
        d2h_times=table.d2h_times,
    )


def explore_once(
    store,
    model,
    table,
    *,
    batch: int,
    counts: Mapping,
    measure_fn: Callable,
    policy: str = "dp",
    min_count: int = 1,
    min_factor: float = 1e-3,
    sweep: str = "cheapest",
) -> dict:
    """One exploration pass (the ``explore`` job body).

    ``sweep="cheapest"`` (default) measures each
    :func:`coverage_report` row's cheapest stored candidate —
    ``measure_fn(layer, config, batch) -> seconds`` — and folds the
    measured/stored kernel-time ratio back via ``fold_observed``
    (scaling the row's same-placement candidates together).
    ``sweep="frontier"`` re-measures **every** stale candidate of
    every row and folds each one's own ratio (per-candidate, via
    :func:`_fold_candidates`) — more measurement off the hot path,
    but a mis-priced non-cheapest candidate can only be caught this
    way.  Either way the old mapping is repriced on the corrected
    table (same correction, fair comparison) against a fresh mapper
    run; a strictly better, different mapping is persisted to the
    store.  Returns the journaled result dict — one ``rows`` entry
    per measurement."""
    from repro.adapt.controller import fold_observed

    if sweep not in ("cheapest", "frontier"):
        raise ValueError(
            f"sweep must be 'cheapest' or 'frontier', got {sweep!r}"
        )
    rows = coverage_report(table, batch, counts, min_count=min_count)
    if not rows:
        return {"explored": 0, "improved": False, "sweep": sweep}

    measured_rows = []

    def measure(row, cfg):
        stored = table.kernel_time(batch, row.layer, cfg)
        observed = float(measure_fn(row.layer, cfg, batch))
        ratio = observed / stored if stored > 0 else 1.0
        measured_rows.append(
            {
                "layer": row.layer,
                "placement": row.placement,
                "config": cfg,
                "stored_s": stored,
                "observed_s": observed,
                "ratio": ratio,
            }
        )
        return ratio

    if sweep == "frontier":
        ratios = {
            (row.layer, cfg): measure(row, cfg)
            for row in rows
            for cfg in row.candidates
        }
        corrected = _fold_candidates(
            table, ratios, min_factor=min_factor
        )
    else:
        reports = []
        for i, row in enumerate(rows):
            ref = min(
                row.candidates,
                key=lambda c: table.kernel_time(batch, row.layer, c),
            )
            reports.append(
                _ShimReport(segment_index=i, ratio=measure(row, ref))
            )
        corrected = fold_observed(
            table, _ShimConfig(rows), reports, min_factor=min_factor
        )

    old = store.load_mapping(model, policy=policy, batch=batch)
    if old is None or old.layer_labels != table.layer_labels:
        old = map_efficient_configuration(
            table, policy=policy, batch_sizes=(batch,)
        )
    old_repriced = price_mapping(corrected, batch, old.layer_configs)
    new = map_efficient_configuration(
        corrected, policy=policy, batch_sizes=(batch,)
    )
    improved = (
        new.layer_configs != old.layer_configs
        and new.expected_time_per_example
        < old_repriced.expected_time_per_example
    )
    if improved:
        # only the mapping persists — the corrected table is
        # session-local, same rule as the adaptive runtime
        store.save_mapping(new)
    return {
        "explored": len(rows),
        "measured": len(measured_rows),
        "sweep": sweep,
        "improved": improved,
        "old_expected_s": old_repriced.expected_time_per_example,
        "new_expected_s": new.expected_time_per_example,
        "rows": measured_rows,
    }


def flush_once(backend) -> dict:
    """One write-back flush pass (the ``flush`` job body): push the
    tiered backend's dirty keys to its back tier.  Idempotent — a
    clean tier flushes zero keys."""
    pushed = int(backend.flush())
    return {"pushed": pushed, "pending": len(backend.dirty())}


def prewarm_once(
    store,
    model,
    packed_params,
    *,
    profile_fn: Callable,
    batch_sizes: Sequence[int],
    policy: str = "dp",
    configs: Sequence[str] | None = None,
) -> dict:
    """One prewarm pass (the ``prewarm`` job body): make sure the
    store holds a profile *and* a mapping for this key, running the
    same paths a cold serve would.  Idempotent — a fully warmed key
    does zero profiling and zero mapping."""
    table, loaded = store.get_or_profile(
        model, packed_params, profile_fn, batch_sizes=batch_sizes
    )
    config = store.load_mapping(model, policy=policy)
    mapped = False
    if (
        config is None
        or config.layer_labels != table.layer_labels
        or config.proper_batch_size not in table.batch_sizes
    ):
        config = map_efficient_configuration(
            table, configs=configs, policy=policy
        )
        store.save_mapping(config)
        mapped = True
    return {
        "profiled": not loaded,
        "mapped": mapped,
        "batch": config.proper_batch_size,
        "expected_s": config.expected_time_per_example,
    }


def refit_once(
    store,
    *,
    min_new_rows: int = 8,
    observations=None,
    predictor_kwargs: dict | None = None,
) -> dict:
    """One refit pass (the ``refit`` job body): retrain the
    :class:`~repro.estimator.LatencyPredictor` when at least
    `min_new_rows` training rows accumulated since the last persisted
    fit (first fit counts from zero).  ``observations=(ledger,
    expected_step_s)`` additionally recalibrates the interference law
    from that ledger's slowdowns.  Idempotent — re-running after a fit
    with no new rows is a no-op."""
    from repro.estimator.latency import LatencyPredictor

    rows = store.load_training_rows()
    meta = store.predictor_meta()
    fitted_on = 0 if meta is None else meta["source_rows"]
    new_rows = len(rows) - fitted_on
    out = {
        "rows": len(rows),
        "new_rows": new_rows,
        "refit": False,
        "interference": False,
    }
    if rows and new_rows >= min_new_rows:
        pred = LatencyPredictor(**(predictor_kwargs or {})).fit(rows)
        store.save_predictor(pred, source_rows=len(rows))
        out["refit"] = True
        out["n_rows"] = pred.n_rows
    if observations is not None:
        from repro.estimator.interference import InterferenceFit

        ledger, expected = observations
        fit = InterferenceFit.from_ledger(ledger, expected)
        if len(fit):
            law = fit.fit()
            store.save_interference(law)
            out["interference"] = True
            out["gamma"] = law.gamma
    return out
