"""Shared profile/mapping cache service (docs/ARCHITECTURE.md §14).

Three layers, each usable alone:

* :mod:`repro.cachesvc.backends` — pluggable keyed-text storage behind
  :class:`~repro.store.ProfileStore` (``dir://`` bit-compatible with
  the classic layout, ``sqlite://`` shared single-file, ``mem://``
  in-process, tiered read-through composition, ETags, LRU/TTL
  eviction, hit/miss/access counters).
* :mod:`repro.cachesvc.workqueue` — a deduped, retrying async work
  queue (`WorkQueue` + `WorkerPool`) with journaled
  :class:`~repro.cachesvc.workqueue.JobRecord`\\ s.
* :mod:`repro.cachesvc.service` / :mod:`repro.cachesvc.jobs` — the
  background jobs (``prewarm`` / ``refit`` / ``explore`` /
  ``flush``) and the
  :class:`~repro.cachesvc.service.CacheService` that schedules them
  off the serving path.

Only the backend layer is imported eagerly: :mod:`repro.store` depends
on it, while the service layer depends on :mod:`repro.store` — lazy
attribute access keeps the cycle open.
"""

from repro.cachesvc.backends import (
    EvictionPolicy,
    LocalDirBackend,
    MemoryBackend,
    SqliteBackend,
    StoreBackend,
    TieredBackend,
    parse_backend,
)

_LAZY = {
    "JobRecord": "repro.cachesvc.workqueue",
    "WorkQueue": "repro.cachesvc.workqueue",
    "WorkerPool": "repro.cachesvc.workqueue",
    "coverage_report": "repro.cachesvc.jobs",
    "execution_counts": "repro.cachesvc.jobs",
    "explore_once": "repro.cachesvc.jobs",
    "flush_once": "repro.cachesvc.jobs",
    "prewarm_once": "repro.cachesvc.jobs",
    "refit_once": "repro.cachesvc.jobs",
    "CacheService": "repro.cachesvc.service",
}

__all__ = [
    "EvictionPolicy",
    "LocalDirBackend",
    "MemoryBackend",
    "SqliteBackend",
    "StoreBackend",
    "TieredBackend",
    "parse_backend",
    *_LAZY,
]


def __getattr__(name):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
