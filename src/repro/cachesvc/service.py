"""`CacheService` — the shared cache's control plane.

Glues the three layers together: a :class:`~repro.store.ProfileStore`
(over any backend), a :class:`~repro.cachesvc.workqueue.WorkQueue`,
and the job bodies in :mod:`repro.cachesvc.jobs`.  A service instance
owns a *catalog* of registered models and turns operator intents into
deduped, journaled background jobs:

* :meth:`enqueue_prewarm` / :meth:`prewarm_popular` — materialize
  profile + mapping for a key ahead of demand; ``prewarm_popular``
  ranks the catalog by the backend's per-key access counters (every
  serving-path ``load_*`` feeds them), so the keys real traffic asks
  for most are warmed first.
* :meth:`enqueue_refit` — retrain the learned estimators when enough
  new training rows accumulated (``jobs.refit_once``).
* :meth:`enqueue_explore` — re-profile never-or-stale-executed
  placements from a coverage report and fold corrections back
  (``jobs.explore_once``), closing the exploration residual off the
  hot path; ``sweep="frontier"`` re-measures every stale candidate,
  not only the cheapest.
* :meth:`enqueue_flush` — push a write-back tier's dirty keys to the
  shared back tier, one-shot or (with a ``flush_interval_s``)
  periodic via the queue's ``repeat_s`` timer.

Jobs are **keyed like the store entries they materialize** (the
profile/mapping/predictor key strings), so queue dedupe and store
idempotency line up: the same intent enqueued twice converges to one
job and one artifact.  Run jobs synchronously
(:meth:`run_pending` / :meth:`drain` — deterministic, test-friendly)
or start a :meth:`workers` pool to take them genuinely off-thread.
"""

from __future__ import annotations

import time
from typing import Callable, Mapping, Sequence

from repro.cachesvc import jobs as _jobs
from repro.cachesvc.workqueue import WorkerPool, WorkQueue


class CacheService:
    def __init__(
        self,
        store,
        *,
        profile_fn: Callable | None = None,
        measure_fn: Callable | None = None,
        batch_sizes: Sequence[int] = (4,),
        policy: str = "dp",
        configs: Sequence[str] | None = None,
        refit_min_new_rows: int = 8,
        explore_min_count: int = 1,
        max_attempts: int = 3,
        backoff_s: float = 0.05,
        clock: Callable[[], float] = time.monotonic,
    ):
        """``store`` is a :class:`~repro.store.ProfileStore`, a backend
        URI, or a backend instance.  ``profile_fn(model, packed, *,
        batch_sizes)`` powers prewarm; ``measure_fn(layer, config,
        batch) -> seconds`` powers explore — each optional until the
        matching job kind is enqueued."""
        from repro.store import ProfileStore

        self.store = (
            store if isinstance(store, ProfileStore)
            else ProfileStore(store)
        )
        self.profile_fn = profile_fn
        self.measure_fn = measure_fn
        self.batch_sizes = tuple(batch_sizes)
        self.policy = policy
        self.configs = configs
        self.refit_min_new_rows = refit_min_new_rows
        self.explore_min_count = explore_min_count
        self.queue = WorkQueue(
            clock=clock, max_attempts=max_attempts, backoff_s=backoff_s
        )
        self._catalog: dict = {}       # name -> (model, packed_params)

    # -- catalog -----------------------------------------------------
    def register(self, name: str, model, packed_params) -> None:
        """Make (model, params) known to the service so prewarm jobs
        can be enqueued by name (e.g. by popularity ranking)."""
        self._catalog[str(name)] = (model, packed_params)

    @property
    def catalog(self) -> tuple:
        return tuple(sorted(self._catalog))

    def _sig(self, name: str) -> str:
        from repro.store import model_signature

        model, _ = self._catalog[name]
        return model_signature(model)

    # -- prewarm -----------------------------------------------------
    def enqueue_prewarm(
        self, name: str, *, batch_sizes: Sequence[int] | None = None
    ) -> bool:
        """Queue a prewarm for a registered model; False when the same
        key is already queued/running."""
        if self.profile_fn is None:
            raise ValueError("prewarm needs a profile_fn")
        model, packed = self._catalog[str(name)]
        sizes = tuple(
            batch_sizes if batch_sizes is not None else self.batch_sizes
        )
        key = self.store.profile_key(self._sig(str(name)), sizes)
        return self.queue.submit(
            "prewarm",
            key,
            lambda: _jobs.prewarm_once(
                self.store, model, packed,
                profile_fn=self.profile_fn,
                batch_sizes=sizes,
                policy=self.policy,
                configs=self.configs,
            ),
        )

    def popularity(self) -> dict:
        """{registered name: backend access count} — how often
        serving-path loads touched each model's keys.  The ranking
        signal for :meth:`prewarm_popular`."""
        counts = self.store.backend.access_counts()
        out = {}
        for name in self._catalog:
            marker = f"/{self._sig(name)}-r"
            out[name] = sum(
                n for key, n in counts.items() if marker in key
            )
        return out

    def prewarm_popular(self, *, top: int = 4) -> int:
        """Enqueue prewarms for the `top` most-accessed registered
        models (most popular first; ties alphabetical); returns jobs
        actually enqueued after dedupe."""
        ranked = sorted(
            self.popularity().items(), key=lambda kv: (-kv[1], kv[0])
        )
        enqueued = 0
        for name, _count in ranked[: max(0, int(top))]:
            if self.enqueue_prewarm(name):
                enqueued += 1
        return enqueued

    # -- refit -------------------------------------------------------
    def enqueue_refit(self, *, observations=None) -> bool:
        """Queue an estimator refit (predictor + optional interference
        law from ``observations=(ledger, expected_step_s)``)."""
        key = self.store._predictor_key()
        return self.queue.submit(
            "refit",
            key,
            lambda: _jobs.refit_once(
                self.store,
                min_new_rows=self.refit_min_new_rows,
                observations=observations,
            ),
        )

    # -- explore -----------------------------------------------------
    def enqueue_explore(
        self,
        name: str,
        table,
        *,
        batch: int,
        counts: Mapping,
        measure_fn: Callable | None = None,
        sweep: str = "cheapest",
    ) -> bool:
        """Queue an exploration pass for a registered model: `counts`
        is :func:`~repro.cachesvc.jobs.execution_counts` output from
        the serving tier; stale placements get re-measured off the hot
        path and a strictly-better remap is persisted.
        ``sweep="frontier"`` re-measures *every* stale candidate row
        (per-candidate folding) instead of the cheapest only."""
        measure = measure_fn or self.measure_fn
        if measure is None:
            raise ValueError("explore needs a measure_fn")
        model, _ = self._catalog[str(name)]
        key = self.store.mapping_key(
            self._sig(str(name)), self.policy, batch
        )
        counts = dict(counts)
        return self.queue.submit(
            "explore",
            key,
            lambda: _jobs.explore_once(
                self.store, model, table,
                batch=batch,
                counts=counts,
                measure_fn=measure,
                policy=self.policy,
                min_count=self.explore_min_count,
                sweep=sweep,
            ),
        )

    # -- flush -------------------------------------------------------
    def enqueue_flush(self, backend=None, *, interval_s=None) -> bool:
        """Queue a write-back flush of `backend` (default: this
        store's backend; it must expose ``flush()``/``dirty()``, i.e.
        be a write-back :class:`~repro.cachesvc.TieredBackend`).

        With an interval — explicit ``interval_s``, else the
        backend's own ``flush_interval_s`` — the job is **periodic**:
        it re-runs every interval until ``queue.cancel("flush",
        backend.uri())``, so dirty keys reach the shared back tier on
        a timer instead of waiting for an explicit flush.  Without
        either, it is a one-shot flush.  Keyed by the backend URI:
        one timer per tier, however many times this is called."""
        backend = backend if backend is not None else self.store.backend
        # every backend inherits a no-op flush(); only the tiered
        # write-back journal exposes dirty(), so gate on that
        if not hasattr(backend, "dirty"):
            raise ValueError(
                f"backend {backend.uri()!r} has no write-back journal; "
                "timed flushes need a write-back TieredBackend"
            )
        interval = (
            interval_s if interval_s is not None
            else getattr(backend, "flush_interval_s", None)
        )
        return self.queue.submit(
            "flush",
            backend.uri(),
            lambda: _jobs.flush_once(backend),
            delay_s=0.0 if interval is None else float(interval),
            repeat_s=None if interval is None else float(interval),
        )

    # -- execution ---------------------------------------------------
    def run_pending(self) -> int:
        return self.queue.run_pending()

    def drain(self, *, sleep=None) -> int:
        return self.queue.drain(sleep=sleep)

    def workers(self, n: int = 2, **kwargs) -> WorkerPool:
        """A started :class:`WorkerPool` over this service's queue."""
        return WorkerPool(self.queue, n_workers=n, **kwargs).start()

    # -- introspection -----------------------------------------------
    @property
    def journal(self) -> tuple:
        return self.queue.journal

    def stats(self) -> dict:
        return {
            "store": self.store.stats(),
            "queue": self.queue.stats(),
        }
