"""Pluggable storage backends behind :class:`~repro.store.ProfileStore`.

The store's artifacts are small keyed JSON documents; everything a
backend must do is string-keyed text I/O::

    key:  "v1/<fingerprint>[/s-<scope>]/<model>-r<registry>/<file>.json"
    text: the versioned envelope the store writes today

Three backends share that contract (one shared test suite,
``tests/test_cachesvc_backends.py``):

* :class:`LocalDirBackend` — today's on-disk layout, bit-compatible:
  keys map 1:1 to files under the root, written atomically
  (tmp + ``os.replace``), so stores written before the backend layer
  existed load unchanged and vice versa.
* :class:`SqliteBackend` — one shareable file (stdlib ``sqlite3``,
  WAL journal) safe for concurrent readers while a writer commits;
  the multi-host cluster tier points every host at it.
* :class:`MemoryBackend` — in-process dict, for tests and ephemeral
  caches.  ``mem://<name>`` URIs resolve to one shared instance per
  name, so several handles in one process share a cache the way
  several hosts share a sqlite file.

Every backend carries **per-key ETags** (content digests — cheap
change detection for read-through promotion), **hit/miss/eviction
counters** plus per-key access counts (the popularity signal the
cache service's ``prewarm`` worker ranks by), and an optional
:class:`EvictionPolicy` (max-entry LRU + TTL) applied on writes and
:meth:`StoreBackend.sweep`.

:class:`TieredBackend` composes two backends read-through: a
host-local front (typically ``dir://`` or ``mem://``) over a shared
back (typically ``sqlite://``).  Reads hit the front first and promote
back-tier hits; writes go through to both (or, with
``write_back=True``, are journaled dirty and pushed by
:meth:`TieredBackend.flush`).

:func:`parse_backend` selects by URI: ``dir://path``,
``sqlite://path``, ``mem://name`` — a bare path is a dir backend, so
every call site that accepted a root ``Path`` keeps working.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import sqlite3
import threading
import time
from pathlib import Path


def _etag_of(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()[:12]


def validate_key(key: str) -> str:
    """Keys are relative POSIX paths — no absolute paths, no parent
    escapes, no empty segments (a dir backend joins them under its
    root, so a hostile key must never leave it)."""
    if not key or key.startswith("/") or "\\" in key or "\0" in key:
        raise ValueError(f"invalid store key {key!r}")
    # split on the raw separator: PurePosixPath normalizes a leading
    # "./" away, which would let dot segments through
    if any(p in ("..", ".", "") for p in key.split("/")):
        raise ValueError(f"invalid store key {key!r} (relative escapes)")
    return key


@dataclasses.dataclass(frozen=True)
class EvictionPolicy:
    """Bounds a backend: at most ``max_entries`` keys (evicting the
    least-recently-*accessed* first — LRU) and nothing older than
    ``ttl_s`` since it was written.  ``None`` disables a bound; the
    default policy bounds nothing (profile stores are tiny and a
    silently-evicted profile re-profiles, so bounded caches are
    opt-in)."""

    max_entries: int | None = None
    ttl_s: float | None = None

    def __post_init__(self):
        if self.max_entries is not None and self.max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        if self.ttl_s is not None and self.ttl_s <= 0:
            raise ValueError("ttl_s must be positive")


class StoreBackend:
    """Counter bookkeeping shared by every backend.  Subclasses
    implement ``_read/_write/_delete/_keys`` plus timestamp lookups;
    the public API (get/peek/put/delete/list/etag/stats) lives here so
    hit/miss/eviction accounting is uniform."""

    scheme = "?"

    def __init__(self, *, policy: EvictionPolicy | None = None,
                 clock=time.time):
        self.policy = policy or EvictionPolicy()
        self._clock = clock
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.deletes = 0
        self.evictions = 0
        self._access: dict = {}        # key -> get() count (per handle)

    # -- subclass surface --------------------------------------------
    def _read(self, key: str) -> str | None:
        raise NotImplementedError

    def _write(self, key: str, text: str) -> None:
        raise NotImplementedError

    def _delete(self, key: str) -> bool:
        raise NotImplementedError

    def _keys(self) -> list:
        raise NotImplementedError

    def _saved_at(self, key: str) -> float:
        raise NotImplementedError

    def _accessed_at(self, key: str) -> float:
        raise NotImplementedError

    def _touch(self, key: str) -> None:
        """Record an access for LRU ordering (default: in-memory)."""

    # -- public contract ---------------------------------------------
    def get(self, key: str) -> str | None:
        """The stored text, counting a hit or miss and feeding the
        per-key access counter (the prewarm popularity signal)."""
        text = self._read(validate_key(key))
        with self._lock:
            if text is None:
                self.misses += 1
            else:
                self.hits += 1
                self._access[key] = self._access.get(key, 0) + 1
        if text is not None:
            self._touch(key)
        return text

    def peek(self, key: str) -> str | None:
        """Like :meth:`get` but counter-silent — maintenance reads
        (inspect/gc/export) must not skew the popularity signal."""
        return self._read(validate_key(key))

    def put(self, key: str, text: str) -> None:
        self._write(validate_key(key), str(text))
        with self._lock:
            self.puts += 1
        self.sweep()

    def delete(self, key: str) -> bool:
        ok = self._delete(validate_key(key))
        if ok:
            with self._lock:
                self.deletes += 1
                self._access.pop(key, None)
        return ok

    def list(self, prefix: str = "") -> list:
        """Every stored key under `prefix`, sorted."""
        return sorted(k for k in self._keys() if k.startswith(prefix))

    def etag(self, key: str) -> str | None:
        """Content digest of the stored text (None when absent):
        version stamp for change detection and tiered promotion."""
        text = self._read(validate_key(key))
        return None if text is None else _etag_of(text)

    def sweep(self) -> int:
        """Apply the eviction policy now; returns entries evicted."""
        evicted = []
        now = self._clock()
        keys = self._keys()
        if self.policy.ttl_s is not None:
            for k in keys:
                if now - self._saved_at(k) > self.policy.ttl_s:
                    evicted.append(k)
        if self.policy.max_entries is not None:
            live = [k for k in keys if k not in evicted]
            excess = len(live) - self.policy.max_entries
            if excess > 0:
                live.sort(key=lambda k: (self._accessed_at(k), k))
                evicted.extend(live[:excess])
        for k in evicted:
            if self._delete(k):
                with self._lock:
                    self.evictions += 1
                    self._access.pop(k, None)
        return len(evicted)

    def access_counts(self) -> dict:
        """{key: get() hits} for this handle — the popularity feed."""
        with self._lock:
            return dict(self._access)

    def stats(self) -> dict:
        with self._lock:
            return {
                "backend": self.scheme,
                "uri": self.uri(),
                "entries": len(self._keys()),
                "hits": self.hits,
                "misses": self.misses,
                "puts": self.puts,
                "deletes": self.deletes,
                "evictions": self.evictions,
            }

    def path_for(self, key: str) -> Path | None:
        """The real filesystem path for `key` (dir backend only) —
        None when the backend has no per-key files."""
        return None

    def uri(self) -> str:
        raise NotImplementedError

    def flush(self) -> None:
        """Push deferred writes (tiered write-back); no-op elsewhere."""

    def close(self) -> None:
        """Release backend resources; handles stay constructible."""


class LocalDirBackend(StoreBackend):
    """Today's on-disk layout: one file per key under ``root``,
    written atomically so readers never see a torn document.
    Access recency for LRU is tracked in-memory per handle (files have
    no portable atime); ``saved_at`` is the file mtime, so TTL
    eviction agrees with what ``gc`` sees."""

    scheme = "dir"

    def __init__(self, root, *, policy=None, clock=time.time):
        super().__init__(policy=policy, clock=clock)
        self.root = Path(root)
        self._seen: dict = {}          # key -> last access (this handle)

    def _path(self, key: str) -> Path:
        return self.root / key

    def _read(self, key):
        p = self._path(key)
        try:
            return p.read_text()
        except OSError:
            return None

    def _write(self, key, text):
        p = self._path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(text)
        os.replace(tmp, p)             # readers never see a torn file

    def _delete(self, key):
        p = self._path(key)
        try:
            p.unlink()
        except OSError:
            return False
        self._seen.pop(key, None)
        return True

    def _keys(self):
        if not self.root.exists():
            return []
        return [
            p.relative_to(self.root).as_posix()
            for p in self.root.rglob("*.json")
            if p.is_file()
        ]

    def _saved_at(self, key):
        try:
            return self._path(key).stat().st_mtime
        except OSError:
            return 0.0

    def _accessed_at(self, key):
        return self._seen.get(key, self._saved_at(key))

    def _touch(self, key):
        self._seen[key] = self._clock()

    def prune_empty_dirs(self) -> None:
        if not self.root.exists():
            return
        for d in sorted(
            (p for p in self.root.rglob("*") if p.is_dir()),
            key=lambda p: len(p.parts),
            reverse=True,
        ):
            if not any(d.iterdir()):
                d.rmdir()

    def path_for(self, key: str) -> Path:
        return self.root if not key else self._path(validate_key(key))

    def uri(self) -> str:
        return f"dir://{self.root}"


class SqliteBackend(StoreBackend):
    """One shareable database file.  WAL journaling keeps readers
    unblocked while a writer commits — the property the multi-host
    cluster needs when every host reads one shared cache.  Each
    operation opens its own short-lived connection (cross-thread and
    cross-process safe; the documents are small and rare enough that
    connection reuse would buy nothing)."""

    scheme = "sqlite"

    _SCHEMA = """
        CREATE TABLE IF NOT EXISTS entries (
            key         TEXT PRIMARY KEY,
            text        TEXT NOT NULL,
            etag        TEXT NOT NULL,
            saved_at    REAL NOT NULL,
            accessed_at REAL NOT NULL
        )
    """

    def __init__(self, path, *, policy=None, clock=time.time):
        super().__init__(policy=policy, clock=clock)
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with self._connect() as con:
            con.execute("PRAGMA journal_mode=WAL")
            con.execute(self._SCHEMA)

    def _connect(self):
        return sqlite3.connect(self.path, timeout=10.0)

    def _read(self, key):
        with self._connect() as con:
            row = con.execute(
                "SELECT text FROM entries WHERE key = ?", (key,)
            ).fetchone()
        return None if row is None else row[0]

    def _write(self, key, text):
        now = self._clock()
        with self._connect() as con:
            con.execute(
                "INSERT INTO entries (key, text, etag, saved_at, "
                "accessed_at) VALUES (?, ?, ?, ?, ?) "
                "ON CONFLICT(key) DO UPDATE SET text = excluded.text, "
                "etag = excluded.etag, saved_at = excluded.saved_at, "
                "accessed_at = excluded.accessed_at",
                (key, text, _etag_of(text), now, now),
            )

    def _delete(self, key):
        with self._connect() as con:
            cur = con.execute(
                "DELETE FROM entries WHERE key = ?", (key,)
            )
        return cur.rowcount > 0

    def _keys(self):
        with self._connect() as con:
            return [
                r[0] for r in con.execute("SELECT key FROM entries")
            ]

    def _saved_at(self, key):
        with self._connect() as con:
            row = con.execute(
                "SELECT saved_at FROM entries WHERE key = ?", (key,)
            ).fetchone()
        return 0.0 if row is None else float(row[0])

    def _accessed_at(self, key):
        with self._connect() as con:
            row = con.execute(
                "SELECT accessed_at FROM entries WHERE key = ?", (key,)
            ).fetchone()
        return 0.0 if row is None else float(row[0])

    def _touch(self, key):
        with self._connect() as con:
            con.execute(
                "UPDATE entries SET accessed_at = ? WHERE key = ?",
                (self._clock(), key),
            )

    def etag(self, key: str) -> str | None:
        with self._connect() as con:
            row = con.execute(
                "SELECT etag FROM entries WHERE key = ?",
                (validate_key(key),),
            ).fetchone()
        return None if row is None else row[0]

    def uri(self) -> str:
        return f"sqlite://{self.path}"


class MemoryBackend(StoreBackend):
    """In-process dict; ``mem://<name>`` URIs share one instance per
    name (module registry), so tests and single-process fleets get a
    shared cache with zero filesystem."""

    scheme = "mem"

    def __init__(self, name: str = "", *, policy=None, clock=time.time):
        super().__init__(policy=policy, clock=clock)
        self.name = name
        self._data: dict = {}          # key -> (text, saved, accessed)

    def _read(self, key):
        row = self._data.get(key)
        return None if row is None else row[0]

    def _write(self, key, text):
        now = self._clock()
        self._data[key] = (text, now, now)

    def _delete(self, key):
        return self._data.pop(key, None) is not None

    def _keys(self):
        return list(self._data)

    def _saved_at(self, key):
        row = self._data.get(key)
        return 0.0 if row is None else row[1]

    def _accessed_at(self, key):
        row = self._data.get(key)
        return 0.0 if row is None else row[2]

    def _touch(self, key):
        row = self._data.get(key)
        if row is not None:
            self._data[key] = (row[0], row[1], self._clock())

    def uri(self) -> str:
        return f"mem://{self.name}"


class TieredBackend(StoreBackend):
    """Read-through composition: a host-local `front` cache over a
    shared `back`.  ``get`` serves front hits without touching the
    back and promotes back-tier hits into the front; ``put`` writes
    through to both unless ``write_back=True``, which journals dirty
    keys locally until :meth:`flush` pushes them (an ETag check skips
    keys the back already holds verbatim).  ``flush_interval_s``
    declares the tier's flush cadence: the backend itself stays
    passive (no threads here), but
    ``CacheService.enqueue_flush`` reads it to drive :meth:`flush`
    as a periodic ``WorkQueue`` job, bounding how stale the shared
    back tier can get.  The tier's own hit/miss
    counters measure front effectiveness; :meth:`stats` nests both
    tiers' counters."""

    scheme = "tiered"

    def __init__(self, front: StoreBackend, back: StoreBackend, *,
                 write_back: bool = False, flush_interval_s=None,
                 policy=None, clock=time.time):
        super().__init__(policy=policy, clock=clock)
        if flush_interval_s is not None:
            flush_interval_s = float(flush_interval_s)
            if flush_interval_s <= 0:
                raise ValueError("flush_interval_s must be positive")
            if not write_back:
                raise ValueError(
                    "flush_interval_s without write_back=True is "
                    "meaningless: write-through tiers are never dirty"
                )
        self.front = front
        self.back = back
        self.write_back = write_back
        self.flush_interval_s = flush_interval_s
        self._dirty: set = set()

    def _read(self, key):
        text = self.front.peek(key)
        if text is not None:
            return text
        text = self.back.peek(key)
        if text is not None:
            self.front.put(key, text)   # promote (read-through)
        return text

    def _write(self, key, text):
        self.front.put(key, text)
        if self.write_back:
            with self._lock:
                self._dirty.add(key)
        else:
            self.back.put(key, text)

    def _delete(self, key):
        with self._lock:
            self._dirty.discard(key)
        f = self.front.delete(key)
        b = self.back.delete(key)
        return f or b

    def _keys(self):
        return list(set(self.front.list()) | set(self.back.list()))

    def _saved_at(self, key):
        return max(self.front._saved_at(key), self.back._saved_at(key))

    def _accessed_at(self, key):
        return max(
            self.front._accessed_at(key), self.back._accessed_at(key)
        )

    def etag(self, key: str) -> str | None:
        return (
            self.front.etag(key)
            if self.front.peek(key) is not None
            else self.back.etag(key)
        )

    def path_for(self, key: str) -> Path | None:
        return self.front.path_for(key)

    def flush(self) -> int:
        """Push every dirty key to the back tier; returns pushes
        performed (ETag-identical keys are skipped, not pushed)."""
        with self._lock:
            dirty, self._dirty = self._dirty, set()
        pushed = 0
        for key in sorted(dirty):
            text = self.front.peek(key)
            if text is None:
                continue               # written then deleted
            if self.back.etag(key) == _etag_of(text):
                continue
            self.back.put(key, text)
            pushed += 1
        return pushed

    def dirty(self) -> tuple:
        with self._lock:
            return tuple(sorted(self._dirty))

    def stats(self) -> dict:
        out = super().stats()
        out["pending_write_back"] = len(self._dirty)
        out["flush_interval_s"] = self.flush_interval_s
        out["front"] = self.front.stats()
        out["back"] = self.back.stats()
        return out

    def uri(self) -> str:
        return f"tiered://{self.front.uri()}|{self.back.uri()}"


_MEM_REGISTRY: dict = {}
_MEM_LOCK = threading.Lock()


def parse_backend(spec, *, policy: EvictionPolicy | None = None
                  ) -> StoreBackend:
    """Resolve a backend from a URI, path, or backend instance.

    ``dir://path`` / bare path / :class:`~pathlib.Path` → dir backend;
    ``sqlite://path`` → sqlite; ``mem://name`` → the process-shared
    memory backend for `name` (an empty name is a fresh private one).
    A :class:`StoreBackend` instance passes through unchanged."""
    if isinstance(spec, StoreBackend):
        return spec
    if isinstance(spec, Path):
        return LocalDirBackend(spec, policy=policy)
    if not isinstance(spec, str):
        raise TypeError(
            f"cannot resolve a store backend from {type(spec).__name__}"
        )
    if spec.startswith("mem://"):
        name = spec[len("mem://"):]
        if not name:
            return MemoryBackend(policy=policy)
        with _MEM_LOCK:
            if name not in _MEM_REGISTRY:
                _MEM_REGISTRY[name] = MemoryBackend(name, policy=policy)
            return _MEM_REGISTRY[name]
    if spec.startswith("sqlite://"):
        path = spec[len("sqlite://"):]
        if not path:
            raise ValueError("sqlite:// needs a database path")
        return SqliteBackend(path, policy=policy)
    if spec.startswith("dir://"):
        path = spec[len("dir://"):]
        if not path:
            raise ValueError("dir:// needs a directory path")
        return LocalDirBackend(path, policy=policy)
    if "://" in spec:
        raise ValueError(
            f"unknown store backend URI {spec!r}; expected dir://, "
            "sqlite:// or mem://"
        )
    return LocalDirBackend(spec, policy=policy)
