"""Async work queue for background cache jobs — deduped, retried,
journaled.

The cache service's jobs (``prewarm`` / ``refit`` / ``explore``) are
**idempotent**: each is keyed like the store entry it materializes,
re-running one converges to the same artifact, and a crash mid-job
loses nothing but the attempt.  That contract is what makes the queue
simple and safe:

* **dedupe** — :meth:`WorkQueue.submit` refuses a (kind, key) that is
  already queued or running, so a popularity spike enqueues one
  prewarm, not fifty;
* **delay + periodic jobs** — ``submit(..., delay_s=, repeat_s=)``
  defers the first run and, with ``repeat_s``, re-enqueues a fresh
  attempt one period after each completion (the timed write-back
  flush rides this) until :meth:`WorkQueue.cancel`;
* **retry with exponential backoff** — a failing job is re-queued with
  ``backoff_s * 2**(attempt-1)`` delay until ``max_attempts``, then
  journaled as failed (never silently dropped, never retried forever);
* **journal** — every *finished* job appends an immutable
  :class:`JobRecord` (mirroring the cluster tier's ``ScaleRecord``),
  so operators can audit what background work ran, when, with what
  outcome.

Time is injected (``clock``) and sleeping is injected (``drain``'s
``sleep=``), so tier-1 tests drive retry/backoff with the shared
``tests/fixtures.py`` FakeClock — ``drain(sleep=clock.advance)``
passes virtual time between attempts with **zero real sleeps**.

:class:`WorkQueue` alone is a synchronous scheduler
(:meth:`~WorkQueue.run_pending` / :meth:`~WorkQueue.drain` — fully
deterministic, what tests and the bench use).  :class:`WorkerPool`
adds real daemon threads popping the same queue for deployments that
want background work genuinely off the serving thread.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable


@dataclasses.dataclass(frozen=True)
class JobRecord:
    """One finished background job, as the journal reports it."""

    seq: int
    kind: str
    key: str
    status: str                  # "done" | "failed"
    attempts: int
    enqueued_s: float
    finished_s: float
    result: dict | None = None
    error: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Job:
    kind: str
    key: str
    fn: Callable
    enqueued_s: float
    due_s: float
    attempts: int = 0
    repeat_s: float | None = None    # periodic job: re-enqueue period

    @property
    def ident(self) -> tuple:
        return (self.kind, self.key)


class WorkQueue:
    """Deduped delay queue of idempotent jobs.

    ``submit(kind, key, fn)`` enqueues ``fn()`` under the job identity
    ``(kind, key)``; a duplicate of a queued/running identity is
    refused (returns False).  Jobs run when *popped* — by
    :meth:`run_pending` / :meth:`drain` on the calling thread, or by a
    :class:`WorkerPool`.  A job that raises is retried with
    exponential backoff up to ``max_attempts``, then journaled as
    failed.  ``fn``'s return value (a JSON-able dict or None) lands in
    the :class:`JobRecord`.
    """

    def __init__(
        self,
        *,
        clock: Callable[[], float] = time.monotonic,
        max_attempts: int = 3,
        backoff_s: float = 0.05,
    ):
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if backoff_s < 0:
            raise ValueError("backoff_s must be >= 0")
        self.clock = clock
        self.max_attempts = max_attempts
        self.backoff_s = backoff_s
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queued: list = []
        self._running: set = set()
        self._cancelled: set = set()
        self._journal: list = []
        self._seq = 0
        self.submitted = 0
        self.deduped = 0
        self.retries = 0

    # -- producer side -----------------------------------------------
    def submit(
        self,
        kind: str,
        key: str,
        fn: Callable,
        *,
        delay_s: float = 0.0,
        repeat_s: float | None = None,
    ) -> bool:
        """Enqueue ``fn`` as job (kind, key); False when that identity
        is already queued or running (idempotent jobs make the newer
        submission redundant, not lost).

        ``delay_s`` defers the first run.  ``repeat_s`` makes the job
        **periodic**: each completion (success *or* final failure —
        a timer must not die because one tick failed) re-enqueues a
        fresh attempt ``repeat_s`` after it finishes, until
        :meth:`cancel`.  Periodic re-enqueues happen at the queue
        level precisely because this dedupe would refuse a job
        resubmitting itself from inside its own ``fn`` (its identity
        is still marked running there)."""
        if delay_s < 0:
            raise ValueError("delay_s must be >= 0")
        if repeat_s is not None and repeat_s <= 0:
            raise ValueError("repeat_s must be positive")
        ident = (str(kind), str(key))
        with self._cv:
            live = {j.ident for j in self._queued} | self._running
            if ident in live:
                self.deduped += 1
                return False
            self._cancelled.discard(ident)
            now = self.clock()
            self._queued.append(
                _Job(
                    ident[0], ident[1], fn, enqueued_s=now,
                    due_s=now + delay_s, repeat_s=repeat_s,
                )
            )
            self.submitted += 1
            self._cv.notify()
            return True

    def cancel(self, kind: str, key: str) -> bool:
        """Drop job (kind, key): dequeue it if queued; if currently
        running, let the attempt finish but suppress a periodic
        re-enqueue.  Returns True when the identity was live."""
        ident = (str(kind), str(key))
        with self._cv:
            before = len(self._queued)
            self._queued = [j for j in self._queued if j.ident != ident]
            if len(self._queued) != before:
                return True
            if ident in self._running:
                self._cancelled.add(ident)
                return True
            return False

    # -- consumer side -----------------------------------------------
    def _pop_due(self):
        """(internal, lock held) the first due job, marked running."""
        now = self.clock()
        for i, job in enumerate(self._queued):
            if job.due_s <= now:
                self._running.add(job.ident)
                return self._queued.pop(i)
        return None

    def _record(self, job: _Job, status: str, result, error: str):
        self._journal.append(
            JobRecord(
                seq=self._seq,
                kind=job.kind,
                key=job.key,
                status=status,
                attempts=job.attempts,
                enqueued_s=job.enqueued_s,
                finished_s=self.clock(),
                result=result,
                error=error,
            )
        )
        self._seq += 1

    def _reschedule(self, job: _Job) -> None:
        """(lock held) re-enqueue a finished periodic job one period
        out, as a fresh attempt — unless it was cancelled mid-run."""
        if job.repeat_s is None:
            return
        if job.ident in self._cancelled:
            self._cancelled.discard(job.ident)
            return
        now = self.clock()
        self._queued.append(
            _Job(
                job.kind, job.key, job.fn, enqueued_s=now,
                due_s=now + job.repeat_s, repeat_s=job.repeat_s,
            )
        )

    def _execute(self, job: _Job) -> None:
        """Run one popped job; journal or re-queue under the lock."""
        job.attempts += 1
        try:
            result = job.fn()
        except Exception as exc:  # noqa: BLE001 — journaled, not lost
            with self._cv:
                self._running.discard(job.ident)
                if job.attempts >= self.max_attempts:
                    self._record(
                        job, "failed", None,
                        f"{type(exc).__name__}: {exc}",
                    )
                    self._reschedule(job)
                else:
                    self.retries += 1
                    job.due_s = self.clock() + self.backoff_s * (
                        2 ** (job.attempts - 1)
                    )
                    self._queued.append(job)
                self._cv.notify_all()
            return
        with self._cv:
            self._running.discard(job.ident)
            self._record(
                job, "done",
                result if isinstance(result, dict) else None, "",
            )
            self._reschedule(job)
            self._cv.notify_all()

    def run_pending(self) -> int:
        """Run every currently-due job on this thread (one pass —
        backoff-delayed retries stay queued); returns jobs run."""
        ran = 0
        while True:
            with self._cv:
                job = self._pop_due()
            if job is None:
                return ran
            self._execute(job)
            ran += 1

    def drain(self, *, sleep: Callable[[float], None] | None = None) -> int:
        """Run until every **one-shot** job (including its backoff
        retries) has finished, sleeping to the next deadline between
        passes; periodic jobs never make a queue "dirty", or a single
        ``repeat_s`` timer would make drain spin forever.  Inject
        ``sleep=fake_clock.advance`` in tests: retries then experience
        full virtual backoff with zero real sleeping.  Returns total
        jobs run."""
        sleep = time.sleep if sleep is None else sleep
        ran = 0
        while True:
            ran += self.run_pending()
            with self._cv:
                oneshot = [
                    j for j in self._queued if j.repeat_s is None
                ]
                if not oneshot:
                    return ran
                delay = max(
                    0.0,
                    min(j.due_s for j in oneshot) - self.clock(),
                )
            # max() guards a clock that only moves when told to: a
            # zero-delay sleep must still let it make progress
            sleep(max(delay, 1e-9))

    def next_due_s(self) -> float | None:
        """Seconds until the earliest queued job is due (0 when due
        now); None when nothing is queued."""
        with self._cv:
            if not self._queued:
                return None
            return max(
                0.0, min(j.due_s for j in self._queued) - self.clock()
            )

    # -- introspection -----------------------------------------------
    def pending(self) -> int:
        with self._cv:
            return len(self._queued) + len(self._running)

    @property
    def journal(self) -> tuple:
        with self._cv:
            return tuple(self._journal)

    def stats(self) -> dict:
        with self._cv:
            done = sum(1 for r in self._journal if r.status == "done")
            failed = len(self._journal) - done
            return {
                "queued": len(self._queued),
                "running": len(self._running),
                "repeating": sum(
                    1 for j in self._queued if j.repeat_s is not None
                ),
                "submitted": self.submitted,
                "deduped": self.deduped,
                "retries": self.retries,
                "done": done,
                "failed": failed,
            }


class WorkerPool:
    """Daemon threads draining a :class:`WorkQueue` in the background.

    Start with :meth:`start`; :meth:`join_idle` blocks (with real
    time) until the queue is momentarily empty — the synchronization
    tests and shutdown paths need; :meth:`stop` halts the loops and
    joins the threads.  The pool adds no scheduling policy of its own:
    dedupe/backoff/journal all live in the queue, so synchronous and
    threaded execution are behaviorally identical.
    """

    def __init__(self, queue: WorkQueue, *, n_workers: int = 2,
                 poll_s: float = 0.02):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        self.queue = queue
        self.n_workers = n_workers
        self.poll_s = poll_s
        self._stop = threading.Event()
        self._threads: list = []

    def start(self) -> "WorkerPool":
        if self._threads:
            raise RuntimeError("worker pool already started")
        for i in range(self.n_workers):
            t = threading.Thread(
                target=self._loop, name=f"cachesvc-worker-{i}",
                daemon=True,
            )
            t.start()
            self._threads.append(t)
        return self

    def _loop(self) -> None:
        q = self.queue
        while not self._stop.is_set():
            with q._cv:
                job = q._pop_due()
                if job is None:
                    q._cv.wait(timeout=self.poll_s)
                    continue
            q._execute(job)

    def join_idle(self, timeout: float = 5.0) -> bool:
        """Wait until no one-shot work is queued and nothing is
        running (True) or `timeout` real seconds elapse (False).
        Dormant periodic jobs don't count — a flush timer would
        otherwise make the pool permanently non-idle."""
        deadline = time.monotonic() + timeout
        q = self.queue
        with q._cv:
            while (
                any(j.repeat_s is None for j in q._queued) or q._running
            ):
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                q._cv.wait(timeout=min(remaining, self.poll_s))
        return True

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        with self.queue._cv:
            self.queue._cv.notify_all()
        for t in self._threads:
            t.join(timeout=timeout)
        self._threads = []

    @property
    def alive(self) -> int:
        return sum(1 for t in self._threads if t.is_alive())
