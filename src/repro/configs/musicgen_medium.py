"""musicgen-medium [audio] — 48L d1536 24H(kv24) d_ff=6144 vocab=2048;
decoder-only over EnCodec tokens [arXiv:2306.05284]. The EnCodec /
text-conditioning frontend is a STUB per the brief: input_specs()
provides 64 precomputed conditioning frame embeddings; the token stream
is a single interleaved EnCodec codebook stream (delay-pattern
flattening), vocab 2048. Standard (non-gated) GELU MLP."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab=2048,
        mlp_type="gelu",
        n_frontend_embeds=64,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        mlp_type="gelu",
        n_frontend_embeds=8,
        dtype="float32",
    )
