"""olmo-1b [dense] — 16L d2048 16H(kv16) d_ff=8192 vocab=50304;
non-parametric LayerNorm (no scale/bias), tied embeddings
[arXiv:2402.00838]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab=50_304,
        norm="nonparam",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        norm="nonparam",
        tie_embeddings=True,
        dtype="float32",
    )
