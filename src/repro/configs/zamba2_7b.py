"""zamba2-7b [hybrid] — 81 Mamba2 layers d3584, shared attention block
32H(kv32) d_ff=14336, vocab=32000, ssm_state=64 [arXiv:2411.15242].
Shared transformer block (single weight set) applied after every 6
Mamba2 layers — the weight-sharing scheme that defines the Zamba
family. Sub-quadratic: runs the long_500k cell."""

from repro.models.config import ModelConfig, SSMConfig, HybridConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14_336,
        vocab=32_000,
        ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_kernel=4),
        hybrid=HybridConfig(attn_every=6),
        subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke",
        family="hybrid",
        n_layers=7,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, chunk=8),
        hybrid=HybridConfig(attn_every=3),
        subquadratic=True,
        dtype="float32",
    )
