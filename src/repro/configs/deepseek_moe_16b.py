"""deepseek-moe-16b [moe] — 28L d2048 16H(kv16) expert_ff=1408
vocab=102400; 2 shared + 64 routed top-6, fine-grained experts
[arXiv:2401.06066]. Simplification vs HF: the real model's first layer
uses a dense MLP; here all 28 layers are MoE (noted in docs/ARCHITECTURE.md §7)."""

from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=102_400,
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=2, d_expert=32),
        dtype="float32",
    )
