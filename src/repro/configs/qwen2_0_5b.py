"""qwen2-0.5b [dense] — 24L d896 14H(kv2) d_ff=4864 vocab=151936;
GQA with QKV bias, tied embeddings [arXiv:2407.10671]. The
'Fashion-MNIST of LMs': small enough that model parallelism never wins
— HEP-Shard maps it to pure data parallelism (see EXPERIMENTS.md)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b",
        family="dense",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab=151_936,
        qkv_bias=True,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,  # kv=2 keeps the 7:1-style grouping exercised
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        qkv_bias=True,
        tie_embeddings=True,
        dtype="float32",
    )
