"""grok-1-314b [moe] — 64L d6144 48H(kv8) d_ff=32768 vocab=131072;
8 experts top-2 [hf:xai-org/grok-1]. Routed experts use the gated-SiLU
form of this framework (grok's GeGLU variant differs only in the
activation; noted in docs/ARCHITECTURE.md §7)."""

from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32_768,
        vocab=131_072,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_expert=32_768),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=0, d_expert=128),
        dtype="float32",
    )
