"""Assigned-architecture registry: ``get(name)`` -> full ModelConfig,
``get_smoke(name)`` -> reduced same-family config for CPU smoke tests,
``input_specs(cfg, shape)`` -> ShapeDtypeStruct stand-ins per cell.

Shapes (assigned to every LM arch):
  train_4k     seq 4,096   global_batch 256   (train_step)
  prefill_32k  seq 32,768  global_batch 32    (prefill_step)
  decode_32k   seq 32,768  global_batch 128   (serve_step, 1 new token)
  long_500k    seq 524,288 global_batch 1     (serve_step; sub-quadratic
                                               archs only — see docs/ARCHITECTURE.md §7)
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import cache_specs

ARCH_NAMES = (
    "deepseek_moe_16b",
    "grok_1_314b",
    "zamba2_7b",
    "llava_next_mistral_7b",
    "qwen2_5_14b",
    "olmo_1b",
    "minitron_8b",
    "qwen2_0_5b",
    "mamba2_130m",
    "musicgen_medium",
    # the paper's own models live in repro.bnn.models (image BNNs)
)


def _mod(name: str):
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str) -> ModelConfig:
    return _mod(canonical(name)).config()


def get_smoke(name: str) -> ModelConfig:
    return _mod(canonical(name)).smoke_config()


def canonical(name: str) -> str:
    n = name.replace("-", "_").replace(".", "_")
    if n not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    return n


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: ModelConfig, shape: str) -> bool:
    """long_500k requires sub-quadratic context (ssm/hybrid)."""
    if shape == "long_500k":
        return cfg.subquadratic
    return True


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    sh = SHAPES[shape]
    i32 = jnp.int32
    nf = cfg.n_frontend_embeds
    t_text = sh.seq - nf
    dt = jnp.dtype(cfg.dtype)

    if sh.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((sh.batch, t_text), i32),
            "labels": jax.ShapeDtypeStruct((sh.batch, t_text), i32),
        }
        if nf:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (sh.batch, nf, cfg.d_model), dt
            )
        return specs

    if sh.kind == "prefill":
        specs = {
            "tokens": jax.ShapeDtypeStruct((sh.batch, t_text), i32),
        }
        if nf:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (sh.batch, nf, cfg.d_model), dt
            )
        return specs

    # decode: one token against a seq-length cache
    return {
        "token": jax.ShapeDtypeStruct((sh.batch, 1), i32),
        "cache": cache_specs(cfg, sh.batch, sh.seq),
    }
