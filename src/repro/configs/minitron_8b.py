"""minitron-8b [dense] — 32L d4096 32H(kv8) d_ff=16384 vocab=256000;
pruned nemotron with squared-ReLU MLP [arXiv:2407.14679]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16_384,
        vocab=256_000,
        mlp_type="relu2",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        mlp_type="relu2",
        dtype="float32",
    )
