"""llava-next-mistral-7b [vlm] — Mistral-7B backbone: 32L d4096
32H(kv8) d_ff=14336 vocab=32000 [hf:llava-hf/llava-v1.6-mistral-7b-hf].
The anyres vision frontend is a STUB per the brief: input_specs()
provides 576 precomputed patch embeddings (one 24x24 CLIP grid)
prepended to the token sequence."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14_336,
        vocab=32_000,
        rope_theta=1e6,
        n_frontend_embeds=576,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        n_frontend_embeds=8,
        dtype="float32",
    )
