"""qwen2.5-14b [dense] — 48L d5120 40H(kv8) d_ff=13824 vocab=152064;
GQA with QKV bias [arXiv:2412.15115 / hf:Qwen]."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        family="dense",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=13_824,
        vocab=152_064,
        qkv_bias=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        qkv_bias=True,
        dtype="float32",
    )
