"""mamba2-130m [ssm] — 24L d768 attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060].
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads. Sub-quadratic:
runs the long_500k cell. The paper's attention-sharding candidates are
inapplicable (attention-free) — the X/Y/Z kernel aspects still apply to
its matmuls; see docs/ARCHITECTURE.md §7."""

from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=1,       # unused (attention-free)
        n_kv_heads=1,
        d_ff=0,
        vocab=50_280,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, conv_kernel=4),
        tie_embeddings=True,
        subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m-smoke",
        family="ssm",
        n_layers=3,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab=512,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, chunk=8),
        tie_embeddings=True,
        subquadratic=True,
        dtype="float32",
    )
