"""Production mesh builders.

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
init; tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; the multi-pod variant
    adds a leading 2-pod data-parallel axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for local sharding tests (subprocess with
    xla_force_host_platform_device_count set accordingly)."""
    return jax.make_mesh(shape, axes)
