import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: compile named scheme variants for the
three chosen cells, derive roofline terms, and log
hypothesis -> change -> before -> after (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen
    PYTHONPATH=src python -m repro.launch.hillclimb --all

Also hosts the BNN *mapping* hillclimb (``--bnn`` /
:func:`bnn_mapping_hillclimb`): local search over per-layer
implementations whose move space is each profile row's own candidate
set — the kernel-variant registry's variable-size per-layer spaces the
DP mapper searches — not the hard-coded fixed 8.
"""

import argparse
import dataclasses
import json
from pathlib import Path


def _fused_total(table, batch, mapping) -> float:
    from repro.core.mapper import attribute_fused_costs

    kernels, boundaries = attribute_fused_costs(table, batch, mapping)
    return sum(kernels) + sum(boundaries)


def bnn_mapping_hillclimb(
    table, *, batch=None, start=None, max_sweeps: int = 50
):
    """First-improvement hillclimb over per-layer configs under the
    fused cost model (the DP's objective).

    The move space for layer *i* at batch *b* is
    ``table.configs_for(b, i)`` — the row's own registry-driven
    candidate set, so autotuned tables (``xla_fused``, Pallas tile
    variants, custom registrations) are climbed over their full
    variable-size spaces; nothing assumes the paper's fixed 8.

    ``start=None`` seeds each batch's climb from the paper's greedy
    per-layer argmin.  Sweeps layers repeatedly until a full sweep
    finds no improving move (or ``max_sweeps``), then returns
    ``(EfficientConfiguration, trajectory)`` for the best batch size,
    where ``trajectory`` is the accepted-total series (before -> after
    per accepted move).  The DP is exact for this objective, so the
    result is sandwiched: DP total <= hillclimb total <= start total
    (asserted in tests/test_adapt.py).
    """
    from repro.core.mapper import price_mapping

    batches = table.batch_sizes if batch is None else (batch,)
    best = None                      # (total, batch, mapping, trajectory)
    n_layers = len(table.layer_labels)
    for b in batches:
        if start is None:
            mapping = [
                min(
                    table.configs_for(b, i),
                    key=lambda c: table.times[b][i][c],
                )
                for i in range(n_layers)
            ]
        else:
            mapping = list(start)
        total = _fused_total(table, b, mapping)
        trajectory = [total]
        for _ in range(max_sweeps):
            improved = False
            for i in range(n_layers):
                for cand in table.configs_for(b, i):
                    if cand == mapping[i]:
                        continue
                    prev = mapping[i]
                    mapping[i] = cand
                    t = _fused_total(table, b, mapping)
                    if t < total:
                        total = t
                        trajectory.append(t)
                        improved = True
                    else:
                        mapping[i] = prev
            if not improved:
                break
        if best is None or total < best[0]:
            best = (total, b, tuple(mapping), trajectory)
    total, b, mapping, trajectory = best
    return price_mapping(table, b, mapping), trajectory


def run_bnn(outdir: Path):
    """Hillclimb a BNN mapping on an autotuned (registry-space) profile
    and log it against the exact DP on the same table."""
    import jax

    from repro.bnn import build_model
    from repro.bnn.models import pack_params
    from repro.core.mapper import map_efficient_configuration
    from repro.core.profiler import autotune_bnn_model

    m = build_model("fashion_mnist", scale=0.25)
    packed = pack_params(m.specs, m.init(jax.random.PRNGKey(0)))
    table = autotune_bnn_model(
        m, packed, batch_sizes=(1, 4, 16), time_source="analytic"
    )
    ec_hc, trajectory = bnn_mapping_hillclimb(table)
    ec_dp = map_efficient_configuration(table, policy="dp")
    space = sum(
        len(table.configs_for(ec_hc.proper_batch_size, i))
        for i in range(len(table.layer_labels))
    )
    print(f"\n=== bnn-mapping hillclimb: {m.name} (autotuned space) ===")
    print(f"  space: {space} summed per-layer candidates "
          f"(registry-driven, variable-size)")
    print(f"  start  {trajectory[0] * 1e6:9.2f} us/ex "
          f"(greedy argmin seed)")
    print(f"  climb  {ec_hc.expected_time_per_example * 1e6:9.2f} us/ex "
          f"@b{ec_hc.proper_batch_size} "
          f"({len(trajectory) - 1} accepted moves)")
    print(f"  dp     {ec_dp.expected_time_per_example * 1e6:9.2f} us/ex "
          f"@b{ec_dp.proper_batch_size} (exact)")
    fp = outdir / "bnn_mapping_hillclimb.json"
    fp.write_text(json.dumps({
        "model": m.name,
        "space": space,
        "trajectory_us": [t * 1e6 for t in trajectory],
        "hillclimb_us": ec_hc.expected_time_per_example * 1e6,
        "hillclimb_mapping": list(ec_hc.layer_configs),
        "dp_us": ec_dp.expected_time_per_example * 1e6,
        "dp_mapping": list(ec_dp.layer_configs),
    }, indent=2))
    print(f"  wrote {fp}")

from repro import configs as C
from repro.launch import hlo_analysis as H
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_BF16, build_lowered
from repro.launch.mesh import make_production_mesh
from repro.parallel.sharding import default_scheme

# The three hillclimb cells (see EXPERIMENTS.md §Perf for selection
# rationale) and their variant ladders. Each variant records the
# hypothesis it tests.
CELLS = {
    "qwen": {
        "arch": "qwen2_5_14b", "shape": "train_4k",
        "why": "worst collective/compute ratio (16x): 40 heads % 16 != 0",
        "variants": [
            ("baseline", {},
             "paper-faithful default: TP+ZeRO-1"),
            ("attn-dp", {"attn_tp": False},
             "H1: chunk-loop all-reduces come from uneven head sharding;"
             " replicating attention weights removes them"),
            ("attn-dp+accum4", {"attn_tp": False, "accum_steps": 4},
             "H2: peak memory is saved-residual dominated; 4 microbatches"
             " cut live activations ~4x at unchanged math"),
            ("accum4", {"accum_steps": 4},
             "H2 control: accum without the attention fix"),
            ("sp", {"sp_residual": True},
             "H3: sequence-parallel residuals shard the saved (B,S,d)"
             " carries 16x over 'model' — memory term down without the"
             " attn-dp compute blowup"),
            ("sp+accum2", {"sp_residual": True, "accum_steps": 2},
             "H4: SP + 2 microbatches fits HBM"),
            ("kvpar", {"attn_kv_parallel": True},
             "H5: keep head-TP projections but compute the attention"
             " inner with KV parts sharded over 'model' + logsumexp"
             " combine — only (B,H,qc,hd) all-reduces remain"),
            ("kvpar+accum4",
             {"attn_kv_parallel": True, "accum_steps": 4},
             "H6: H5 + microbatching = fits HBM at the lower"
             " collective point"),
            ("kvpar+accum8",
             {"attn_kv_parallel": True, "accum_steps": 8},
             "H7: 8 microbatches -> peak under the 16 GiB HBM line"),
        ],
    },
    "grok": {
        "arch": "grok_1_314b", "shape": "train_4k",
        "why": "most collective-bound cell overall; 314B MoE, ZeRO-3",
        "variants": [
            ("baseline", {},
             "paper-faithful default: TP+ZeRO-3, expert TP (8 experts"
             " % 16 != 0)"),
            ("accum8", {"accum_steps": 8},
             "H1: 162 GiB/dev peak is layer-residual dominated"
             " (64L x 16 local seqs); 8 microbatches -> ~1/8 residents"),
            ("accum8+attn-dp", {"accum_steps": 8, "attn_tp": False},
             "H2: 48H%16==0 so head sharding is clean — expect attn-dp"
             " to NOT help (control for H1 of the qwen cell)"),
            ("zero1+accum8", {"fsdp": "zero1", "accum_steps": 8},
             "H3: ZeRO-3 weight re-gathers per microbatch dominate"
             " collectives; ZeRO-1 trades +param memory for -gathers"
             " (expect OOM: params/16 = 39 GiB/dev — measure anyway)"),
            ("accum2", {"accum_steps": 2},
             "H4: regather cost scales with accum count — 2 microbatches"
             " should halve the memory win of accum8 but keep most of"
             " the collective budget"),
            ("sp+accum2", {"sp_residual": True, "accum_steps": 2},
             "H5: grok's 48H%16==0 heads shard cleanly, so SP residuals"
             " may not trigger qwen's resharding storm — residual memory"
             " /16 without accum's regather multiplication"),
            ("e-zero3", {"moe_e_over_data": True},
             "H6 (from HLO attribution): 720 GiB/layer-pass comes from"
             " wd's d@data making the BACKWARD contraction partial-sum;"
             " ZeRO-3 on the expert dim (8 over 16, padded) removes"
             " contraction sharding in both directions at 2x wd storage"),
            ("e-zero3+accum2", {"moe_e_over_data": True,
                                "accum_steps": 2},
             "H7: H6 + microbatching for the memory Pareto"),
        ],
    },
    "qwen-prefill": {
        "arch": "qwen2_5_14b", "shape": "prefill_32k",
        "why": "bonus 5th cell: most collective-bound cell in the whole"
               " table (2.2 TiB/dev) — the 40H/16 pathology at 32k ctx",
        "variants": [
            ("baseline", {},
             "paper-faithful default"),
            ("kvpar", {"attn_kv_parallel": True},
             "H1: same mechanism as the train cell — KV-part-sharded"
             " inner with logsumexp combine removes the per-chunk"
             " partial-sum all-reduces at 32k context too"),
        ],
    },
    "grok-decode": {
        "arch": "grok_1_314b", "shape": "decode_32k",
        "why": "bonus 4th cell: worst useful_ratio in the table (0.01) —"
               " ZeRO-3 weights are re-gathered for every decoded token",
        "variants": [
            ("baseline", {},
             "paper-faithful default: same scheme as training"),
            ("wstat", {"decode_replicate_batch": True},
             "H1: weight-stationary 2D-TP decode — replicate the ~MB"
             " per-token activations, never move the 632 GB of weights;"
             " predicted collective drop ~100x (weights dominate)"),
            ("wstat+ep", {"decode_replicate_batch": True,
                          "expert_mode": "ep"},
             "H2: with activations replicated, 8-expert EP (uneven over"
             " 16) may beat expert-TP for decode (each token hits only"
             " 2 experts)"),
            ("contr2d", {"out_proj_contracting_2d": True},
             "H3 (from HLO attribution): 440 GiB/step is wd all-gathered"
             " over 'data' per token; shard wd's CONTRACTING dim 2D ->"
             " partial-sum all-reduce of ~50 MB outputs instead;"
             " predicted coll 10.4s -> ~1.5s"),
        ],
    },
    "deepseek": {
        "arch": "deepseek_moe_16b", "shape": "train_4k",
        "why": "most representative of the paper's technique: the EP-vs-TP"
               " expert placement IS a layer-to-device mapping choice",
        "variants": [
            ("baseline", {},
             "paper-faithful default: expert-parallel (64e % 16 == 0)"),
            ("expert-tp", {"expert_mode": "tp"},
             "H1: EP all-to-alls vs TP all-reduces — fine-grained 1408-"
             "wide experts are too small for 16-way TP (88 cols/shard);"
             " expect EP to win (confirming 'auto')"),
            ("ep+accum4", {"accum_steps": 4},
             "H2: 34 GiB/dev peak -> fits HBM with microbatching"),
            ("ep+attn-dp+accum4", {"attn_tp": False, "accum_steps": 4},
             "H3: 16H/16 model axis = 1 head per chip — replicating"
             " attention may still cut resharding around GQA"),
        ],
    },
}


def evaluate(arch: str, shape: str, overrides: dict) -> dict:
    cfg = C.get(arch)
    mesh = make_production_mesh()
    scheme = dataclasses.replace(default_scheme(cfg), **overrides)
    compiled = build_lowered(cfg, shape, mesh, scheme).compile()
    txt = compiled.as_text()
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    coll = H.collective_bytes(txt, mesh.devices.size)
    flops = H.dot_flops(txt)
    bytes_ = H.hbm_bytes(txt)
    return {
        "compute_s": flops / PEAK_BF16,
        "memory_s": bytes_ / HBM_BW,
        "collective_s": coll.total_bytes / ICI_BW,
        "peak_gib": peak / 2**30,
        "coll_gib": coll.total_bytes / 2**30,
        "coll_by_kind_gib": {
            k: v / 2**30 for k, v in coll.bytes_by_kind.items()
        },
    }


def run_cell(key: str, outdir: Path):
    spec = CELLS[key]
    print(f"\n=== {key}: {spec['arch']} / {spec['shape']} ===")
    print(f"    ({spec['why']})")
    results = []
    for name, overrides, hyp in spec["variants"]:
        fp = outdir / f"{key}__{name}.json"
        if fp.exists():
            r = json.loads(fp.read_text())
        else:
            try:
                r = evaluate(spec["arch"], spec["shape"], overrides)
                r["variant"] = name
                r["hypothesis"] = hyp
                r["overrides"] = overrides
            except Exception as e:
                r = {"variant": name, "error": repr(e), "hypothesis": hyp}
            fp.write_text(json.dumps(r, indent=2, default=float))
        results.append(r)
        if "error" in r:
            print(f"  {name:22s} ERROR {r['error'][:60]}")
            continue
        step = max(r["compute_s"], r["memory_s"]) + r["collective_s"]
        print(
            f"  {name:22s} step~{step:7.2f}s  "
            f"cmp {r['compute_s']:6.2f}  mem {r['memory_s']:6.2f}  "
            f"coll {r['collective_s']:6.2f}  peak {r['peak_gib']:6.1f}GiB"
        )
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=tuple(CELLS) + ("all",),
                    default="all")
    ap.add_argument("--bnn", action="store_true",
                    help="hillclimb a BNN layer mapping over the "
                         "registry candidate space instead of the LM "
                         "scheme cells")
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    if args.bnn:
        run_bnn(outdir)
        return
    cells = tuple(CELLS) if args.cell == "all" else (args.cell,)
    for key in cells:
        run_cell(key, outdir)


if __name__ == "__main__":
    main()
