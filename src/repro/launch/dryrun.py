import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input
shape) cell on the production meshes and record memory / cost /
collective analyses for the roofline (EXPERIMENTS.md).

MUST set XLA_FLAGS before ANY other import (jax locks the device count
on first init) — hence the module's first two lines.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo_1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

Flop/byte totals use small-L twin compiles (L in {a, b}) and linear
extrapolation — exact for homogeneous layer stacks since
cost_analysis() counts scan bodies once (see hlo_analysis.py).
Collective bytes come from the FULL compile with exact while-trip
multiplication.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.models.config import ModelConfig
from repro.models.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.transformer import param_specs
from repro.optim import adamw
from repro.parallel.sharding import (
    ShardScheme,
    default_scheme,
    make_batch_shardings,
    make_opt_shardings,
    make_param_shardings,
)

# v5e constants (per chip) — EXPERIMENTS.md §Roofline
PEAK_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def build_lowered(
    cfg: ModelConfig, shape: str, mesh, scheme: ShardScheme | None = None,
):
    """Lower the cell's step function with shardings. Returns the
    jax.stages.Lowered."""
    from repro.parallel.constrain import scheme_context

    scheme = scheme or default_scheme(cfg)
    specs = C.input_specs(cfg, shape)
    kind = C.SHAPES[shape].kind
    ps_tree = param_specs(cfg)
    p_sh = make_param_shardings(cfg, mesh, ps_tree, scheme)

    with mesh, scheme_context(scheme):
        if kind == "train":
            opt = adamw(3e-4, state_dtype=jnp.bfloat16
                        if cfg.n_params() > 1e11 else jnp.float32)
            step = make_train_step(
                cfg, opt, grad_compression="bf16",
                accum_steps=scheme.accum_steps,
            )
            o_specs = jax.eval_shape(opt.init, ps_tree)
            o_sh = make_opt_shardings(cfg, mesh, ps_tree, scheme, "adamw")
            b_sh = make_batch_shardings(cfg, mesh, specs, scheme)
            fn = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            return fn.lower(ps_tree, o_specs, specs)

        if kind == "prefill":
            prefill = make_prefill_step(cfg)
            b_sh = make_batch_shardings(cfg, mesh, specs, scheme)
            args = [specs["tokens"]]
            shardings = [b_sh["tokens"]]
            if "frontend_embeds" in specs:
                args.append(specs["frontend_embeds"])
                shardings.append(b_sh["frontend_embeds"])
            # pin the returned KV cache's sharding (heads/head_dim over
            # 'model', batch over 'data') — otherwise XLA may leave the
            # (L,B,S,Hkv,hd) cache head-replicated (+8.6 GiB/dev on
            # olmo prefill_32k)
            from repro.parallel.sharding import make_cache_shardings

            _, cache_sds = jax.eval_shape(prefill, ps_tree, *args)
            c_sh = make_cache_shardings(
                cfg, mesh, cache_sds, scheme, allow_hd=False
            )
            fn = jax.jit(
                prefill,
                in_shardings=(p_sh, *shardings),
                out_shardings=(None, c_sh),
            )
            return fn.lower(ps_tree, *args)

        # decode
        serve = make_serve_step(cfg)
        b_sh = make_batch_shardings(cfg, mesh, specs, scheme)
        fn = jax.jit(
            serve,
            in_shardings=(p_sh, b_sh["cache"], b_sh["token"]),
            out_shardings=(None, b_sh["cache"]),
            donate_argnums=(1,),
        )
        return fn.lower(ps_tree, specs["cache"], specs["token"])


def run_cell(
    arch: str, shape: str, *, multi_pod: bool,
    scheme: ShardScheme | None = None, extrapolate: bool = True,
) -> dict:
    cfg = C.get(arch)
    if not C.cell_supported(cfg, shape):
        return {
            "arch": arch, "shape": shape, "multi_pod": multi_pod,
            "status": "skipped",
            "reason": "long_500k needs sub-quadratic attention "
                      "(full-attention arch; see docs/ARCHITECTURE.md §7)",
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh, scheme)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    txt = compiled.as_text()
    coll = H.collective_bytes(txt, n_dev)
    flops_pd = H.dot_flops(txt)
    bytes_pd = H.hbm_bytes(txt)

    out = {
        "arch": arch, "shape": shape,
        "multi_pod": multi_pod, "devices": n_dev,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (
                mem.argument_size_in_bytes
                + mem.temp_size_in_bytes
                + mem.output_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        "collectives": {
            "per_device_bytes": coll.total_bytes,
            "by_kind_bytes": coll.bytes_by_kind,
            "by_kind_count": coll.count_by_kind,
        },
        "whiles": H.while_summary(txt)[:12],
        "per_device": {
            "hlo_flops": flops_pd,   # dot flops, trip-corrected
            "hlo_bytes": bytes_pd,   # approx HBM traffic, trip-corrected
        },
    }
    return out


def roofline_terms(result: dict, cfg: ModelConfig, shape: str) -> dict:
    """The three §Roofline terms, in seconds (per step)."""
    pd = result.get("per_device", {})
    flops = pd.get("hlo_flops", 0.0)
    bytes_ = pd.get("hlo_bytes", 0.0)
    coll = result["collectives"]["per_device_bytes"]
    compute_s = flops / PEAK_BF16
    memory_s = bytes_ / HBM_BW
    collective_s = coll / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]
    sh = C.SHAPES[shape]
    n_tok = sh.batch * (sh.seq if sh.kind == "train" else
                        (sh.seq if sh.kind == "prefill" else 1))
    mult = 3 if sh.kind == "train" else 1  # fwd+bwd
    model_flops = 2 * mult * cfg.n_active_params() * n_tok
    denom = flops * result["devices"]
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / denom if denom else 0.0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("off", "on", "both"),
                    default="off")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-extrapolate", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = C.ARCH_NAMES if (args.all or not args.arch) else (
        C.canonical(args.arch),)
    shapes = tuple(C.SHAPES) if (args.all or not args.shape) else (
        args.shape,)
    pods = {"off": (False,), "on": (True,), "both": (False, True)}[
        args.multi_pod]
    for mp in pods:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    summary = []
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
        fp = outdir / f"{tag}.json"
        if fp.exists():
            r = json.loads(fp.read_text())
            print(f"[cached ] {tag}: {r['status']}")
            summary.append(r)
            continue
        print(f"[running] {tag} ...", flush=True)
        try:
            r = run_cell(arch, shape, multi_pod=mp,
                         extrapolate=not args.no_extrapolate)
            if r["status"] == "ok":
                cfg = C.get(arch)
                r["roofline"] = roofline_terms(r, cfg, shape)
                print(
                    f"    ok: compile {r['compile_s']}s, "
                    f"peak {r['memory']['peak_bytes_per_device']/2**30:.2f} "
                    f"GiB/dev, coll {r['collectives']['per_device_bytes']/2**30:.2f} "
                    f"GiB/dev, dominant={r['roofline']['dominant']}",
                    flush=True,
                )
            else:
                print(f"    {r['status']}: {r.get('reason','')}", flush=True)
        except Exception as e:  # record failures — they are bugs
            r = {
                "arch": arch, "shape": shape, "multi_pod": mp,
                "status": "error", "error": repr(e),
                "traceback": traceback.format_exc()[-2000:],
            }
            print(f"    ERROR: {e!r}", flush=True)
        fp.write_text(json.dumps(r, indent=2, default=float))
        summary.append(r)

    ok = sum(1 for r in summary if r["status"] == "ok")
    sk = sum(1 for r in summary if r["status"] == "skipped")
    er = sum(1 for r in summary if r["status"] == "error")
    print(f"\n=== dry-run: {ok} ok, {sk} skipped(by-design), {er} errors ===")
    (outdir / "summary.json").write_text(
        json.dumps(summary, indent=2, default=float)
    )
    return 0 if er == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
