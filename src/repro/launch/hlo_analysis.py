"""Compiled-HLO analysis for the roofline: exact collective-byte
accounting with while-loop trip-count multiplication.

``cost_analysis()`` counts each while body ONCE (verified empirically),
so naive sums undercount scanned layers by ~L. This module parses
``compiled.as_text()``:

  1. split into computation blocks,
  2. find ``while`` ops and read the exact trip count from the scalar
     integer constant in their condition computation,
  3. propagate execution multiplicity ENTRY -> bodies (nested whiles
     multiply),
  4. sum collective operand bytes x multiplicity x op-specific ring
     factors (all-reduce 2x, reduce-scatter gx on the scattered output,
     all-gather/all-to-all/collective-permute 1x).

All numbers are **per-device** (the partitioned HLO is the per-device
program); the roofline divides by per-chip link bandwidth.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_CONST_RE = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def parse_computations(hlo_text: str) -> dict:
    """name -> list of op lines."""
    comps: dict = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and line.strip():
            comps[cur].append(line)
    return comps


def _entry_name(comps: dict, hlo_text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)\s*\(", hlo_text, re.M)
    return m.group(1) if m else next(iter(comps))


def _trip_count(cond_lines: list) -> int:
    vals = []
    for line in cond_lines:
        vals += [int(v) for v in _CONST_RE.findall(line)]
    return max(vals) if vals else 1


def computation_multiplicity(hlo_text: str) -> tuple:
    """Returns (comps, mult) where mult[name] = times executed."""
    comps = parse_computations(hlo_text)
    entry = _entry_name(comps, hlo_text)
    # (parent, body, trip) edges
    edges = []
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                trip = _trip_count(comps.get(cond, []))
                edges.append((name, body, trip))
                edges.append((name, cond, trip + 1))
    mult = defaultdict(float)
    mult[entry] = 1.0
    # propagate to fixpoint (nesting depth is tiny)
    for _ in range(8):
        changed = False
        for parent, body, trip in edges:
            want = mult[parent] * trip
            if want > mult[body]:
                mult[body] = want
                changed = True
        if not changed:
            break
    return comps, dict(mult)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_SET_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Per-device collective traffic, trip-count-corrected."""
    comps, mult = computation_multiplicity(hlo_text)
    bytes_by = defaultdict(float)
    count_by = defaultdict(float)
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            # unreferenced (e.g. to_apply-only) computations execute as
            # part of their caller; skip standalone accounting
            continue
        for line in lines:
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            kind = cm.group(2)
            size = _shape_bytes(cm.group(1))
            g = _group_size(line, n_devices)
            if kind == "all-reduce":
                size *= 2.0 * (g - 1) / max(g, 1)
            elif kind == "reduce-scatter":
                size *= float(g - 1)
            elif kind in ("all-gather", "all-to-all"):
                size *= (g - 1) / max(g, 1)
            # collective-permute: 1x
            bytes_by[kind] += size * m
            count_by[kind] += m
    return CollectiveStats(dict(bytes_by), dict(count_by))


_LHS_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],]+)")
_OP_KIND_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],]+)(?:\{[^}]*\})?\s+([\w\-]+)\("
)
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "copy-start", "copy-done", "iota",
}


def _shape_dims(text: str) -> list:
    """All (dtype, dims tuple) in a type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        d = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dt, d))
    return out


def dot_flops(hlo_text: str) -> float:
    """Per-device matmul flops, trip-count-corrected: for every dot op,
    2 x output_elements x prod(lhs contracting dim sizes)."""
    comps, mult = computation_multiplicity(hlo_text)
    # symbol table: computation -> {op name -> shape dims of output}
    total = 0.0
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        table: dict = {}
        for line in lines:
            lm = _LHS_RE.match(line)
            if lm:
                shapes = _shape_dims(lm.group(2))
                table[lm.group(1)] = shapes[0] if shapes else None
        for line in lines:
            km = _OP_KIND_RE.search(line)
            if not km or km.group(1) != "dot":
                continue
            lm = _LHS_RE.match(line)
            if not lm:
                continue
            out_shapes = _shape_dims(lm.group(2))
            out_elems = 0
            for _, dims in out_shapes:
                n = 1
                for d in dims:
                    n *= d
                out_elems += n
            # first operand after "dot("
            args = line.split(" dot(", 1)[1]
            ops = _OPERANDS_RE.findall(args.split(")", 1)[0])
            k = 1
            dm = _DOT_DIMS_RE.search(line)
            if dm and ops:
                lhs = table.get(ops[0])
                if lhs:
                    _, ldims = lhs
                    for ci in dm.group(1).split(","):
                        if ci != "" and int(ci) < len(ldims):
                            k *= ldims[int(ci)]
            total += 2.0 * out_elems * k * m
    return total


def hbm_bytes(hlo_text: str) -> float:
    """Approximate per-device HBM traffic, trip-count-corrected.

    Accounting: 2 x (output bytes of every executed top-level op),
    i.e. each materialized tensor is written once and read ~once.
    Post-fusion HLO makes each top-level op a materialization boundary;
    dynamic-slice fusions count their *slice* (not the full stacked
    operand — operand-based accounting overcounted scanned stacked
    params by O(L) and was abandoned). Control/aliasing ops are free.
    Within ~2x of true traffic; used only as the roofline memory-term
    numerator."""
    comps, mult = computation_multiplicity(hlo_text)
    total = 0.0
    # computations called via fusion `calls=` execute inside the fusion
    # op — exclude them from top-level accounting
    called_by_fusion = set(re.findall(r"calls=%([\w\.\-]+)", hlo_text))
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or cname in called_by_fusion:
            continue
        for line in lines:
            km = _OP_KIND_RE.search(line)
            if not km or km.group(1) in _FREE_OPS:
                continue
            lm = _LHS_RE.match(line)
            if not lm:
                continue
            total += 2.0 * _shape_bytes(lm.group(2)) * m
    return total


def while_summary(hlo_text: str) -> list:
    comps, mult = computation_multiplicity(hlo_text)
    out = []
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                trip = _trip_count(comps.get(m.group(1), []))
                out.append({"in": name, "body": m.group(2), "trip": trip})
    return out
