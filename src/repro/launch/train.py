"""Training launcher: real training on the available devices (the
dry-run sibling proves the production-mesh distribution compiles; this
driver actually steps — on TPU pods it is the entry point, on this CPU
container it runs reduced configs).

    PYTHONPATH=src python -m repro.launch.train --arch olmo_1b \
        --steps 50 --batch 8 --seq 128 [--full]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.data import make_token_stream
from repro.models.steps import make_train_step
from repro.models.transformer import init_params
from repro.optim import adamw, linear_warmup_cosine
from repro.runtime import LoopConfig, TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (TPU-scale)")
    ap.add_argument("--ckpt", default="results/train_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    args = ap.parse_args()

    cfg = C.get(args.arch) if args.full else C.get_smoke(args.arch)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M "
          f"devices={jax.device_count()}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(linear_warmup_cosine(args.lr, 10, args.steps))
    raw = make_train_step(cfg, opt, accum_steps=args.accum)
    sample = make_token_stream(0, cfg.vocab)

    @jax.jit
    def step_fn(state, batch):
        p, o = state
        p, o, m = raw(p, o, batch)
        return (p, o), m

    def batch_fn(step):
        toks = sample(step, args.batch, args.seq)
        b = {"tokens": toks, "labels": toks}
        if cfg.n_frontend_embeds:
            b["frontend_embeds"] = jnp.zeros(
                (args.batch, cfg.n_frontend_embeds, cfg.d_model), cfg.dtype
            )
        return b

    loop = TrainLoop(
        step_fn, batch_fn, (params, opt.init(params)),
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                   save_every=args.save_every, async_save=True),
    )
    loop.restore_if_available()
    out = loop.run()
    last = out["metrics"][-1] if out["metrics"] else {}
    print(f"done at step {out['final_step']}; "
          f"final loss {last.get('loss', float('nan')):.4f}")


if __name__ == "__main__":
    main()
