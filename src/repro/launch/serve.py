"""Serving launcher: prefill a batch of prompts, then decode tokens
autoregressively with the KV/SSM cache (greedy).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2_130m \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.models.steps import greedy_decode
from repro.models.transformer import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = C.get(args.arch) if args.full else C.get_smoke(args.arch)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    t0 = time.perf_counter()
    toks = greedy_decode(
        cfg, params, prompt, n_steps=args.gen,
        max_len=args.prompt_len + args.gen,
    )
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    n = args.batch * args.gen
    print(f"generated {n} tokens in {dt:.2f}s "
          f"({n/dt:.1f} tok/s incl. compile)")
    print("sample:", jnp.asarray(toks[0, :12]).tolist())


if __name__ == "__main__":
    main()
