"""Deterministic synthetic data pipeline (this container is offline —
no Fashion-MNIST/CIFAR downloads; see docs/ARCHITECTURE.md §6). Streams are pure
functions of (seed, step) so training resumes exactly after restart."""

from repro.data.synthetic import (
    make_image_dataset,
    make_token_stream,
    ImageDataset,
)
from repro.data.loader import ShardedBatcher
