"""Shard-aware batcher with exact-resume semantics.

Batch indices are a pure function of (seed, step): after a restart at
step s the stream continues identically — required by the fault-
tolerance contract (see repro.runtime.loop).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ShardedBatcher:
    n: int                 # dataset size
    global_batch: int
    seed: int = 0
    shard_index: int = 0   # this host's shard of the global batch
    num_shards: int = 1

    def __post_init__(self):
        if self.global_batch % self.num_shards:
            raise ValueError("global_batch must divide evenly over shards")
        self.local_batch = self.global_batch // self.num_shards

    def indices(self, step: int) -> np.ndarray:
        """Global batch indices for `step`, then this host's slice."""
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, self.n, size=self.global_batch)
        lo = self.shard_index * self.local_batch
        return idx[lo : lo + self.local_batch]

    def batch(self, arrays, step: int):
        idx = self.indices(step)
        return tuple(a[idx] for a in arrays)
