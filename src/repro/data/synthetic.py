"""Synthetic datasets.

Images: class-conditional prototype + noise, thresholdable at 0.5 so a
BNN can learn them (stands in for Fashion-MNIST / CIFAR-10 offline).
Tokens: a k-gram Markov language over a given vocab so an LM's loss
decreases measurably within a few hundred steps.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ImageDataset:
    x: np.ndarray  # (N, H, W, C) float32 in [0,1]
    y: np.ndarray  # (N,) int32
    n_classes: int


def make_image_dataset(
    seed: int,
    n: int,
    hw: tuple,
    channels: int,
    n_classes: int = 10,
    noise: float = 0.35,
) -> ImageDataset:
    rng = np.random.default_rng(seed)
    h, w = hw
    protos = rng.random((n_classes, h, w, channels)).astype(np.float32)
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    eps = rng.normal(0.0, noise, size=(n, h, w, channels)).astype(np.float32)
    x = np.clip(protos[y] + eps, 0.0, 1.0)
    return ImageDataset(x=x, y=y, n_classes=n_classes)


def make_token_stream(
    seed: int, vocab: int, order: int = 2, temperature: float = 0.5
):
    """Returns sample(step, batch, seq) -> int32 tokens drawn from a fixed
    random k-gram process (pure function of (seed, step): resumable)."""
    base = jax.random.PRNGKey(seed)
    # hash-based transition: next ~ Cat(softmax(h(prev_k) / T))
    folds = jax.random.randint(
        jax.random.fold_in(base, 7), (order,), 1, 2**20
    )

    def sample(step: int, batch: int, seq: int) -> jax.Array:
        key = jax.random.fold_in(base, step)
        k0, key = jax.random.split(key)
        ctx = jax.random.randint(k0, (batch, order), 0, vocab)

        def body(carry, i):
            ctx, key = carry
            key, sub = jax.random.split(key)
            h = jnp.sum(ctx * folds, axis=-1)  # (batch,)
            logits_key = jax.vmap(
                lambda hh: jax.random.fold_in(jax.random.fold_in(base, 13), hh)
            )(h)
            logits = jax.vmap(
                lambda kk: jax.random.normal(kk, (vocab,))
            )(logits_key) / temperature
            nxt = jax.random.categorical(sub, logits)
            ctx = jnp.concatenate([ctx[:, 1:], nxt[:, None]], axis=1)
            return (ctx, key), nxt

        (_, _), toks = jax.lax.scan(
            body, (ctx, key), jnp.arange(seq)
        )
        return jnp.transpose(toks).astype(jnp.int32)  # (batch, seq)

    return sample
