"""The blessed path through the stack: profile → map → fuse → place
→ serve, behind one facade.

Seven PRs grew seven entrypoints (profiler sweeps, two mappers, the
fusion pass, engines, routers, the cluster tier), and every consumer —
examples, benchmarks, the cluster scheduler — re-wired the same chain
by hand.  This module is the single public API (docs/ARCHITECTURE.md
§13):

* **Canonical verb set** (re-exported, one name per verb)::

      profile_model    fixed-space per-layer sweep (paper §IV)
      autotune_model   registry-driven sweep with pruning
      map_model        single-model greedy/DP mapping
      map_fleet        contention-aware joint mapping
      map_all_device   DP restricted to device placements
      price_mapping    price an explicit per-layer mapping
      fuse_mapping     profile + select fused segment kernels

  The pre-facade spellings (``configuration_from_mapping``,
  ``fuse_configuration``, ``all_device_configuration``) remain
  importable from their home modules as deprecation shims that
  delegate here (one warning per call site).

* **Planning helpers** — :func:`plan_single` / :func:`plan_fleet`
  run the profile→map(→fuse) chain for one model or a co-served
  fleet, store-aware (zero profiling passes on a warm start).

* **:class:`Deployment`** — the one object consumers hold::

      dep = Deployment.plan({"a": (model_a, packed_a),
                             "b": (model_b, packed_b)},
                            hosts=2, batch_sizes=(4,), store=store)
      dep.serve()
      req = dep.submit(x, tenant="a")
      dep.step(); dep.drain()
      dep.stats()

  ``plan()`` picks the serving topology from its inputs: one model on
  one host serves through a :class:`~repro.serving.ServingEngine`;
  several models on one host through a
  :class:`~repro.fleet.FleetRouter` (+ ledger, optional per-tenant
  adaptive controllers); ``hosts > 1`` stands up the cluster tier
  (:mod:`repro.cluster`): tenant placement, per-host routers, a
  pluggable dispatch policy, and optionally an elastic host pool.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.mapper import (
    EfficientConfiguration,
    map_efficient_configuration,
    price_mapping,
)
from repro.core.plan import fuse_mapping
from repro.core.profiler import (
    ProfileTable,
    autotune_bnn_model,
    profile_bnn_model,
)
from repro.fleet.scheduler import FleetPlan, map_all_device, map_fleet

__all__ = [
    # the verb set: profile, map, price, fuse
    "profile_model",
    "autotune_model",
    "map_model",
    "map_fleet",
    "map_all_device",
    "price_mapping",
    "fuse_mapping",
    # planning + serving facade
    "plan_single",
    "plan_fleet",
    "Deployment",
    "TenantPlan",
    # the objects plans are made of
    "ProfileTable",
    "EfficientConfiguration",
    "FleetPlan",
]

# verb-set aliases: the implementations keep their paper-faithful
# homes; the facade fixes the public names
profile_model = profile_bnn_model
autotune_model = autotune_bnn_model
map_model = map_efficient_configuration


@dataclasses.dataclass
class TenantPlan:
    """One planned tenant: everything needed to build its engine.

    ``elastic`` (an :class:`~repro.elastic.ElasticPlan`, set by
    ``Deployment.plan(elastic=...)``) carries the tenant's planned
    nested-width subnet levels; its level 0 is this plan.
    ``quality_floor`` is the deepest subnet level the tenant may be
    degraded to (``None`` = the narrowest planned level; 0 pins full
    width)."""

    name: str
    model: object
    packed: list
    table: ProfileTable
    config: EfficientConfiguration
    weight: float = 1.0
    priority: int = 0
    deadline_s: float = math.inf
    elastic: object = None
    quality_floor: int | None = None

    @property
    def expected_s_per_example(self) -> float:
        return self.config.expected_time_per_example


def _as_store(store):
    """Normalize a ``store=`` argument: None passes through, a
    :class:`~repro.store.ProfileStore` is used as-is, and anything
    else — a root path, a ``dir://`` / ``sqlite://`` / ``mem://``
    backend URI, or a :class:`~repro.cachesvc.StoreBackend` — becomes
    a store over that backend.  This is how ``plan(store=...)``
    accepts cache-service URIs everywhere a store object worked."""
    if store is None:
        return None
    from repro.store import ProfileStore

    if isinstance(store, ProfileStore):
        return store
    return ProfileStore(store)


def _profile_fn(*, autotune, configs, repeats, time_source, registry):
    """The profiling callable plan_* hand to the store's
    ``get_or_profile`` (signature: model, packed, batch_sizes=...)."""
    if autotune:
        def fn(model, packed, *, batch_sizes):
            return autotune_model(
                model, packed, batch_sizes=batch_sizes,
                repeats=repeats, time_source=time_source,
                registry=registry,
            )
    else:
        def fn(model, packed, *, batch_sizes):
            kwargs = {} if configs is None else {"configs": configs}
            return profile_model(
                model, packed, batch_sizes=batch_sizes,
                repeats=repeats, time_source=time_source, **kwargs,
            )
    return fn


def plan_single(
    model,
    packed,
    *,
    batch_sizes: Sequence[int] = (1, 4, 16),
    store=None,
    policy: str = "dp",
    configs: Sequence[str] | None = None,
    autotune: bool = False,
    fuse: bool = False,
    repeats: int = 2,
    time_source: str = "measured",
    registry=None,
    name: str | None = None,
) -> TenantPlan:
    """Profile → map (→ fuse) one model; the single-tenant planning
    path every consumer shares.

    With a :class:`~repro.store.ProfileStore`, a stored profile is a
    warm start (zero profiling passes) and the resulting mapping is
    persisted back.  ``autotune=True`` sweeps the open registry space
    instead of the fixed 8; ``fuse=True`` additionally profiles
    segment-scope variants over the mapping's device segments and
    records the winners (:func:`fuse_mapping`)."""
    store = _as_store(store)
    profile = _profile_fn(
        autotune=autotune, configs=configs, repeats=repeats,
        time_source=time_source, registry=registry,
    )
    if store is not None:
        table, _ = store.get_or_profile(
            model, packed, profile, batch_sizes=batch_sizes
        )
    else:
        table = profile(model, packed, batch_sizes=batch_sizes)
    config = map_model(table, policy=policy, configs=configs)
    if fuse:
        config = fuse_mapping(
            model, packed, table, config,
            registry=registry, time_source=time_source, repeats=repeats,
        )
        if store is not None:
            store.save_profile(table)   # now carries the segment rows
    if store is not None:
        store.save_mapping(config)
    return TenantPlan(
        name=name or getattr(model, "name", table.model_name),
        model=model, packed=packed, table=table, config=config,
    )


def plan_fleet(
    models: dict,
    *,
    batch_sizes: Sequence[int] = (4,),
    store=None,
    policy: str = "dp",
    configs: Sequence[str] | None = None,
    autotune: bool = False,
    repeats: int = 2,
    time_source: str = "measured",
    registry=None,
    gamma: float = 1.0,
    law=None,
    weights: dict | None = None,
    shares=None,
) -> tuple:
    """Profile every tenant and jointly map the fleet under the
    contention model (:func:`map_fleet`).

    `models` is ``{name: (model, packed_params)}``; `weights` an
    optional ``{name: relative workload}``.  Returns ``(tenants,
    fleet_plan)`` where `tenants` is a name-keyed dict of
    :class:`TenantPlan` carrying each tenant's contention-priced
    configuration.  With a store, profiles warm-start and the joint
    mappings are persisted (callers co-serving should hand a
    fleet-scoped store — ``ProfileStore(root,
    scope=fleet_scope(names))`` — so joint mappings never leak into
    solo deployments)."""
    if not models:
        raise ValueError("plan_fleet needs at least one tenant")
    store = _as_store(store)
    names = tuple(models)
    profile = _profile_fn(
        autotune=autotune, configs=configs, repeats=repeats,
        time_source=time_source, registry=registry,
    )
    tables = []
    for name in names:
        model, packed = models[name]
        if store is not None:
            table, _ = store.get_or_profile(
                model, packed, profile, batch_sizes=batch_sizes
            )
        else:
            table = profile(model, packed, batch_sizes=batch_sizes)
        tables.append(table)
    weight_seq = (
        None if weights is None
        else tuple(float(weights.get(n, 1.0)) for n in names)
    )
    plan = map_fleet(
        tables, names=names, policy=policy, configs=configs,
        batch_sizes=tuple(batch_sizes), weights=weight_seq,
        shares=shares, gamma=gamma, law=law, registry=registry,
    )
    tenants = {}
    for name, table, tp in zip(names, tables, plan.tenants):
        model, packed = models[name]
        tenants[name] = TenantPlan(
            name=name, model=model, packed=packed, table=table,
            config=tp.config, weight=tp.weight,
        )
        if store is not None:
            store.save_mapping(tp.config)
    return tenants, plan


def _as_model_dict(models) -> dict:
    """Normalize ``plan()``'s `models` argument: a single ``(model,
    packed)`` pair or a ``{name: (model, packed)}`` dict."""
    if isinstance(models, dict):
        if not models:
            raise ValueError("models dict must not be empty")
        return dict(models)
    model, packed = models
    name = getattr(model, "name", "model")
    return {name: (model, packed)}


def _as_elastic_specs(elastic, names) -> dict:
    """Normalize ``plan()``'s `elastic` argument to {name:
    ElasticSpec}: ``None`` (no elastic tenants), one spec or fractions
    tuple (applied to every tenant), or a per-tenant dict of either."""
    if elastic is None:
        return {}
    from repro.elastic import ElasticSpec

    def as_spec(v):
        if isinstance(v, ElasticSpec):
            return v
        return ElasticSpec(fractions=tuple(v))

    if isinstance(elastic, dict):
        unknown = set(elastic) - set(names)
        if unknown:
            raise ValueError(
                f"elastic names {sorted(unknown)} match no tenant in "
                f"{sorted(names)}"
            )
        return {n: as_spec(v) for n, v in elastic.items()}
    spec = as_spec(elastic)
    return {n: spec for n in names}


class Deployment:
    """A planned (and, after :meth:`serve`, running) deployment —
    the one object the examples, benchmarks and cluster tier hold.

    Build via :meth:`plan`; every knob of the underlying chain
    (policy, configs, autotune, fuse, gamma/law, priorities,
    deadlines, hosts, routing) is a keyword here so no consumer needs
    the internals."""

    def __init__(self, *, tenants, fleet_plan=None, hosts=1, **knobs):
        self.tenants: dict = tenants            # name -> TenantPlan
        self.fleet_plan = fleet_plan            # FleetPlan | None
        self.hosts = int(hosts)
        self._knobs = knobs
        # serving state (populated by serve())
        self.engine = None                      # single-tenant mode
        self.router = None                      # fleet mode
        self.ledger = None
        self.controllers: dict = {}
        self.cluster = None                     # cluster mode
        self.cluster_plan = None

    # -- planning ----------------------------------------------------
    @classmethod
    def plan(
        cls,
        models,
        *,
        hosts: int = 1,
        store=None,
        batch_sizes: Sequence[int] = (4,),
        policy: str = "dp",
        configs: Sequence[str] | None = None,
        autotune: bool = False,
        fuse: bool = False,
        repeats: int = 2,
        time_source: str = "measured",
        registry=None,
        gamma: float = 1.0,
        law=None,
        weights: dict | None = None,
        priorities: dict | None = None,
        deadlines: dict | None = None,
        routing: str = "least_loaded",
        elastic=None,
        quality_floors: dict | None = None,
        estimate_levels: bool = False,
    ) -> "Deployment":
        """Plan `models` onto `hosts` simulated serving hosts.

        One model, one host → single-engine deployment (optionally
        ``fuse``\\ d).  Several models, one host → joint fleet mapping.
        ``hosts > 1`` → the cluster placement scheduler assigns
        tenants to hosts and each host plans its own fleet (the
        per-host mapping happens at :meth:`serve`, against the actual
        co-residents placement chose).

        ``elastic`` declares nested-width subnet families
        (``repro.elastic``): an ``ElasticSpec``, a fractions tuple
        like ``(1.0, 0.5, 0.25)``, or a per-tenant dict of either.
        Elastic tenants get every level planned (level-tagged store
        keys; level 0 is the tenant's own plan) and serve through an
        ``ElasticEngine``.  ``quality_floors`` is ``{name: deepest
        permitted level}``; ``estimate_levels=True`` prices narrow
        levels through the store's persisted latency predictor when
        one exists (zero extra profiling sweeps).  Note the distinct
        ``serve(elastic=...)`` knob, which configures the cluster
        host-pool controller."""
        if hosts < 1:
            raise ValueError("hosts must be >= 1")
        store = _as_store(store)
        model_dict = _as_model_dict(models)
        single = len(model_dict) == 1 and hosts == 1
        if single:
            ((name, (model, packed)),) = model_dict.items()
            tp = plan_single(
                model, packed, batch_sizes=batch_sizes, store=store,
                policy=policy, configs=configs, autotune=autotune,
                fuse=fuse, repeats=repeats, time_source=time_source,
                registry=registry, name=name,
            )
            tenants, fleet_plan = {tp.name: tp}, None
        elif hosts == 1:
            tenants, fleet_plan = plan_fleet(
                model_dict, batch_sizes=batch_sizes, store=store,
                policy=policy, configs=configs, autotune=autotune,
                repeats=repeats, time_source=time_source,
                registry=registry, gamma=gamma, law=law,
                weights=weights,
            )
        else:
            # cluster mode: profile every tenant now (store-aware);
            # placement + per-host joint mapping happen in serve()
            profile = _profile_fn(
                autotune=autotune, configs=configs, repeats=repeats,
                time_source=time_source, registry=registry,
            )
            tenants = {}
            for name, (model, packed) in model_dict.items():
                if store is not None:
                    table, _ = store.get_or_profile(
                        model, packed, profile, batch_sizes=batch_sizes
                    )
                else:
                    table = profile(model, packed, batch_sizes=batch_sizes)
                tenants[name] = TenantPlan(
                    name=name, model=model, packed=packed,
                    table=table,
                    config=map_model(
                        table, policy=policy, configs=configs
                    ),
                )
            fleet_plan = None
        for name, tp in tenants.items():
            tp.weight = float((weights or {}).get(name, tp.weight))
            tp.priority = int((priorities or {}).get(name, 0))
            tp.deadline_s = float((deadlines or {}).get(name, math.inf))
        elastic_specs = _as_elastic_specs(elastic, tuple(tenants))
        for name, spec in elastic_specs.items():
            from repro.elastic import SubnetFamily, plan_family

            tp = tenants[name]
            family = SubnetFamily.build(tp.model, tp.packed, spec)
            # base=tp: level 0 reuses this tenant's (solo or joint)
            # plan verbatim; narrow levels are planned under their
            # #L{k}-tagged store keys
            tp.elastic = plan_family(
                family, base=tp, store=store, policy=policy,
                configs=configs, autotune=autotune, repeats=repeats,
                time_source=time_source, registry=registry,
                estimate=estimate_levels,
            )
            if quality_floors and name in quality_floors:
                tp.quality_floor = int(quality_floors[name])
        return cls(
            tenants=tenants, fleet_plan=fleet_plan, hosts=hosts,
            store=store, policy=policy, configs=configs, gamma=gamma,
            law=law, registry=registry, routing=routing,
            batch_sizes=tuple(batch_sizes),
        )

    # -- serving -----------------------------------------------------
    @property
    def mode(self) -> str:
        if self.hosts > 1:
            return "cluster"
        return "single" if len(self.tenants) == 1 else "fleet"

    def configuration(self, name: str | None = None):
        """The planned :class:`EfficientConfiguration` for `name`
        (or the only tenant's when omitted)."""
        if name is None:
            if len(self.tenants) != 1:
                raise ValueError(
                    f"deployment has tenants {tuple(self.tenants)}; "
                    "name one"
                )
            (tp,) = self.tenants.values()
            return tp.config
        return self.tenants[name].config

    def serve(
        self,
        *,
        adapt: bool = False,
        telemetry_sample_every: int = 2,
        engine_factory=None,
        elastic=None,
        quality=None,
        clock=None,
        **engine_kwargs,
    ) -> "Deployment":
        """Stand up the serving tier for the planned topology and
        return self.

        ``adapt=True`` attaches per-tenant ``SegmentTelemetry`` + a
        ``RemapController`` (journaled drift-triggered remapping)
        in single/fleet modes.  ``engine_factory(tenant_plan, config,
        **kwargs)`` overrides engine construction (benchmarks inject
        contention-taxed engines).  ``elastic`` is a dict of
        :class:`repro.cluster.ElasticController` knobs (cluster mode
        only; ``None`` serves a fixed pool).  ``quality`` (fleet mode)
        attaches a :class:`~repro.fleet.QualityController` that
        degrades/restores elastic tenants' subnet width on shed
        pressure: ``True`` for defaults, a knob dict, or a built
        controller.  Extra ``engine_kwargs`` (e.g. ``max_wait_s``)
        reach every engine."""
        if quality is not None and self.mode != "fleet":
            raise ValueError(
                "quality= drives width adaptation off the fleet "
                "router's admission signal; in cluster mode attach "
                "the host-pool controller (serve(elastic=...)) — it "
                "prefers width degradation — and in single mode call "
                "engine.set_level() directly"
            )
        if self.mode == "cluster":
            from repro.cluster import Cluster, make_policy

            self.cluster = Cluster(
                tuple(self.tenants.values()),
                n_hosts=self.hosts,
                gamma=self._knobs.get("gamma", 1.0),
                law=self._knobs.get("law"),
                configs=self._knobs.get("configs"),
                batch_sizes=self._knobs.get("batch_sizes"),
                registry=self._knobs.get("registry"),
                policy=make_policy(self._knobs.get("routing",
                                                   "least_loaded")),
                engine_factory=engine_factory,
                elastic=elastic,
                store=self._knobs.get("store"),
                **({} if clock is None else {"clock": clock}),
                engine_kwargs=engine_kwargs,
            )
            self.cluster_plan = self.cluster.plan
            return self

        if self.mode == "fleet":
            from repro.fleet import DeviceTimeLedger, FleetRouter

            self.ledger = DeviceTimeLedger()
            self.router = FleetRouter(
                ledger=self.ledger, quality=self._as_quality(quality)
            )
        for name, tp in self.tenants.items():
            observer = (
                self.ledger.observer(name) if self.ledger is not None
                else None
            )
            telemetry = None
            if adapt:
                from repro.adapt import SegmentTelemetry

                telemetry = SegmentTelemetry(
                    sample_every=telemetry_sample_every, tenant=name
                )
            engine = self._build_engine(
                tp, engine_factory, telemetry=telemetry,
                observer=observer, **engine_kwargs,
            )
            controller = None
            if adapt:
                from repro.adapt import RemapController

                controller = RemapController(
                    engine, tp.table, store=self._knobs.get("store"),
                    tenant=name,
                )
                self.controllers[name] = controller
            if self.mode == "single":
                self.engine = engine
            else:
                self.router.add_tenant(
                    name, engine, priority=tp.priority,
                    deadline_s=tp.deadline_s, controller=controller,
                )
        return self

    @staticmethod
    def _as_quality(quality):
        if quality is None or quality is False:
            return None
        from repro.fleet import QualityController

        if isinstance(quality, QualityController):
            return quality
        if quality is True:
            return QualityController()
        return QualityController(**quality)

    @staticmethod
    def _build_engine(tp: TenantPlan, factory, **kwargs):
        kwargs.setdefault("allowed_batch_sizes", tp.table.batch_sizes)
        if factory is not None:
            return factory(tp, tp.config, **kwargs)
        if tp.elastic is not None:
            from repro.elastic import ElasticEngine

            return ElasticEngine(
                tp.elastic, quality_floor=tp.quality_floor, **kwargs
            )
        from repro.serving import ServingEngine

        return ServingEngine(tp.model, tp.packed, tp.config, **kwargs)

    def _serving(self):
        target = self.engine or self.router or self.cluster
        if target is None:
            raise RuntimeError(
                "deployment is planned but not serving; call serve()"
            )
        return target

    def submit(self, x, *, tenant: str | None = None, key=None):
        """Enqueue one example.  `tenant` is required except in
        single-tenant mode; `key` is the affinity key consistent-hash
        cluster routing uses (ignored elsewhere)."""
        target = self._serving()
        if self.engine is not None:
            return self.engine.submit(x)
        if tenant is None:
            raise ValueError("tenant= is required for multi-tenant "
                             "deployments")
        if self.router is not None:
            return self.router.submit(tenant, x)
        return target.submit(tenant, x, key=key)

    def step(self, *, force: bool = False):
        return self._serving().step(force=force)

    def drain(self, **kwargs):
        target = self._serving()
        if self.engine is not None:
            served = 0
            while self.engine.batcher.pending():
                served += self.engine.step(force=True)
            return served
        return target.drain(**kwargs)

    def stats(self) -> dict:
        """One nested dict for the whole deployment — per-tenant
        admission/served counters, ledger occupancy where metered,
        and per-host pool state in cluster mode."""
        if self.cluster is not None:
            return self.cluster.stats()
        if self.router is not None:
            out = {"mode": "fleet", "tenants": self.router.stats()}
            if self.ledger is not None:
                out["ledger"] = self.ledger.snapshot()
            if self.router.quality is not None:
                out["quality"] = [
                    dataclasses.asdict(r)
                    for r in self.router.quality.journal
                ]
            return out
        e = self._serving()
        out = {
            "mode": "single",
            "served": e.served,
            "steps": e.steps,
            "swaps": e.swaps,
        }
        if hasattr(e, "set_level"):
            out.update(
                level=e.level,
                quality_floor=e.quality_floor,
                level_switches=e.level_switches,
                degraded_share=e.degraded_share,
            )
        return out
