"""Sharding plans: per-parameter PartitionSpecs from path-based rules
with divisibility guards, plus batch/cache/optimizer shardings.

Mesh-axis conventions (launch/mesh.py):
  single-pod: ('data', 'model')  = (16, 16)
  multi-pod : ('pod', 'data', 'model') = (2, 16, 16)

  'model' — tensor/expert parallelism (Megatron TP, MoE EP, KV heads)
  'data'  — data parallelism within a pod; optimizer-state sharding
            (ZeRO-1) and, for very large models, parameter sharding
            (ZeRO-3)
  'pod'   — pure data parallelism across pods (gradients all-reduce
            over the slower inter-pod links; params replicated per pod)

Hard-won GSPMD rules encoded here (EXPERIMENTS.md §Perf, iteration 0):
  * NEVER shard a weight's contracting dim over 'data' — GSPMD emits
    activation-sized partial-sum all-reduces per layer (~600 GiB/dev
    per step on olmo-1b when we tried).
  * NEVER vocab-shard an embedding table used by a gather — GSPMD
    falls back to "involuntary full rematerialization" (replicates the
    table per device, per step). Untied tables shard d_model instead;
    tied tables belong to <2B models and are replicated.
  * ZeRO-1 is expressed by sharding ONLY the optimizer moments over
    ('model','data') composite dims; the weight-update all-gather XLA
    then inserts is exactly the ZeRO-1 gather.

The :class:`ShardScheme` knobs are the HEP-Shard search space — each
knob is a per-layer-class 'device mapping' decision in the paper's
sense, selected by profiled (dry-run) cost rather than folklore.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardScheme:
    tp: bool = True                  # tensor parallelism over 'model'
    fsdp: str = "zero1"              # 'none' | 'zero1' | 'zero3'
    expert_mode: str = "auto"        # 'ep' | 'tp' | 'none' | 'auto'
    batch_over_model: bool = False   # fold 'model' into the batch axes
    seq_over_model: bool = False     # shard activation seq dim (prefill)
    # TP on attention projections; False replicates them (the fix for
    # head counts indivisible by the model axis, e.g. qwen2.5's 40H/16
    # — GSPMD otherwise partial-sums every attention chunk)
    attn_tp: bool = True
    # gradient-accumulation microbatches (memory knob, not a sharding)
    accum_steps: int = 1
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sharded over 'model' on the seq dim (saved-for-backward
    # residuals /16; per-layer all-gather before projections)
    sp_residual: bool = False
    # context-parallel attention inner: KV chunks sharded over 'model'
    # with log-sum-exp combine (the fix for head counts indivisible by
    # the model axis; see modules.chunked_attention_kv_parallel)
    attn_kv_parallel: bool = False
    # weight-stationary decode: replicate the (tiny) per-token
    # activations instead of batch-sharding them, so 2D-sharded weights
    # are never re-gathered per token (the fix for ZeRO-3 serving of
    # very large models; moves ~KB activations instead of GB weights)
    decode_replicate_batch: bool = False
    # out-projections (wo/wd/out_proj) sharded 2D on their CONTRACTING
    # dim: right for decode (partial-sum all-reduce of tiny per-token
    # outputs instead of per-token weight gathers), wrong for training
    # (activation-sized partial sums) — the workload-dependent layout
    # flip that HEP-Shard searches over
    out_proj_contracting_2d: bool = False
    # TP-mode MoE: put the ZeRO-3 data shard on the EXPERT dim (uneven
    # when E < data size — GSPMD pads) instead of on d/Fe; neither
    # matmul direction then contracts over a data-sharded dim
    moe_e_over_data: bool = False

    def resolve_expert_mode(self, cfg: ModelConfig, model_size: int) -> str:
        if self.expert_mode != "auto":
            return self.expert_mode
        if cfg.moe and cfg.moe.n_experts % model_size == 0:
            return "ep"
        return "tp"


def default_scheme(cfg: ModelConfig) -> ShardScheme:
    """Size-adaptive defaults — the LM analogue of the paper's 'small
    layers stay on CPU' finding:
      < 2B params : pure data parallelism (TP of a small model over 16
                    chips is all dispatch/collective overhead)
      2B - 20B    : Megatron TP + ZeRO-1
      > 20B       : TP + ZeRO-3 (params cannot be replicated per data
                    group at this scale)
    """
    n = cfg.n_params()
    if n < 2e9:
        return ShardScheme(tp=False, fsdp="zero1", batch_over_model=True)
    if n > 2e10:
        return ShardScheme(tp=True, fsdp="zero3")
    return ShardScheme(tp=True, fsdp="zero1")


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _guard(axis: Optional[str], dim: int, sizes: dict) -> Optional[str]:
    """Use `axis` for a dim only if the dim divides evenly."""
    if axis is None:
        return None
    return axis if _div(dim, sizes[axis]) else None


def batch_axes(mesh: Mesh, scheme: ShardScheme, batch: int):
    """Axes used for the batch dimension of activations: the first
    candidate subset (preference-ordered, largest first) whose device
    product divides the batch. Considering ('data','model') before
    ('pod','data') matters on the multi-pod mesh: global_batch 256 on
    512 chips can still engage the model axis 256-wide with pod-level
    replication (2x waste) instead of idling 'model' (16x waste)."""
    sizes = _axis_sizes(mesh)
    have = [a for a in ("pod", "data", "model") if a in sizes]
    if scheme.batch_over_model:
        prefs = [
            ("pod", "data", "model"), ("data", "model"), ("pod", "data"),
            ("data",), (),
        ]
    else:
        prefs = [("pod", "data"), ("data",), ()]
    for cand in prefs:
        axes = tuple(a for a in cand if a in have)
        if tuple(sorted(axes)) != tuple(sorted(set(axes))):
            continue
        total = int(np.prod([sizes[a] for a in axes])) if axes else 1
        if axes and batch % total == 0:
            return axes
        if not axes:
            return ()
    return ()


# ---------------------------------------------------------------------------
# Parameter shardings
# ---------------------------------------------------------------------------

_REPLICATED = {
    "ln1", "ln2", "final_norm", "gnorm",
    "conv_x_b", "conv_bc_b", "A_log", "D", "dt_bias", "router",
}
# (.., contracting_d, out) -> (None, out@model[,data if zero3])
_IN_PROJ = {"wq", "wk", "wv", "wg", "wu", "in_z", "in_x", "in_bc", "in_dt"}
# (.., in@model, out_d@data-if-zero3)
_OUT_PROJ = {"wo", "wd", "out_proj"}
_BIAS_TP = {"bq", "bk", "bv"}
_ATTN_NAMES = {"wq", "wk", "wv", "wo", "bq", "bk", "bv"}


def _tp_dim(dim: int, sizes: dict, scheme: ShardScheme, *,
            force_zero3: bool = False):
    """Sharding for a weight's output/TP dim. fsdp ('data') is folded
    into the same dim — never a contracting dim — when zero3."""
    m = sizes.get("model", 1)
    d = sizes.get("data", 1)
    zero3 = force_zero3 or scheme.fsdp == "zero3"
    tp_ok = scheme.tp and dim % m == 0
    if tp_ok and zero3 and dim % (m * d) == 0:
        return ("model", "data")
    if tp_ok:
        return "model"
    if zero3 and dim % d == 0:
        return "data"
    return None


def _param_spec(path, shape, cfg, scheme, sizes, emode, *,
                force_zero3: bool = False) -> P:
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    in_moe = any(getattr(p, "key", None) == "moe" for p in path)
    tp = "model" if scheme.tp else None
    rank = len(shape)

    def lead(spec_tail: tuple) -> P:
        """Pad with None for the stacked-layer leading dims."""
        pad = rank - len(spec_tail)
        return P(*((None,) * pad + spec_tail))

    def tp_dim(dim):
        return _tp_dim(dim, sizes, scheme, force_zero3=force_zero3)

    if name in _REPLICATED and not in_moe:
        return P()
    if name == "router":
        return P()
    if name in _ATTN_NAMES and not scheme.attn_tp:
        # replicated attention: ZeRO-3 still shards over 'data' only
        if (force_zero3 or scheme.fsdp == "zero3") and len(shape) >= 2:
            d_ax = _guard("data", shape[-1], sizes)
            return lead((None, d_ax)) if len(shape) >= 2 else P()
        return P()
    if name == "embed":
        if cfg.tie_embeddings:
            # tied tables belong to <2B archs; replicate (gather from a
            # vocab-sharded table makes GSPMD replicate it anyway)
            return P()
        return P(None, tp_dim(shape[1]))
    if name == "lm_head":
        return P(None, tp_dim(shape[1]))
    zero3 = force_zero3 or scheme.fsdp == "zero3"
    data_out = "data" if zero3 else None

    def contracting(dim):
        """2D contracting-dim spec for decode-style out-projections."""
        return _tp_dim(dim, sizes, scheme, force_zero3=zero3)

    if in_moe and name in ("wg", "wu", "wd"):
        e, a, b = shape[-3], shape[-2], shape[-1]
        if emode == "ep":
            ex = _guard(tp, e, sizes)
            if name == "wd" and scheme.out_proj_contracting_2d:
                return lead((ex, _guard(data_out, a, sizes), None))
            return lead((ex, None, _guard(data_out, b, sizes)))
        if emode == "tp":
            e_ax = (
                "data" if (scheme.moe_e_over_data and zero3) else None
            )
            if name == "wd":   # (E, Fe, d)
                if scheme.out_proj_contracting_2d:
                    return lead((None, contracting(a), None))
                if e_ax:
                    return lead((e_ax, _guard(tp, a, sizes), None))
                return lead((None, _guard(tp, a, sizes),
                             _guard(data_out, b, sizes)))
            if e_ax:           # wg/wu (E@data, d, Fe@model)
                return lead((e_ax, None, _guard(tp, b, sizes)))
            return lead((None, None, tp_dim(b)))
        return lead((None, None, _guard(data_out, b, sizes)))
    if name in _IN_PROJ:
        return lead((None, tp_dim(shape[-1])))
    if name in _OUT_PROJ:
        if scheme.out_proj_contracting_2d:
            return lead((contracting(shape[-2]), None))
        return lead((_guard(tp, shape[-2], sizes),
                     _guard(data_out, shape[-1], sizes)))
    if name in _BIAS_TP:
        return lead((_guard(tp, shape[-1], sizes),))
    if name in ("conv_x_w", "conv_bc_w"):   # (L, K, C)
        return lead((None, _guard(tp, shape[-1], sizes)))
    return P()


def make_param_shardings(
    cfg: ModelConfig, mesh: Mesh, params_tree: Any,
    scheme: Optional[ShardScheme] = None, *, force_zero3: bool = False,
) -> Any:
    """params_tree: pytree of arrays or ShapeDtypeStructs.
    force_zero3 is used for optimizer-moment trees (ZeRO-1)."""
    scheme = scheme or default_scheme(cfg)
    sizes = _axis_sizes(mesh)
    emode = scheme.resolve_expert_mode(cfg, sizes["model"])

    def one(path, leaf):
        spec = _param_spec(
            path, leaf.shape, cfg, scheme, sizes, emode,
            force_zero3=force_zero3,
        )
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def make_opt_shardings(
    cfg: ModelConfig, mesh: Mesh, params_tree: Any,
    scheme: Optional[ShardScheme] = None, kind: str = "adamw",
) -> Any:
    """ZeRO-1: optimizer moments shard over ('model','data') composite
    dims even when params are only TP-sharded; XLA inserts the weight-
    update all-gather. Scalars replicated."""
    from repro.optim.optimizers import OptState

    moment_sh = make_param_shardings(
        cfg, mesh, params_tree, scheme, force_zero3=True
    )
    scalar = NamedSharding(mesh, P())
    if kind == "adamw":
        inner = {"m": moment_sh, "v": moment_sh}
    elif kind in ("sgd", "lion"):
        inner = moment_sh
    else:
        raise ValueError(kind)
    return OptState(step=scalar, inner=inner)


# ---------------------------------------------------------------------------
# Activation / batch / cache shardings
# ---------------------------------------------------------------------------


def make_batch_shardings(
    cfg: ModelConfig, mesh: Mesh, specs: dict,
    scheme: Optional[ShardScheme] = None,
) -> dict:
    """Shardings for train/prefill input dicts (tokens/labels/
    frontend_embeds): batch dim over the data axes, seq replicated
    (or over 'model' when scheme.seq_over_model)."""
    scheme = scheme or default_scheme(cfg)
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = make_cache_shardings(cfg, mesh, v, scheme)
            continue
        if k == "token" and scheme.decode_replicate_batch:
            out[k] = NamedSharding(mesh, P())
            continue
        b = v.shape[0]
        baxes = batch_axes(mesh, scheme, b)
        spec = [baxes if baxes else None] + [None] * (len(v.shape) - 1)
        if scheme.seq_over_model and len(v.shape) >= 2:
            sizes = _axis_sizes(mesh)
            if _div(v.shape[1], sizes["model"]):
                spec[1] = "model"
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def make_cache_shardings(
    cfg: ModelConfig, mesh: Mesh, cache_tree: dict,
    scheme: Optional[ShardScheme] = None, *, allow_hd: bool = True,
) -> dict:
    """Decode-cache shardings.

    k/v (L, B, S, Hkv, hd): batch over data; heads over 'model' when
    divisible, else head_dim over 'model' (partial-sum attention — the
    universal fallback for kv-head counts < the model axis; decode
    only — pass allow_hd=False for prefill outputs, where hd@model
    would back-propagate into the chunked softmax as per-block
    all-reduces).
    ssd (L, B, H, P, N): batch over data; H over model else P.
    conv_* (L, B, K, C): batch over data; C over model when divisible.
    """
    scheme = scheme or default_scheme(cfg)
    sizes = _axis_sizes(mesh)
    # caches always use 'model' even when weights are not TP-sharded
    # (scheme.tp=False): decode memory is cache-dominated, and leaving
    # the model axis idle replicates the cache 16x (musicgen decode_32k
    # measured 262 GiB/dev before this rule)
    tp = "model"
    out = {}
    for kname, leaf in cache_tree.items():
        if kname == "len":
            out[kname] = NamedSharding(mesh, P())
            continue
        sh = leaf.shape
        b_ax = batch_axes(mesh, dataclasses.replace(
            scheme, batch_over_model=False), sh[1])
        if kname in ("k", "v"):
            h_ax = _guard(tp, sh[3], sizes)
            d_ax = (
                _guard(tp, sh[4], sizes)
                if (h_ax is None and allow_hd) else None
            )
            s_ax = None
            if not b_ax:
                # unbatchable (B=1, long-context): shard the sequence
                # dim over the idle data axes (sequence-parallel KV)
                cand = tuple(a for a in ("pod", "data") if a in sizes)
                tot = int(np.prod([sizes[a] for a in cand])) if cand else 0
                if cand and sh[2] % tot == 0:
                    s_ax = cand
            elif h_ax is None and d_ax is None:
                # kv-heads indivisible by the model axis and hd-sharding
                # disallowed (prefill): sequence-shard the cache so it
                # is not replicated 16x over 'model'
                s_ax = _guard("model", sh[2], sizes)
            spec = P(None, b_ax if b_ax else None, s_ax, h_ax, d_ax)
        elif kname == "ssd":
            h_ax = _guard(tp, sh[2], sizes)
            p_ax = _guard(tp, sh[3], sizes) if h_ax is None else None
            spec = P(None, b_ax if b_ax else None, h_ax, p_ax, None)
        elif kname in ("conv_x", "conv_bc"):
            spec = P(None, b_ax if b_ax else None, None,
                     _guard(tp, sh[3], sizes))
        else:
            spec = P()
        out[kname] = NamedSharding(mesh, spec)
    return out
