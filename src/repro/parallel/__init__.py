"""Distribution: mesh-axis conventions, sharding plans, schemes."""

from repro.parallel.sharding import (
    ShardScheme,
    default_scheme,
    make_param_shardings,
    make_batch_shardings,
    make_cache_shardings,
    make_opt_shardings,
)
