"""Mesh-aware sharding constraints usable from model code.

``constrain(x, *axes)`` applies ``with_sharding_constraint`` against
the ambient mesh when one is active, filtering spec entries down to
axis names the mesh actually has; with no mesh (unit tests, single
device) it is the identity. This lets model internals (e.g. the MoE
dispatch buffer) pin the intended sharding without plumbing a mesh
handle through every call.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# set by the launcher while lowering: lets model-internal pins follow
# the ShardScheme's policy without plumbing it through every call
_BATCH_OVER_MODEL = contextvars.ContextVar("batch_over_model",
                                           default=False)
_SP_RESIDUAL = contextvars.ContextVar("sp_residual", default=False)
_ATTN_KV_PARALLEL = contextvars.ContextVar("attn_kv_parallel",
                                           default=False)
_DECODE_REPLICATE = contextvars.ContextVar("decode_replicate_batch",
                                           default=False)


@contextlib.contextmanager
def batch_over_model(enabled: bool):
    tok = _BATCH_OVER_MODEL.set(enabled)
    try:
        yield
    finally:
        _BATCH_OVER_MODEL.reset(tok)


@contextlib.contextmanager
def scheme_context(scheme):
    """Expose the ShardScheme's model-internal knobs while lowering."""
    t1 = _BATCH_OVER_MODEL.set(getattr(scheme, "batch_over_model", False))
    t2 = _SP_RESIDUAL.set(getattr(scheme, "sp_residual", False))
    t3 = _ATTN_KV_PARALLEL.set(getattr(scheme, "attn_kv_parallel", False))
    t4 = _DECODE_REPLICATE.set(
        getattr(scheme, "decode_replicate_batch", False)
    )
    try:
        yield
    finally:
        _BATCH_OVER_MODEL.reset(t1)
        _SP_RESIDUAL.reset(t2)
        _ATTN_KV_PARALLEL.reset(t3)
        _DECODE_REPLICATE.reset(t4)


def sp_residual_enabled() -> bool:
    return _SP_RESIDUAL.get()


def attn_kv_parallel_enabled() -> bool:
    return _ATTN_KV_PARALLEL.get()


def pick_batch_axes(dim: int, sizes: dict) -> tuple:
    """Largest preference-ordered axis subset whose product divides
    `dim` (mirrors sharding.batch_axes; ('data','model') outranks
    ('pod','data') so a 256-batch on 512 chips engages 256-way)."""
    if _DECODE_REPLICATE.get():
        return ()
    if _BATCH_OVER_MODEL.get():
        prefs = [("pod", "data", "model"), ("data", "model"),
                 ("pod", "data"), ("data",)]
    else:
        prefs = [("pod", "data"), ("data",)]
    for cand in prefs:
        axes = tuple(a for a in cand if a in sizes)
        total = 1
        for a in axes:
            total *= sizes[a]
        if axes and dim % total == 0:
            return axes
    return ()


def pin_batch(x: jax.Array, *rest):
    """Constrain dim 0 as a batch dim (policy-aware), dims 1.. by
    `rest` (padded with None)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = pick_batch_axes(x.shape[0], sizes)
    spec = [axes if axes else None] + list(rest)
    spec += [None] * (x.ndim - len(spec))
    return constrain(x, *spec)


def _ambient_mesh():
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover
        return None


def constrain(x: jax.Array, *spec):
    """spec entries: None, an axis name, or a tuple of axis names.
    Unknown axis names are dropped (e.g. 'pod' on a single-pod mesh)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def filt(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    cleaned = [filt(e) for e in spec]
    return _apply(x, cleaned, mesh)


def _apply(x, cleaned, mesh):
    # guard divisibility per entry: drop only the offending entry
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    final = []
    for dim, entry in zip(x.shape, cleaned):
        if entry is None:
            final.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes[a]
        final.append(entry if dim % total == 0 else None)
    if all(e is None for e in final):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*final))
    )


def constrain_kv(x: jax.Array) -> jax.Array:
    """Cache-copy sharding for a (B,S,Hkv,hd) tensor: batch over data
    axes; kv-heads over 'model' when divisible, else head_dim over
    'model'. Applied to the COPY bound for the cache, never to the
    value the attention math consumes — constraining the compute path
    makes GSPMD emit partial-softmax all-reduces per chunk per layer
    (musicgen prefill measured 17 TiB/dev before this split)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    h, d = x.shape[-2], x.shape[-1]
    # heads-only: a heads@model constraint propagates benignly into the
    # attention (heads are a parallel dim); an hd@model constraint is a
    # CONTRACTION dim and makes GSPMD compute partial-sum all-reduces
    # per score block (measured 9.2 TiB/dev on musicgen prefill).
    h_ax = "model" if h % m == 0 else None
    if x.ndim != 4 or h_ax is None:
        return x
    return constrain(x, ("pod", "data"), None, h_ax, None)


def constrain_ssd(x: jax.Array) -> jax.Array:
    """(B,H,P,N) SSD state: batch over data; heads over model when
    divisible, else head_dim P."""
    mesh = _ambient_mesh()
    if mesh is None or x.ndim != 4:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    h, p = x.shape[1], x.shape[2]
    h_ax = "model" if h % m == 0 else None
    p_ax = "model" if (h_ax is None and p % m == 0) else None
    return constrain(x, ("pod", "data"), h_ax, p_ax, None)
