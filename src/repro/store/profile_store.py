"""Persistent profile/mapping store — profile once, adapt forever.

The paper's pipeline re-profiles every platform from scratch on every
run.  :class:`ProfileStore` makes the expensive artifacts — the
:class:`~repro.core.profiler.ProfileTable` a sweep produced and the
:class:`~repro.core.mapper.EfficientConfiguration` the mapper chose —
first-class, persisted, *keyed* documents, so a serving process warm
starts: load the stored mapping, serve immediately, and let the
adaptive runtime (``repro.adapt``) correct it online.  The
``RemapController`` writes its remapped *configurations* back, so the
next process warm-starts from the adapted mapping.  Corrected tables
are deliberately **not** persisted: they encode observed — possibly
transient — conditions, and a placement the remap abandoned can never
be re-observed to recover, so the factory profile on disk stays
authoritative (one contention episode must not poison warm starts
forever).

**Key.**  An artifact is valid only for the platform, model, batch
sizes and kernel space it was measured under, so entries are keyed by

* ``hardware_fingerprint()`` — host platform/processor/core-count plus
  the JAX backend and device kind (a profile from machine A must never
  warm-start machine B);
* ``model_signature(model)`` — model name + the per-layer labels the
  profiler emits (a resized or re-architected model re-profiles);
* the profiled ``batch_sizes`` (profiles) / serving batch (mappings);
* ``registry_hash()`` — the kernel-variant registry's names and
  pricing metadata (registering a new variant invalidates nothing, it
  just keys new entries; *changing* a variant's semantics re-keys);
* optionally a **scope** — a namespace for artifacts that are only
  valid under a particular co-tenancy: a fleet's jointly-mapped
  configurations (``repro.fleet``) are optimal only against that
  fleet's co-runners, so they live under ``fleet_scope(names)`` and a
  solo warm start can never pick one up (nor vice versa).  Scope-less
  entries stay where previous versions wrote them.

**Backends.**  The store reads and writes through a pluggable
:class:`~repro.cachesvc.backends.StoreBackend` (``root`` accepts a
path, a ``dir://`` / ``sqlite://`` / ``mem://`` URI, or a backend
instance — see ``repro.cachesvc``).  The entry *key* is the relative
POSIX path of the layout below, identical across backends, so the
default dir backend is bit-compatible with stores written before the
backend layer existed.  Serving-path loads go through
``backend.get`` — the hit/miss/access counters they feed are the
cache service's prewarm popularity signal; maintenance reads
(``entries``/``gc``/``export``) use counter-silent peeks.

**Layout.**  ``root/v<schema>/<fingerprint>/<model>-r<registry>/`` with
one JSON document per artifact (``profile-b<sizes>.json``,
``mapping-<policy>-b<batch>.json``), each wrapped in a versioned
envelope (schema, kind, saved_at, full key) around the payload's own
versioned JSON (``ProfileTable.to_json`` /
``EfficientConfiguration.to_json``).  Loaders verify the envelope key
before trusting a payload; unknown newer schemas are refused, not
misread.  ``tools/profile_store.py`` gives ``inspect`` / ``stats`` /
``gc`` / ``export`` over the same layout on any backend.

**Training rows.**  Every profile run additionally appends estimator
training rows (``repro.estimator.features``) under
``training-r<registry>/rows-*.json`` — same envelope, additive kind
``training_rows`` — so :class:`~repro.estimator.LatencyPredictor`
accumulates cross-model, cross-run data per (fingerprint, registry,
scope) key (:meth:`ProfileStore.predictor` /
``tools/profile_store.py fit``).  A *fitted* predictor and a
calibrated interference law can be persisted beside the rows
(:meth:`save_predictor` / :meth:`save_interference`) so the cache
service's ``refit`` worker re-trains only when enough new rows
accumulated since the last fit.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform
import time
from pathlib import Path
from typing import Callable, Sequence

from repro.cachesvc.backends import parse_backend
from repro.core.mapper import EfficientConfiguration
from repro.core.profiler import ProfileTable

SCHEMA_VERSION = 1


def _digest(parts) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(repr(p).encode())
        h.update(b"\x00")
    return h.hexdigest()[:12]


def hardware_fingerprint() -> str:
    """Short stable hash of the serving platform: host CPU identity and
    core count plus the JAX backend and device kind.  Deliberately
    excludes load/clock state — that is what telemetry tracks."""
    import jax

    dev = jax.devices()[0]
    return _digest(
        (
            platform.system(),
            platform.machine(),
            platform.processor(),
            os.cpu_count(),
            jax.default_backend(),
            getattr(dev, "device_kind", type(dev).__name__),
        )
    )


def model_signature(model) -> str:
    """Hash of the model's name + per-layer labels — exactly the labels
    a ProfileTable for it carries, so table and model key identically."""
    labels = tuple(f"L{s.idx}:{s.notation}" for s in model.specs)
    return signature_from_labels(model.name, labels)


def signature_from_labels(model_name: str, layer_labels) -> str:
    return _digest((model_name,) + tuple(layer_labels))


def registry_hash(registry=None) -> str:
    """Hash of the kernel-variant space: every registered name with its
    scope, placement and pricing metadata, order-independent.  The
    scope is part of the row, so a registry with segment-scope (fused)
    variants keys different entries than a per-layer-only one — fused
    and per-layer stores never cross-contaminate."""
    if registry is None:
        from repro.kernels.registry import DEFAULT_REGISTRY

        registry = DEFAULT_REGISTRY
    rows = sorted(
        (
            v.name,
            getattr(v, "scope", "layer"),
            v.placement,
            tuple(v.aspects),
            v.p_blk,
            v.n_blk,
            v.analytic,
        )
        for v in registry
    )
    return _digest(rows)


def _batch_key(batch_sizes: Sequence[int]) -> str:
    # canonicalized: (4, 1) and (1, 4) are the same profiled set
    return "x".join(str(int(b)) for b in sorted(batch_sizes))


def fleet_scope(tenant_names: Sequence[str]) -> str:
    """The store scope for a fleet's artifacts, canonicalized over the
    tenant composition (order-insensitive, duplicates collapse): the
    same models co-served in any order share warm starts, a different
    mix re-keys — a mapping jointly optimized against one set of
    co-runners must never warm-start another."""
    names = sorted(set(tenant_names))
    if not names:
        raise ValueError("fleet_scope needs at least one tenant name")
    return "fleet-" + _digest(names)


@dataclasses.dataclass(frozen=True)
class StoreEntry:
    """One stored artifact, as ``inspect`` reports it.  ``store_key``
    is the backend key (the relative path on a dir backend); ``path``
    is where that key lives — real on a dir backend, synthesized under
    the display root elsewhere."""

    path: Path
    kind: str
    schema: int
    saved_at: float
    key: dict
    size_bytes: int
    store_key: str = ""

    @property
    def age_s(self) -> float:
        return max(0.0, time.time() - self.saved_at)


class ProfileStore:
    def __init__(
        self,
        root,
        *,
        fingerprint: str | None = None,
        registry=None,
        scope: str | None = None,
    ):
        """``root`` is a directory path (today's layout), a backend URI
        (``dir://`` / ``sqlite://`` / ``mem://``), or a
        :class:`~repro.cachesvc.backends.StoreBackend` instance —
        handles constructed over the same backend share one cache.

        ``scope`` namespaces every artifact this handle reads or
        writes (module docstring): a scoped store neither sees
        scope-less entries nor leaks into them — fleets pass
        :func:`fleet_scope` so per-co-tenancy mappings and solo
        mappings of the same model coexist under one root."""
        if scope is not None and (
            not scope or any(c in scope for c in "/\\\0")
        ):
            raise ValueError(
                "scope must be a non-empty path-component-safe string"
            )
        self.backend = parse_backend(root)
        base = self.backend.path_for("")
        if base is not None:
            self.root = base
        else:
            # display root only — non-dir backends have no real files,
            # but entries()/export() still report per-key paths under it
            self.root = Path(
                str(getattr(self.backend, "path", "") or self.backend.uri())
            )
        self.scope = scope
        self._fingerprint = fingerprint
        self._registry = registry
        self._registry_hash: str | None = None

    def with_scope(self, scope: str | None) -> "ProfileStore":
        """A handle over the *same backend* (shared counters, shared
        cache) under a different scope — how the cluster tier reads a
        fleet's jointly-mapped artifacts from the shared store."""
        return ProfileStore(
            self.backend,
            fingerprint=self._fingerprint,
            registry=self._registry,
            scope=scope,
        )

    def stats(self) -> dict:
        """The backend's counters (hits/misses/puts/evictions)."""
        return self.backend.stats()

    # -- keys --------------------------------------------------------
    @property
    def fingerprint(self) -> str:
        if self._fingerprint is None:
            self._fingerprint = hardware_fingerprint()
        return self._fingerprint

    @property
    def space_hash(self) -> str:
        if self._registry_hash is None:
            self._registry_hash = registry_hash(self._registry)
        return self._registry_hash

    def _base_key(self) -> str:
        parts = [f"v{SCHEMA_VERSION}", self.fingerprint]
        if self.scope is not None:
            parts.append(f"s-{self.scope}")
        return "/".join(parts)

    def _dir_key(self, model_sig: str) -> str:
        return f"{self._base_key()}/{model_sig}-r{self.space_hash}"

    def profile_key(self, model_sig: str, batch_sizes) -> str:
        return (
            f"{self._dir_key(model_sig)}"
            f"/profile-b{_batch_key(batch_sizes)}.json"
        )

    def mapping_key(self, model_sig: str, policy: str, batch: int) -> str:
        return (
            f"{self._dir_key(model_sig)}/mapping-{policy}-b{int(batch)}.json"
        )

    def _path_of(self, key: str) -> Path:
        p = self.backend.path_for(key)
        return p if p is not None else self.root / key

    def _dir(self, model_sig: str) -> Path:
        return self._path_of(self._dir_key(model_sig))

    def profile_path(self, model_sig: str, batch_sizes) -> Path:
        return self._path_of(self.profile_key(model_sig, batch_sizes))

    def mapping_path(self, model_sig: str, policy: str, batch: int) -> Path:
        return self._path_of(self.mapping_key(model_sig, policy, batch))

    # -- envelope ----------------------------------------------------
    def _envelope(self, kind: str, key: dict, payload: dict) -> str:
        return json.dumps(
            {
                "schema": SCHEMA_VERSION,
                "kind": kind,
                "saved_at": time.time(),
                "key": {
                    "fingerprint": self.fingerprint,
                    "registry": self.space_hash,
                    **({"scope": self.scope}
                       if self.scope is not None else {}),
                    **key,
                },
                "payload": payload,
            },
            indent=2,
        )

    def _open(self, store_key: str, kind: str) -> dict | None:
        """Read + verify an envelope; None when absent or keyed for a
        different platform/registry (never served cross-key).  Goes
        through ``backend.get`` so serving-path loads feed the cache
        counters (the prewarm popularity signal)."""
        text = self.backend.get(store_key)
        if text is None:
            return None
        doc = json.loads(text)
        if doc.get("schema", 0) > SCHEMA_VERSION:
            raise ValueError(
                f"{store_key}: store schema {doc.get('schema')} is newer "
                f"than supported ({SCHEMA_VERSION}); upgrade the loader"
            )
        if doc.get("kind") != kind:
            return None
        key = doc.get("key", {})
        if key.get("fingerprint") != self.fingerprint:
            return None
        if key.get("registry") != self.space_hash:
            return None
        # symmetric scope check: a scoped handle refuses scope-less
        # entries and vice versa (key.get returns None for both sides)
        if key.get("scope") != self.scope:
            return None
        return doc

    def _put(self, store_key: str, doc: str) -> Path:
        self.backend.put(store_key, doc)
        return self._path_of(store_key)

    # -- profiles ----------------------------------------------------
    def save_profile(self, table: ProfileTable) -> Path:
        sig = signature_from_labels(table.model_name, table.layer_labels)
        spans = sorted(
            {
                span
                for rows in (table.segment_times or {}).values()
                for span in rows
            }
        )
        doc = self._envelope(
            "profile_table",
            {
                "model": sig,
                "model_name": table.model_name,
                "batch_sizes": list(table.batch_sizes),
                # spans with fused segment-variant rows (informational,
                # for `inspect` — () on per-layer-only tables)
                "segment_spans": spans,
            },
            json.loads(table.to_json()),
        )
        return self._put(self.profile_key(sig, table.batch_sizes), doc)

    def load_profile(
        self, model, batch_sizes: Sequence[int]
    ) -> ProfileTable | None:
        sig = model_signature(model)
        doc = self._open(
            self.profile_key(sig, batch_sizes), "profile_table"
        )
        if doc is None:
            return None
        return ProfileTable.from_json(json.dumps(doc["payload"]))

    def get_or_profile(
        self,
        model,
        packed_params,
        profile_fn: Callable,
        *,
        batch_sizes: Sequence[int],
    ) -> tuple:
        """(table, loaded): the stored profile when one matches the
        key, else ``profile_fn(model, packed_params,
        batch_sizes=batch_sizes)`` — run, saved, and returned.  The
        warm-start contract: a hit performs **zero** profiling."""
        table = self.load_profile(model, batch_sizes)
        if table is not None:
            return table, True
        table = profile_fn(model, packed_params, batch_sizes=batch_sizes)
        self.save_profile(table)
        self._record_training_rows(model, table)
        return table, False

    # -- estimator training data -------------------------------------
    def _training_key(self) -> str:
        return f"{self._base_key()}/training-r{self.space_hash}"

    def training_dir(self) -> Path:
        """Training rows live beside the per-model dirs, keyed by the
        same (fingerprint, registry, scope) — rows measured under one
        kernel space or platform never train a predictor for
        another."""
        return self._path_of(self._training_key())

    def _record_training_rows(self, model, table) -> None:
        """Every real profile run feeds the estimator's training set —
        best-effort: extraction failure must never fail the profiling
        path that produced the table."""
        try:
            from repro.estimator.features import training_rows_from_table

            rows = training_rows_from_table(
                model, table, registry=self._registry
            )
            if rows:
                # keyed by signature + batch sweep, not model name:
                # width variants of one family share a name, and each
                # sweep's rows must accumulate, not overwrite
                sig = signature_from_labels(
                    table.model_name, table.layer_labels
                )
                self.save_training_rows(
                    rows,
                    source=(
                        f"profile:{sig}"
                        f"-b{_batch_key(table.batch_sizes)}"
                    ),
                )
        except Exception:
            pass

    def save_training_rows(self, rows, *, source: str | None = None) -> Path:
        """Persist one batch of estimator training rows
        (``repro.estimator.features.training_rows_from_table``) as a
        keyed envelope.  One document per (models, batches) source;
        re-profiling the same sweep overwrites rather than
        duplicates."""
        rows = list(rows)
        if not rows:
            raise ValueError("no training rows to save")
        models = sorted({r.get("model", "?") for r in rows})
        if source is None:
            source = _digest(
                sorted(
                    (r.get("model", "?"), r.get("batch", 0))
                    for r in rows
                )
            )
        doc = self._envelope(
            "training_rows",
            {
                "source": source,
                "models": models,
                "n_rows": len(rows),
            },
            {"rows": rows},
        )
        return self._put(
            f"{self._training_key()}/rows-{_digest([source])}.json", doc
        )

    def load_training_rows(self) -> list:
        """Every training row stored under this handle's key, across
        all saved batches — the estimator's training set."""
        rows: list = []
        prefix = self._training_key() + "/"
        for store_key in self.backend.list(prefix):
            name = store_key[len(prefix):]
            if not (name.startswith("rows-") and name.endswith(".json")):
                continue
            doc = self._open(store_key, "training_rows")
            if doc is None:
                continue
            rows.extend(doc["payload"].get("rows", ()))
        return rows

    def predictor(self, **kwargs):
        """A :class:`~repro.estimator.LatencyPredictor` fitted on the
        accumulated training rows, or ``None`` when the store has no
        rows yet — callers fall back to a real profiling pass (and
        thereby create the first rows)."""
        from repro.estimator.latency import LatencyPredictor

        rows = self.load_training_rows()
        if not rows:
            return None
        return LatencyPredictor(**kwargs).fit(rows)

    # -- fitted estimator artifacts (cachesvc refit worker) ----------
    def _predictor_key(self) -> str:
        return f"{self._training_key()}/latency-predictor.json"

    def save_predictor(self, predictor, *, source_rows: int) -> Path:
        """Persist a *fitted* predictor with the training-set size it
        was fitted on, so the refit worker can tell when enough new
        rows accumulated to justify retraining."""
        doc = self._envelope(
            "latency_predictor",
            {
                "n_rows": int(getattr(predictor, "n_rows", 0)),
                "source_rows": int(source_rows),
            },
            json.loads(predictor.to_json()),
        )
        return self._put(self._predictor_key(), doc)

    def load_predictor(self):
        """The persisted fitted predictor, or None."""
        from repro.estimator.latency import LatencyPredictor

        doc = self._open(self._predictor_key(), "latency_predictor")
        if doc is None:
            return None
        return LatencyPredictor.from_json(json.dumps(doc["payload"]))

    def predictor_meta(self) -> dict | None:
        """{'n_rows', 'source_rows', 'saved_at'} of the persisted
        predictor (counter-silent), or None when never fitted."""
        text = self.backend.peek(self._predictor_key())
        if text is None:
            return None
        doc = json.loads(text)
        if doc.get("kind") != "latency_predictor":
            return None
        key = doc.get("key", {})
        return {
            "n_rows": int(key.get("n_rows", 0)),
            "source_rows": int(key.get("source_rows", 0)),
            "saved_at": float(doc.get("saved_at", 0.0)),
        }

    def _interference_key(self) -> str:
        return f"{self._training_key()}/interference-law.json"

    def save_interference(self, law) -> Path:
        """Persist a calibrated contention law
        (:class:`~repro.estimator.interference.FittedInterference`)."""
        doc = self._envelope(
            "interference_law",
            {"n_obs": int(getattr(law, "n_obs", 0))},
            json.loads(law.to_json()),
        )
        return self._put(self._interference_key(), doc)

    def load_interference(self):
        """The persisted contention law, or None."""
        from repro.estimator.interference import FittedInterference

        doc = self._open(self._interference_key(), "interference_law")
        if doc is None:
            return None
        return FittedInterference.from_json(json.dumps(doc["payload"]))

    # -- mappings ----------------------------------------------------
    def save_mapping(self, config: EfficientConfiguration) -> Path:
        sig = signature_from_labels(config.model_name, config.layer_labels)
        fused = getattr(config, "fused_segments", ())
        doc = self._envelope(
            "efficient_configuration",
            {
                "model": sig,
                "model_name": config.model_name,
                "batch": config.proper_batch_size,
                "policy": config.policy,
                # surfaced (not verified) so `inspect` can tell fused
                # and per-layer mappings apart without parsing payloads
                "fused_variants": sorted(
                    {name for _, _, name, _ in fused}
                ),
            },
            json.loads(config.to_json()),
        )
        return self._put(
            self.mapping_key(
                sig, config.policy, config.proper_batch_size
            ),
            doc,
        )

    def load_mapping(
        self, model, *, policy: str = "dp", batch: int | None = None
    ) -> EfficientConfiguration | None:
        """The stored mapping for (platform, model, registry) —
        at `batch` when given, else the most recently saved one for
        `policy`."""
        return self.load_mapping_for_labels(
            model_signature(model), policy=policy, batch=batch
        )

    def load_mapping_for_labels(
        self,
        model_sig: str,
        *,
        policy: str = "dp",
        batch: int | None = None,
    ) -> EfficientConfiguration | None:
        """:meth:`load_mapping` by precomputed signature
        (:func:`signature_from_labels`) — for callers that hold a
        table/configuration but no model object (the cluster tier's
        warm start)."""
        sig = model_sig
        if batch is not None:
            keys = [self.mapping_key(sig, policy, batch)]
        else:
            prefix = self._dir_key(sig) + "/"
            stem = f"mapping-{policy}-b"
            keys = [
                k for k in self.backend.list(prefix)
                if k[len(prefix):].startswith(stem)
                and k.endswith(".json")
            ]
        best = None
        for store_key in keys:
            doc = self._open(store_key, "efficient_configuration")
            if doc is None:
                continue
            if best is None or doc.get("saved_at", 0.0) > best.get(
                "saved_at", 0.0
            ):
                best = doc
        if best is None:
            return None
        return EfficientConfiguration.from_json(
            json.dumps(best["payload"])
        )

    def warm_start(
        self,
        model,
        *,
        batch_sizes: Sequence[int],
        policy: str = "dp",
    ) -> tuple | None:
        """(table, config) for an immediate serve with no profiling
        pass, or None when this platform has no stored profile.  A
        missing mapping is re-derived from the stored table (cheap —
        the sweep, not the solve, is what the store amortizes)."""
        from repro.core.mapper import map_efficient_configuration

        table = self.load_profile(model, batch_sizes)
        if table is None:
            return None
        config = self.load_mapping(model, policy=policy)
        if (
            config is None
            or config.layer_labels != table.layer_labels
            # a mapping remapped/saved at a batch this sweep never
            # profiled cannot be served against this table
            or config.proper_batch_size not in table.batch_sizes
        ):
            config = map_efficient_configuration(table, policy=policy)
            self.save_mapping(config)
        return table, config

    # -- maintenance (tools/profile_store.py) ------------------------
    def entries(self) -> list:
        """Every parseable artifact in the backend, newest first —
        including other schemas/fingerprints (inspect sees all).
        Counter-silent: maintenance must not skew popularity."""
        out = []
        for store_key in self.backend.list():
            text = self.backend.peek(store_key)
            if text is None:
                continue
            try:
                doc = json.loads(text)
            except json.JSONDecodeError:
                continue
            if not isinstance(doc, dict) or "kind" not in doc:
                continue
            out.append(
                StoreEntry(
                    path=self._path_of(store_key),
                    kind=doc.get("kind", "?"),
                    schema=int(doc.get("schema", 0)),
                    saved_at=float(doc.get("saved_at", 0.0)),
                    key=doc.get("key", {}),
                    size_bytes=len(text.encode()),
                    store_key=store_key,
                )
            )
        out.sort(key=lambda e: e.saved_at, reverse=True)
        return out

    def gc(
        self, *, max_age_s: float | None = None, dry_run: bool = False
    ) -> list:
        """Remove stale artifacts: anything from an older store schema,
        plus (when ``max_age_s`` is set) current-schema entries older
        than that.  Returns the removed paths; empty directories are
        pruned (dir backends)."""
        removed = []
        for entry in self.entries():
            stale = entry.schema < SCHEMA_VERSION or (
                max_age_s is not None and entry.age_s > max_age_s
            )
            if not stale:
                continue
            removed.append(entry.path)
            if not dry_run:
                self.backend.delete(entry.store_key)
        if not dry_run:
            prune = getattr(self.backend, "prune_empty_dirs", None)
            if prune is not None:
                prune()
        return removed

    def export(self) -> dict:
        """One self-contained bundle of every artifact (portable
        backup; re-import by writing the files back)."""
        return {
            "schema": SCHEMA_VERSION,
            "kind": "profile_store_export",
            "exported_at": time.time(),
            "entries": [
                {
                    "path": e.store_key,
                    "document": json.loads(
                        self.backend.peek(e.store_key)
                    ),
                }
                for e in self.entries()
            ],
        }
