"""Persistent profile/mapping store (``ProfileStore``): ProfileTables
and EfficientConfigurations persisted to disk keyed by (hardware
fingerprint, model signature, batch sizes, registry hash, optional
co-tenancy scope), with versioned JSON envelopes, warm start, and
gc/inspect/export tooling (``tools/profile_store.py``).  See
docs/ARCHITECTURE.md §9 (and §10 for fleet-scoped keys).
"""

from repro.store.profile_store import (
    ProfileStore,
    StoreEntry,
    fleet_scope,
    hardware_fingerprint,
    model_signature,
    registry_hash,
    signature_from_labels,
)
