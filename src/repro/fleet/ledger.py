"""Device-time ledger: who actually occupied which processor.

The joint mapper's interference model needs each tenant's *share* of
host and device time; predictions (``placement_shares`` of the served
configuration) are only as good as the profile they came from.
:class:`DeviceTimeLedger` meters the real thing: every tenant's
engine feeds it one observation per (step, segment) through the
engine's always-on ``observer`` hook, and the ledger accumulates
per-tenant host/device occupancy over a bounded window of recent
steps.

Two consumers:

* :func:`repro.fleet.scheduler.map_fleet` — re-plans against
  *measured* co-runner shares (``shares()`` / ``co_runner_share()``)
  instead of the demand model, so a tenant whose traffic died down
  stops inflating everyone else's placements;
* per-tenant drift detection — the ledger's per-tenant totals make
  "who is being slowed by whom" auditable (``snapshot()`` rides in
  journal records and bench output).

Metering truth has a cost: an engine observer forces the pipelined
driver to sync device segments for wall times (see
``repro.serving.pipeline``).  Fleet dispatch is batch-at-a-time
through the router, where that sync is already on the completion
path; latency-critical single-tenant serving should sample instead
(``SegmentTelemetry``).

Thread-safety: ``record`` and the read methods take an internal lock,
so engines stepped from different threads may share one ledger.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque

from repro.core.mapper import DEVICE, HOST


@dataclasses.dataclass(frozen=True)
class TenantUsage:
    """One tenant's metered occupancy over the retained window."""

    tenant: str
    host_s: float
    device_s: float
    steps: int

    @property
    def total_s(self) -> float:
        return self.host_s + self.device_s

    def share(self, placement: str) -> float:
        """Fraction of this tenant's own busy time spent on
        `placement` — the measured analogue of
        ``EfficientConfiguration.placement_shares``."""
        if self.total_s <= 0.0:
            return 0.0
        s = self.host_s if placement == HOST else self.device_s
        return s / self.total_s


class DeviceTimeLedger:
    """Per-tenant host/device occupancy metering over a sliding
    window of engine steps."""

    def __init__(self, *, window: int = 64):
        if window <= 0:
            raise ValueError("window must be positive")
        self.window = window
        self._lock = threading.Lock()
        # tenant -> deque of (host_s, device_s) per completed step
        self._steps: dict[str, deque] = {}
        # tenant -> [host_s, device_s] accumulating the current step
        self._open: dict[str, list] = {}

    # -- engine-facing ----------------------------------------------
    def observer(self, tenant: str):
        """The always-on segment observer for `tenant`'s engine
        (``ServingEngine(observer=ledger.observer(name))``): each
        (segment, wall seconds) lands in the tenant's open step."""

        def on_segment(seg_index, segment, seconds, batch):
            del seg_index, batch
            self.record(tenant, segment.placement, seconds)

        return on_segment

    def record(self, tenant: str, placement: str, seconds: float) -> None:
        with self._lock:
            acc = self._open.setdefault(tenant, [0.0, 0.0])
            acc[0 if placement == HOST else 1] += max(0.0, seconds)

    def close_step(self, tenant: str) -> None:
        """Fold `tenant`'s open accumulation into its window — call
        once per engine step (the router does, after each dispatch).
        A step with no observations closes to nothing."""
        with self._lock:
            acc = self._open.pop(tenant, None)
            if acc is None:
                return
            steps = self._steps.setdefault(
                tenant, deque(maxlen=self.window)
            )
            steps.append((acc[0], acc[1]))

    # -- consumer-facing --------------------------------------------
    def tenants(self) -> tuple:
        with self._lock:
            return tuple(sorted(set(self._steps) | set(self._open)))

    def usage(self, tenant: str) -> TenantUsage:
        with self._lock:
            rows = list(self._steps.get(tenant, ()))
            open_acc = self._open.get(tenant)
            # snapshot the open step while still holding the lock — a
            # concurrent record() mutates the same list, and a torn
            # (host_s, device_s) pair would feed inconsistent shares
            # into the planner
            if open_acc is not None:
                rows.append(tuple(open_acc))
        return TenantUsage(
            tenant=tenant,
            host_s=sum(r[0] for r in rows),
            device_s=sum(r[1] for r in rows),
            steps=len(rows),
        )

    def step_rows(self, tenant: str) -> tuple:
        """The retained **closed** (host_s, device_s) step pairs for
        `tenant`, oldest first — the raw per-step occupancy
        :class:`repro.estimator.InterferenceFit` consumes when
        calibrating the contention law.  The open step is excluded:
        a partially-accumulated pair would read as a spurious
        speedup."""
        with self._lock:
            return tuple(self._steps.get(tenant, ()))

    def shares(self) -> dict:
        """{tenant: (host_share, device_share)} over the retained
        window — each tenant's measured demand profile."""
        return {
            t: (u.share(HOST), u.share(DEVICE))
            for t in self.tenants()
            for u in (self.usage(t),)
        }

    def co_runner_share(self, tenant: str, placement: str) -> float:
        """Sum of *other* tenants' shares on `placement` — the input
        to :func:`repro.core.cost_model.contention_inflation` when
        planning `tenant`'s next mapping from measured occupancy."""
        return sum(
            self.usage(t).share(placement)
            for t in self.tenants()
            if t != tenant
        )

    def reset(self, tenant: str | None = None) -> None:
        """Drop metered history — for one tenant (its mapping was
        swapped, so its occupancy profile re-keys) or the whole
        ledger."""
        with self._lock:
            if tenant is None:
                self._steps.clear()
                self._open.clear()
            else:
                self._steps.pop(tenant, None)
                self._open.pop(tenant, None)

    def snapshot(self) -> dict:
        """Plain-dict summary for journals / bench derived columns."""
        out = {}
        for t in self.tenants():
            u = self.usage(t)
            out[t] = {
                "steps": u.steps,
                "host_s": u.host_s,
                "device_s": u.device_s,
                "host_share": u.share(HOST),
                "device_share": u.share(DEVICE),
            }
        return out
