"""Contention-aware multi-model co-serving (``repro.fleet``).

Serving N BNN models on one shared host/device platform composes the
whole stack — profiler tables, the DP mapper, serving engines, the
adaptive runtime, the profile store — under one new constraint:
co-located placements interfere.  Three pieces close that loop
(docs/ARCHITECTURE.md §10):

* :mod:`scheduler` — :func:`map_fleet`: coordinate-descent joint
  mapping over per-tenant contention-inflated ProfileTables
  (``cost_model.inflate_profile``), seeded at — and provably never
  worse than — the all-models-all-GPU assignment;
* :mod:`router` — :class:`FleetRouter`: priority/deadline dispatch
  into per-tenant ServingEngines with admission control (shed at the
  door rather than serve past the SLO), plus the
  :class:`QualityController` that degrades elastic tenants' subnet
  width under sustained shedding instead (``repro.elastic``, §15);
* :mod:`ledger` — :class:`DeviceTimeLedger`: metered per-tenant
  host/device occupancy feeding measured co-runner shares back into
  the joint mapper and the per-tenant drift loops.

See ``benchmarks/fleet_bench.py`` and ``examples/serve_fleet.py``.
"""

from repro.fleet.ledger import DeviceTimeLedger, TenantUsage
from repro.fleet.router import (
    FleetRouter,
    QualityController,
    QualityRecord,
    Tenant,
)
from repro.fleet.scheduler import (
    FleetPlan,
    TenantPlan,
    all_device_configuration,
    device_configs,
    joint_makespan,
    map_all_device,
    map_fleet,
    tenant_inflations,
)
