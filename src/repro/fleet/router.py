"""SLO-aware request routing over per-tenant serving engines.

:class:`FleetRouter` fronts N tenants, each a
:class:`~repro.serving.ServingEngine` (its ``MicroBatcher`` is the
tenant's queue) with a priority, a latency deadline, and optionally a
per-tenant :class:`~repro.adapt.RemapController`:

* **submit** — admission control at the door: a request predicted to
  complete past its tenant's deadline (queue depth ahead of it, in
  batches, times the tenant's expected step time — the **live**
  telemetry estimate once the engine's ``SegmentTelemetry`` is warm,
  the profiled prediction while cold) is *rejected now*
  rather than served late — a shed request costs nothing, a late one
  cost a batch slot some other tenant's in-SLO request needed.
  Rejections are counted per tenant (:meth:`stats`).
* **step** — dispatch: tenants with a ready batch are served in
  (higher priority first, earliest deadline first) order, one engine
  step each — strict priority, rather than fair-share, because the
  joint mapper already balanced sustained load; priority here decides
  who eats a transient burst's latency.  Tenants with an attached
  controller are stepped through it, so per-tenant drift detection
  and remapping ride the same dispatch loop.  When a
  :class:`~repro.fleet.ledger.DeviceTimeLedger` is attached, every
  tenant's engine observer feeds it and the router closes the
  tenant's ledger step after each dispatch.

* **quality** — when a :class:`QualityController` is attached, the
  router closes every dispatch round by letting it observe shed
  pressure and hot-swap elastic tenants' engines to a narrower subnet
  level before the next round sheds more (``repro.elastic``; docs
  §15) — degrading width instead of availability, and restoring width
  when the pressure clears.

Threading contract (see ``repro.serving.batcher``): ``submit`` may be
called from many client threads concurrently; ``step`` must be driven
from a single dispatch thread.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time

from repro.serving.batcher import Request
from repro.serving.engine import ServingEngine


@dataclasses.dataclass
class Tenant:
    """One co-served model behind the router."""

    name: str
    engine: ServingEngine
    priority: int = 0             # higher dispatches first
    deadline_s: float = math.inf  # per-request latency SLO
    controller: object = None     # optional RemapController
    # samples every segment needs before live telemetry replaces the
    # profiled step estimate in admission
    live_min_samples: int = 3
    admitted: int = 0
    rejected: int = 0
    # guards this tenant's admission decision + counters: submit() is
    # callable from many client threads, and an unlocked
    # `admitted += 1` loses increments under thread switches.
    # Per-tenant, so one tenant's submit storm never serializes
    # another tenant's clients
    lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False
    )

    def live_step_s(self) -> float | None:
        """Measured wall seconds for one full engine step, from the
        engine's segment-telemetry EWMAs — or ``None`` while cold
        (no telemetry attached, or any segment below
        ``live_min_samples``).  Hot swaps reset the telemetry, so the
        estimate automatically falls back to profiled until the new
        configuration has been observed."""
        telemetry = getattr(self.engine, "telemetry", None)
        if telemetry is None:
            return None
        cfg = self.engine.config
        s_ex = telemetry.live_s_per_example(
            len(cfg.segments()), min_count=self.live_min_samples
        )
        if s_ex is None:
            return None
        return s_ex * cfg.proper_batch_size

    def step_expected_s(self) -> float:
        """Expected wall seconds for one full engine step — one
        micro-batch of the serving batch size under the tenant's
        current configuration.  Prefers the live telemetry estimate
        (:meth:`live_step_s`) so admission tracks what the step
        actually costs under drift; falls back to the profiled
        prediction while telemetry is cold (hot swaps update both
        paths automatically because the engine's config is read
        live)."""
        live = self.live_step_s()
        if live is not None:
            return live
        cfg = self.engine.config
        return cfg.expected_time_per_example * cfg.proper_batch_size

    def backlog_batches(self, extra: int = 1) -> int:
        """Batches ahead of (and including) a request arriving now."""
        pending = self.engine.batcher.pending() + extra
        return math.ceil(pending / self.engine.batcher.max_batch)


@dataclasses.dataclass(frozen=True)
class QualityRecord:
    """One journaled quality transition — the elastic analogue of
    ``SwapRecord`` (remaps) and ``ScaleRecord`` (topology)."""

    seq: int
    at_s: float
    tenant: str
    action: str          # "degrade" | "restore" | "floor_hold"
    from_level: int
    to_level: int
    reason: str
    shed_delta: int      # rejections since the previous observation
    backlog_batches: int
    est_step_s: float
    deadline_s: float
    applied: bool        # False when deferred to the batch boundary


class QualityController:
    """SLO-driven width adaptation for elastic tenants.

    Watches each elastic tenant's *shed pressure* — the delta of its
    rejection counter between dispatch rounds (admission control
    already encodes backlog × step-estimate vs deadline, so a shed is
    the precise signal that the current width cannot hold the SLO) —
    and drives the engine's subnet level with PR 4-style hysteresis:

    * ``degrade_after`` consecutive rounds with sheds → hot-swap one
      level narrower (``engine.set_level(level + 1)``), *before* the
      next round sheds more.  At the engine's ``quality_floor`` a
      ``floor_hold`` is journaled instead — the floor is honored, the
      overflow sheds.
    * ``restore_after`` consecutive shed-free rounds → one level wider,
      but only when the wider level's expected step fits inside
      ``headroom × deadline`` (restoring into a step that instantly
      sheds again would oscillate).

    Every transition (and every held floor) is a :class:`QualityRecord`
    in :attr:`journal`.  Attach via ``FleetRouter(quality=...)`` — the
    router calls :meth:`observe` at the end of each dispatch round —
    or call :meth:`observe` from your own loop.
    """

    def __init__(
        self,
        *,
        degrade_after: int = 2,
        restore_after: int = 4,
        headroom: float = 0.5,
        clock=time.monotonic,
    ):
        if degrade_after < 1 or restore_after < 1:
            raise ValueError(
                "degrade_after and restore_after must be >= 1"
            )
        if not 0.0 < headroom <= 1.0:
            raise ValueError("headroom must be in (0, 1]")
        self.degrade_after = degrade_after
        self.restore_after = restore_after
        self.headroom = headroom
        self._clock = clock
        self.journal: list[QualityRecord] = []
        self._seq = 0
        self._last_rejected: dict[str, int] = {}
        self._hi: dict[str, int] = {}
        self._lo: dict[str, int] = {}

    @staticmethod
    def _elastic(tenant: Tenant):
        """The tenant's engine when it supports level switching."""
        engine = tenant.engine
        return engine if hasattr(engine, "set_level") else None

    def _record(self, tenant: Tenant, from_level, action, to_level,
                reason, shed_delta, applied) -> QualityRecord:
        rec = QualityRecord(
            seq=self._seq,
            at_s=self._clock(),
            tenant=tenant.name,
            action=action,
            from_level=from_level,
            to_level=to_level,
            reason=reason,
            shed_delta=shed_delta,
            backlog_batches=tenant.backlog_batches(extra=0),
            est_step_s=tenant.step_expected_s(),
            deadline_s=tenant.deadline_s,
            applied=applied,
        )
        self._seq += 1
        self.journal.append(rec)
        return rec

    def _wider_fits(self, tenant: Tenant, engine) -> bool:
        """Would the next-wider level's step fit in ``headroom ×
        deadline``?  (Always, for deadline-free tenants.)"""
        if math.isinf(tenant.deadline_s):
            return True
        cfg = engine.level_config(engine.level - 1)
        est = cfg.expected_time_per_example * cfg.proper_batch_size
        return est <= self.headroom * tenant.deadline_s

    def observe(self, router: "FleetRouter") -> list:
        """One hysteresis tick over the router's elastic tenants;
        returns the records journaled this tick."""
        out = []
        for t in router.tenants():
            engine = self._elastic(t)
            if engine is None:
                continue
            name = t.name
            shed = t.rejected - self._last_rejected.get(name, 0)
            self._last_rejected[name] = t.rejected
            if shed > 0:
                self._lo[name] = 0
                self._hi[name] = self._hi.get(name, 0) + 1
                if self._hi[name] < self.degrade_after:
                    continue
                self._hi[name] = 0
                if engine.can_degrade():
                    # journal the pre-switch level: set_level mutates
                    # engine.level when it applies immediately
                    frm = engine.level
                    target = frm + 1
                    applied = engine.set_level(target)
                    out.append(self._record(
                        t, frm, "degrade", target,
                        f"{shed} sheds, sustained "
                        f"{self.degrade_after} rounds",
                        shed, applied,
                    ))
                else:
                    out.append(self._record(
                        t, engine.level, "floor_hold", engine.level,
                        f"overloaded at quality_floor "
                        f"{engine.quality_floor}; shedding",
                        shed, False,
                    ))
            else:
                self._hi[name] = 0
                self._lo[name] = self._lo.get(name, 0) + 1
                if (
                    self._lo[name] >= self.restore_after
                    and engine.can_restore()
                    and self._wider_fits(t, engine)
                ):
                    self._lo[name] = 0
                    frm = engine.level
                    target = frm - 1
                    applied = engine.set_level(target)
                    out.append(self._record(
                        t, frm, "restore", target,
                        f"shed-free {self.restore_after} rounds, "
                        "wider step fits headroom",
                        0, applied,
                    ))
        return out


class FleetRouter:
    def __init__(self, *, ledger=None, quality=None):
        self._tenants: dict[str, Tenant] = {}
        self.ledger = ledger
        self.quality = quality

    def add_tenant(
        self,
        name: str,
        engine: ServingEngine,
        *,
        priority: int = 0,
        deadline_s: float = math.inf,
        controller=None,
        live_min_samples: int = 3,
    ) -> Tenant:
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        if deadline_s <= 0.0:
            raise ValueError("deadline_s must be positive")
        if live_min_samples < 1:
            raise ValueError("live_min_samples must be >= 1")
        tenant = Tenant(
            name=name, engine=engine, priority=priority,
            deadline_s=deadline_s, controller=controller,
            live_min_samples=live_min_samples,
        )
        self._tenants[name] = tenant
        return tenant

    def tenant(self, name: str) -> Tenant:
        return self._tenants[name]

    def tenants(self) -> tuple:
        return tuple(self._tenants.values())

    # -- admission ---------------------------------------------------
    def admit(self, name: str) -> bool:
        """Would a request for `name` submitted now make its
        deadline?  Estimate: batches ahead of it times the tenant's
        expected step time (coalescing wait is bounded by the same
        step cadence, so one backlog term covers both)."""
        t = self._tenants[name]
        if math.isinf(t.deadline_s):
            return True
        est = t.backlog_batches() * t.step_expected_s()
        return est <= t.deadline_s

    def submit(self, name: str, x) -> Request | None:
        """Enqueue one example for tenant `name`, or reject it
        (returns ``None``, counted in :meth:`stats`) when its
        predicted completion violates the tenant's deadline.
        Thread-safe: the admit decision, the counter, and the enqueue
        happen under the tenant's lock, so counters never drop
        increments and two racing submits cannot both squeeze into
        the last slot the deadline allowed."""
        t = self._tenants[name]
        with t.lock:
            if not self.admit(name):
                t.rejected += 1
                return None
            t.admitted += 1
            return t.engine.submit(x)

    # -- dispatch ----------------------------------------------------
    def _dispatch_order(self, *, force: bool) -> list:
        ready = [
            t for t in self._tenants.values()
            if (t.engine.batcher.pending() > 0 if force
                else t.engine.batcher.ready())
        ]
        # strict priority; deadline breaks ties (tightest SLO first);
        # name last so dispatch order is deterministic
        return sorted(
            ready, key=lambda t: (-t.priority, t.deadline_s, t.name)
        )

    def step(self, *, force: bool = False) -> dict:
        """One dispatch round: every tenant with a ready batch (any
        pending request under ``force``) takes one engine step, in
        priority/deadline order.  Returns {tenant: requests served}
        for the tenants that served."""
        served = {}
        for t in self._dispatch_order(force=force):
            stepper = t.controller.step if t.controller else t.engine.step
            done = stepper(force=force)
            if self.ledger is not None:
                self.ledger.close_step(t.name)
            if done:
                served[t.name] = done
        if self.quality is not None:
            # after dispatch: this round's sheds are on the counters,
            # and level switches land at an idle batch boundary
            self.quality.observe(self)
        return served

    def drain(self, *, max_steps: int = 1000) -> dict:
        """Forced steps until every tenant's queue is empty (bounded
        by ``max_steps``).  Returns total {tenant: served}."""
        total: dict = {}
        for _ in range(max_steps):
            served = self.step(force=True)
            if not served:
                break
            for name, n in served.items():
                total[name] = total.get(name, 0) + n
        return total

    def stats(self) -> dict:
        """Per-tenant admission/served counters for reporting.
        Elastic tenants additionally report their current subnet
        level, floor, switch count and degraded-time share."""
        out = {}
        for t in self._tenants.values():
            row = {
                "priority": t.priority,
                "deadline_s": t.deadline_s,
                "admitted": t.admitted,
                "rejected": t.rejected,
                "served": t.engine.served,
                "steps": t.engine.steps,
                "swaps": t.engine.swaps,
                # which estimate admission is currently running on
                "admission": (
                    "live" if t.live_step_s() is not None
                    else "profiled"
                ),
            }
            if hasattr(t.engine, "set_level"):
                row.update(
                    level=t.engine.level,
                    quality_floor=t.engine.quality_floor,
                    level_switches=t.engine.level_switches,
                    degraded_share=t.engine.degraded_share,
                )
            out[t.name] = row
        return out
