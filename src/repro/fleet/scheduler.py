"""Interference-aware joint mapping for a fleet of co-served BNNs.

HEP-BNN's mapper optimizes one model as if it owned the platform;
co-located tenants contend, and each model's "efficient" solo mapping
is jointly inefficient — typically every tenant maps onto the device
and they timeslice it.  :func:`map_fleet` searches the *joint*
assignment:

**Interference model.**  Tenant *j* running configuration *c_j*
demands a share of each processor — the fraction of its busy time
spent there (``EfficientConfiguration.placement_shares``, or measured
occupancy from a :class:`~repro.fleet.ledger.DeviceTimeLedger`).  In
the saturated co-serving regime (every tenant continuously busy),
tenant *i*'s kernels on processor *p* stretch by
``contention_inflation(sum of co-runners' shares on p, gamma)``
(``repro.core.cost_model``), so its wall time per example is its
mapping repriced on a per-tenant **contention-inflated table**
(:func:`repro.core.cost_model.inflate_profile`).

**Objective.**  ``joint makespan`` — the wall time until every
tenant drains its workload, all running concurrently::

    makespan(assignment) = max_i  weight_i * inflated_time_i(assignment)

with ``weight_i`` the tenant's relative workload (examples to serve).

**Search.**  Coordinate descent: seed every tenant with its best
all-device mapping (the *all-GPU fleet assignment* — what N solo
HEP-BNN runs would deploy), then repeatedly re-run the existing
per-model DP (``map_efficient_configuration``) for one tenant at a
time against that tenant's contention-inflated table, accepting a
move only when it strictly lowers the joint makespan, until a full
round changes nothing (or ``max_rounds``).  Because the descent
starts *at* the all-GPU assignment and only ever accepts improving
moves, the returned plan is **provably never worse than
all-models-all-GPU under the same inflated cost model** — the
property ``tests/test_fleet.py`` asserts over random tables.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.cost_model import contention_inflation, inflate_profile
from repro.core.mapper import (
    EfficientConfiguration,
    map_efficient_configuration,
    price_mapping,
)
from repro.core.parallel_config import is_host_config
from repro.core.profiler import ProfileTable


def device_configs(table: ProfileTable, registry=None) -> tuple:
    """Every device-placed config name appearing anywhere in `table` —
    the restriction that forces an all-device mapping."""
    names: list = []
    for b in table.batch_sizes:
        for i in range(len(table.layer_labels)):
            for c in table.configs_for(b, i):
                if not is_host_config(c, registry) and c not in names:
                    names.append(c)
    if not names:
        raise ValueError(
            f"table {table.model_name!r} has no device-placed configs"
        )
    return tuple(names)


def map_all_device(
    table: ProfileTable,
    *,
    batch_sizes: Sequence[int] | None = None,
    registry=None,
) -> EfficientConfiguration:
    """The strongest all-GPU mapping for one model: the DP restricted
    to device placements (any device variant per layer, best batch) —
    the per-tenant piece of the all-models-all-GPU fleet baseline.

    Canonical spelling of the legacy ``all_device_configuration``
    (part of the ``repro.api`` verb set)."""
    return map_efficient_configuration(
        table,
        configs=device_configs(table, registry),
        policy="dp",
        batch_sizes=batch_sizes,
    )


@dataclasses.dataclass(frozen=True)
class TenantPlan:
    """One tenant's slice of a :class:`FleetPlan`.

    ``config`` is repriced on the tenant's contention-**inflated**
    table under the final assignment, so
    ``config.expected_time_per_example == inflated_expected_s`` for
    every tenant — the deployment-honest estimate consumers like the
    router's admission control read, consistent across tenants
    regardless of which descent step produced the mapping
    (``solo_expected_s`` keeps the uninflated view)."""

    name: str
    config: EfficientConfiguration
    host_share: float             # demand (or measured) share used
    device_share: float
    host_inflation: float         # factors the mapping was priced under
    device_inflation: float
    solo_expected_s: float        # per example, uninflated table
    inflated_expected_s: float    # per example, under co-runner load
    weight: float
    law: object = None            # fitted interference law, if any

    @property
    def makespan_s(self) -> float:
        return self.weight * self.inflated_expected_s


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """A joint assignment plus the evidence it beat the baseline."""

    tenants: tuple                # TenantPlan per model, input order
    joint_makespan_s: float
    baseline_makespan_s: float    # the all-GPU seed, same inflated model
    rounds: int                   # descent rounds executed
    converged: bool               # a full round changed nothing

    @property
    def configs(self) -> tuple:
        return tuple(t.config for t in self.tenants)

    @property
    def vs_all_gpu(self) -> float:
        """joint / all-GPU makespan ratio (<= 1.0 by construction)."""
        if self.baseline_makespan_s <= 0.0:
            return 1.0
        return self.joint_makespan_s / self.baseline_makespan_s


def _shares_of(
    tables,
    configs: Sequence[EfficientConfiguration],
    shares=None,
) -> list:
    """Per-tenant (host, device) shares: measured ones when given
    (``None`` entries fall back per tenant), else each mapping's
    demand profile **repriced on its own uninflated table** — so the
    share a tenant charges its co-runners depends only on (table,
    mapping, batch), never on which (possibly inflated) table happened
    to price the configuration object in hand."""
    out = []
    for i, cfg in enumerate(configs):
        measured = None if shares is None else shares[i]
        if measured is not None:
            out.append(measured)
            continue
        solo = price_mapping(
            tables[i], cfg.proper_batch_size, cfg.layer_configs
        )
        out.append(solo.placement_shares())
    return out


def tenant_inflations(
    tenant_shares: Sequence, index: int, *, gamma: float = 1.0, law=None
) -> tuple:
    """(host_factor, device_factor) for tenant `index` given every
    tenant's (host, device) share: co-runners' summed share on each
    processor, through :func:`contention_inflation`.  A fitted `law`
    (``repro.estimator.FittedInterference``) replaces the linear
    ``gamma`` model on both processors."""
    co_host = sum(
        s[0] for j, s in enumerate(tenant_shares) if j != index
    )
    co_dev = sum(
        s[1] for j, s in enumerate(tenant_shares) if j != index
    )
    return (
        contention_inflation(co_host, gamma, law=law),
        contention_inflation(co_dev, gamma, law=law),
    )


def joint_makespan(
    tables: Sequence[ProfileTable],
    configs: Sequence[EfficientConfiguration],
    *,
    gamma: float = 1.0,
    law=None,
    weights: Sequence[float] | None = None,
    shares=None,
    registry=None,
) -> float:
    """The fleet objective: max over tenants of weighted per-example
    wall time, each tenant's mapping repriced on its
    contention-inflated table.  `shares` (per-tenant (host, device),
    e.g. from a ledger) overrides the demand model; `law` swaps the
    linear gamma model for a calibrated inflation law."""
    plans = _price_assignment(
        tables, configs, gamma=gamma, law=law, weights=weights,
        shares=shares, registry=registry,
    )
    return max(t.makespan_s for t in plans)


def _price_assignment(
    tables,
    configs,
    *,
    gamma,
    law=None,
    weights=None,
    shares=None,
    names=None,
    registry=None,
) -> tuple:
    if weights is None:
        weights = (1.0,) * len(tables)
    tenant_shares = _shares_of(tables, configs, shares)
    plans = []
    for i, (table, cfg) in enumerate(zip(tables, configs)):
        host_f, dev_f = tenant_inflations(
            tenant_shares, i, gamma=gamma, law=law
        )
        inflated = inflate_profile(
            table, host_factor=host_f, device_factor=dev_f,
            registry=registry,
        )
        batch = cfg.proper_batch_size
        priced = price_mapping(inflated, batch, cfg.layer_configs)
        solo = price_mapping(table, batch, cfg.layer_configs)
        plans.append(
            TenantPlan(
                name=names[i] if names else table.model_name,
                config=priced,
                host_share=tenant_shares[i][0],
                device_share=tenant_shares[i][1],
                host_inflation=host_f,
                device_inflation=dev_f,
                solo_expected_s=solo.expected_time_per_example,
                inflated_expected_s=priced.expected_time_per_example,
                weight=float(weights[i]),
                law=law,
            )
        )
    return tuple(plans)


def map_fleet(
    tables: Sequence[ProfileTable],
    *,
    names: Sequence[str] | None = None,
    policy: str = "dp",
    configs=None,
    batch_sizes: Sequence[int] | None = None,
    weights: Sequence[float] | None = None,
    shares=None,
    gamma: float = 1.0,
    law=None,
    max_rounds: int = 8,
    registry=None,
) -> FleetPlan:
    """Jointly map N co-served models (one ProfileTable each) under
    the contention-inflation model (module docstring).

    ``configs``/``batch_sizes``/``policy`` restrict each per-tenant DP
    exactly as in :func:`map_efficient_configuration`.  ``shares`` is
    an optional per-tenant list of measured (host, device) occupancy
    pairs — ``DeviceTimeLedger.shares()`` values — overriding the
    demand model per tenant (``None`` entries fall back); ``weights``
    are relative workload sizes.  ``law`` replaces the linear
    ``gamma`` model with a calibrated inflation law
    (``repro.estimator.InterferenceFit().fit()``) — the descent's
    never-worse guarantee only needs monotonicity, which the
    fitted-law contract provides.  Returns a :class:`FleetPlan` whose
    ``joint_makespan_s <= baseline_makespan_s`` always holds: the
    descent seeds at the all-GPU fleet assignment and only accepts
    strictly improving moves.
    """
    if not tables:
        raise ValueError("map_fleet needs at least one tenant table")
    if names is not None and len(names) != len(tables):
        raise ValueError("names must match tables one-to-one")
    if shares is not None and len(shares) != len(tables):
        raise ValueError("shares must match tables one-to-one")
    if weights is not None and len(weights) != len(tables):
        raise ValueError("weights must match tables one-to-one")

    def makespan(assignment) -> float:
        return joint_makespan(
            tables, assignment, gamma=gamma, law=law, weights=weights,
            shares=shares, registry=registry,
        )

    # seed: the all-GPU fleet assignment — N solo deployments
    assignment = [
        map_all_device(t, batch_sizes=batch_sizes, registry=registry)
        for t in tables
    ]
    baseline = best = makespan(assignment)

    rounds = 0
    converged = False
    for rounds in range(1, max_rounds + 1):
        changed = False
        for i, table in enumerate(tables):
            tenant_shares = _shares_of(tables, assignment, shares)
            host_f, dev_f = tenant_inflations(
                tenant_shares, i, gamma=gamma, law=law
            )
            inflated = inflate_profile(
                table, host_factor=host_f, device_factor=dev_f,
                registry=registry,
            )
            candidate = map_efficient_configuration(
                inflated, policy=policy, configs=configs,
                batch_sizes=batch_sizes,
            )
            if (
                candidate.layer_configs,
                candidate.proper_batch_size,
            ) == (
                assignment[i].layer_configs,
                assignment[i].proper_batch_size,
            ):
                continue
            trial = list(assignment)
            trial[i] = candidate
            m = makespan(trial)
            if m < best:
                assignment, best, changed = trial, m, True
        if not changed:
            converged = True
            break

    return FleetPlan(
        tenants=_price_assignment(
            tables, assignment, gamma=gamma, law=law, weights=weights,
            shares=shares, names=names, registry=registry,
        ),
        joint_makespan_s=best,
        baseline_makespan_s=baseline,
        rounds=rounds,
        converged=converged,
    )


def all_device_configuration(
    table: ProfileTable,
    *,
    batch_sizes: Sequence[int] | None = None,
    registry=None,
) -> EfficientConfiguration:
    """Deprecated spelling of :func:`repro.api.map_all_device` — kept
    importable; warns once per call site and delegates."""
    from repro._compat import warn_deprecated

    warn_deprecated("all_device_configuration", "map_all_device")
    from repro import api

    return api.map_all_device(
        table, batch_sizes=batch_sizes, registry=registry
    )
