"""Deprecation plumbing for the ``repro.api`` naming sweep.

The facade (:mod:`repro.api`) owns the canonical verb set; the legacy
spellings (``configuration_from_mapping``, ``fuse_configuration``,
``all_device_configuration``) stay importable as shims that delegate
to the facade and emit one :class:`DeprecationWarning` **per call
site** — a long-running serving loop hitting a shim every step warns
once, not once per request.
"""

from __future__ import annotations

import inspect
import warnings

# (old name, caller file, caller line) triples already warned about
_WARNED: set = set()


def warn_deprecated(old: str, new: str) -> None:
    """Warn that `old` is deprecated in favor of ``repro.api``'s
    `new`, at most once per call site of the shim that invokes this
    (the shim's caller's file:line keys the dedup)."""
    site = ("<unknown>", 0)
    frame = inspect.currentframe()
    try:
        if frame is not None:
            shim = frame.f_back
            caller = shim.f_back if shim is not None else None
            if caller is not None:
                site = (caller.f_code.co_filename, caller.f_lineno)
    finally:
        del frame
    key = (old, site)
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(
        f"{old} is deprecated; use repro.api.{new} (same arguments, "
        "same result)",
        DeprecationWarning,
        stacklevel=3,
    )


def reset_warned() -> None:
    """Forget warned-at sites (test isolation)."""
    _WARNED.clear()
