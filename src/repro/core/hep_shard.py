"""HEP-Shard: the paper's mapping algorithm lifted to multi-pod scale.

Exactly Algorithm 1's skeleton with substitutions:
  layer implementation   ->  ShardScheme knob value (tp / fsdp /
                             expert_mode / batch_over_model /
                             seq_over_model)
  profiled wall-clock    ->  compiled dry-run roofline terms
                             (compute/memory/collective seconds,
                             repro.launch.dryrun)
  batch-size sweep       ->  knob sweep via greedy coordinate descent
                             (one knob at a time, argmin cost, repeat
                             until fixpoint — the paper's greedy
                             per-layer argmin generalized to a config
                             lattice)

Cost = step-time estimate max(compute, memory) + collective (compute
and memory overlap on TPU; collectives on ICI only partially — we use
the conservative sum) + a hard penalty when peak bytes/device exceed
HBM (a config that does not fit is not a config, it is an OOM).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.parallel.sharding import ShardScheme

HBM_BYTES = 16 * 2**30   # v5e
OOM_PENALTY = 1e6


@dataclasses.dataclass
class ShardTrial:
    scheme: ShardScheme
    compute_s: float
    memory_s: float
    collective_s: float
    peak_bytes: int
    # kernel-vs-transfer split (mirrors ProfileTable's kernel/boundary
    # decomposition): host<->device staging charged separately from the
    # on-device step so schedulers can elide it across co-placed steps
    h2d_s: float = 0.0
    d2h_s: float = 0.0

    @property
    def kernel_s(self) -> float:
        """On-device step time: overlapped compute/memory + collective."""
        return max(self.compute_s, self.memory_s) + self.collective_s

    @property
    def transfer_s(self) -> float:
        return self.h2d_s + self.d2h_s

    @property
    def cost(self) -> float:
        c = self.kernel_s + self.transfer_s
        if self.peak_bytes > HBM_BYTES:
            c += OOM_PENALTY * (self.peak_bytes / HBM_BYTES)
        return c


KNOBS = {
    "tp": (True, False),
    "fsdp": ("zero1", "zero3", "none"),
    "expert_mode": ("auto", "ep", "tp"),
    "batch_over_model": (False, True),
    "seq_over_model": (False, True),
    "attn_kv_parallel": (False, True),
    "out_proj_contracting_2d": (False, True),
    "accum_steps": (1, 4, 8),
}


def search(
    evaluate: Callable[[ShardScheme], ShardTrial],
    start: Optional[ShardScheme] = None,
    *,
    knobs: Optional[dict] = None,
    max_rounds: int = 3,
    log: Optional[Callable[[str], None]] = print,
) -> tuple:
    """Greedy coordinate descent over the scheme lattice.

    `evaluate` compiles the cell under a scheme and returns its trial
    (cached by the caller — compiles are the expensive unit).
    Returns (best ShardTrial, history list).
    """
    current = start or ShardScheme()
    knobs = knobs or KNOBS
    seen: dict = {}

    def ev(scheme: ShardScheme) -> ShardTrial:
        key = dataclasses.astuple(scheme)
        if key not in seen:
            seen[key] = evaluate(scheme)
        return seen[key]

    best = ev(current)
    history = [best]
    for round_ in range(max_rounds):
        improved = False
        for knob, values in knobs.items():       # Alg.1 foreach layer
            trials = []
            for v in values:                     # Alg.1 foreach implem
                cand = dataclasses.replace(current, **{knob: v})
                try:
                    trials.append(ev(cand))
                except Exception as e:           # an invalid combo is a
                    if log:                      # profiled failure, not
                        log(f"  {knob}={v}: {e!r}")  # a crash
                    continue
            if not trials:                       # every value failed:
                if log:                          # the knob is a no-op
                    log(f"  {knob}: all values failed, skipping")
                continue
            t = min(trials, key=lambda t: t.cost)
            if t.cost < best.cost - 1e-12:       # Alg.1 argmin
                best = t
                current = t.scheme
                improved = True
                if log:
                    log(
                        f"  round {round_} {knob} -> "
                        f"{getattr(t.scheme, knob)}: cost {t.cost:.4f}s"
                    )
            history.append(t)
        if not improved:
            break
    return best, history
