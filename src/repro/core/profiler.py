"""Per-layer latency profiling (paper §III-A, Fig. 4) and the
registry-driven autotune pass.

Two entry points, one ``ProfileTable`` output:

* :func:`profile_bnn_model` — the paper's sweep: for every batch size
  and every layer, time a **fixed** candidate list (default: ``CPU`` +
  the 7 aspect configs).
* :func:`autotune_bnn_model` — the open-space sweep: per-layer
  candidates come from the kernel-variant registry
  (:mod:`repro.kernels.registry`) filtered by each GEMM layer's shape
  and the host platform, so rows are **variable-size** (and always a
  superset of the fixed-8 space — the paper's configs carry no
  applicability predicate).  In measured mode, extended variants get a
  cheap one-repeat warm-up timing first and are pruned (skipped for
  the full ``repeats`` sweep) when dominated by ``prune_factor`` x the
  best warm-up so far; the fixed-8 names are never pruned.

**Kernel/boundary time model.**  Each profiled entry is split into two
independently-stored components:

* ``kernel``  — the layer's compute alone, wherever it is placed;
* ``boundary`` — the host<->device transfer cost of the layer's operand
  (H2D) and result (D2H), measured/modeled **separately** per
  direction and stored per layer in ``h2d_times`` / ``d2h_times``.

The paper-faithful total (``times``) charges device-placed layers
``kernel + h2d + d2h`` — §IV-A: "data transfer between CPU and GPU
takes place before and after every layer's execution".  The split
exists because the fused executor (``mapped_model.build_mapped_model``
with ``fused=True``) elides the interior transfers between co-placed
device layers; the transfer-aware DP mapper (``mapper`` with
``policy='dp'``) prices exactly that execution: kernel time per layer,
boundary cost only where placement changes host<->device.

Times are stored **seconds per example** so totals are comparable
across batch sizes (the paper profiles the full test set per batch
size; per-example normalization is equivalent).

``time_source='measured'`` times real XLA executables on the host
platform; ``'analytic'`` uses the TPU v5e cost model
(``repro.core.cost_model``) — the dry-run-style path for hardware we
cannot run.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.bnn import layers as L
from repro.bnn.models import BNNModel, prepare_input_packed
from repro.core import cost_model as cm
from repro.core.parallel_config import CONFIGS, is_host_config
from repro.kernels.registry import DEFAULT_REGISTRY, GemmShape


@dataclasses.dataclass
class ProfileTable:
    model_name: str
    batch_sizes: tuple
    layer_labels: tuple          # e.g. ('L1:C64', 'L2:MP14', ...)
    # times[batch][layer_idx][config] -> seconds per example, paper
    # semantics: kernel + full per-layer boundary for device configs.
    # Rows are dicts keyed by variant name, so per-layer config spaces
    # may differ in size (autotuned tables) — consumers must iterate
    # row keys (``configs_for``), never assume the fixed 8.
    times: dict
    # kernel_times[batch][layer_idx][config] -> kernel-only s/example
    kernel_times: dict | None = None
    # h2d_times/d2h_times[batch][layer_idx] -> boundary s/example for
    # the layer's operand upload / result download (config-independent)
    h2d_times: dict | None = None
    d2h_times: dict | None = None
    # segment_times[batch]["start:stop"][variant] -> kernel s/example
    # for a whole device segment executed as one fused dispatch
    # (segment-scope variants, ``repro.kernels.segment_fused``) —
    # the candidate rows ``core.plan.select_fused_segments`` compares
    # against the span's per-layer kernel sum
    segment_times: dict | None = None
    # where the rows came from: "measured" / "analytic" (the profiler
    # stamps its time_source) or "predicted" (synthesized by
    # repro.estimator.LatencyPredictor with zero profiling passes).
    # None on legacy tables; additive, so the schema stays at 1.
    provenance: str | None = None

    @staticmethod
    def span_key(start: int, stop: int) -> str:
        return f"{start}:{stop}"

    def segment_variants_for(
        self, batch: int, start: int, stop: int
    ) -> tuple:
        """Segment-scope variant names profiled for the span at
        `batch` (``()`` when the span was never segment-profiled)."""
        if self.segment_times is None:
            return ()
        row = self.segment_times.get(batch, {}).get(
            self.span_key(start, stop)
        )
        return tuple(row) if row else ()

    def segment_time(
        self, batch: int, start: int, stop: int, variant: str
    ) -> float:
        return self.segment_times[batch][self.span_key(start, stop)][
            variant
        ]

    def add_segment_row(
        self, batch: int, start: int, stop: int, row: dict
    ) -> None:
        """Record (merge) a span's segment-variant timings at `batch`."""
        if self.segment_times is None:
            self.segment_times = {}
        self.segment_times.setdefault(batch, {}).setdefault(
            self.span_key(start, stop), {}
        ).update(row)

    def configs_for(self, batch: int, layer: int) -> tuple:
        """The candidate config names profiled for (batch, layer) —
        the layer's searchable space, variable-size by design."""
        return tuple(self.times[batch][layer])

    def best_config(self, batch: int, layer: int) -> tuple:
        row = self.times[batch][layer]
        cfg = min(row, key=row.get)
        return cfg, row[cfg]

    # -- split accessors (legacy tables without the split degrade to
    #    kernel == total, boundary == 0, under which the DP mapper
    #    reproduces the greedy mapping exactly) ----------------------
    def kernel_time(self, batch: int, layer: int, config: str) -> float:
        if self.kernel_times is not None:
            return self.kernel_times[batch][layer][config]
        return self.times[batch][layer][config]

    def h2d(self, batch: int, layer: int) -> float:
        if self.h2d_times is None:
            return 0.0
        return self.h2d_times[batch][layer]

    def d2h(self, batch: int, layer: int) -> float:
        if self.d2h_times is None:
            return 0.0
        return self.d2h_times[batch][layer]

    def boundary_time(self, batch: int, layer: int, config: str) -> float:
        """Full per-layer roundtrip charged under paper semantics."""
        if is_host_config(config):
            return 0.0
        return self.h2d(batch, layer) + self.d2h(batch, layer)

    # -- JSON round-trip (mirrors the EfficientConfiguration
    #    conventions: versioned schema, legacy-tolerant loader) -------
    SCHEMA_VERSION = 1

    def to_json(self) -> str:
        """Serialize the table, kernel/boundary split included when
        present.  Batch keys are stringified (JSON object keys);
        :meth:`from_json` restores them to ints."""

        def by_batch(d):
            return (
                None if d is None else {str(b): d[b] for b in sorted(d)}
            )

        return json.dumps(
            {
                "schema": self.SCHEMA_VERSION,
                "kind": "profile_table",
                "model": self.model_name,
                "batch_sizes": list(self.batch_sizes),
                "layer_labels": list(self.layer_labels),
                "times": by_batch(self.times),
                "kernel_times": by_batch(self.kernel_times),
                "h2d_times": by_batch(self.h2d_times),
                "d2h_times": by_batch(self.d2h_times),
                "segment_times": by_batch(self.segment_times),
                "provenance": self.provenance,
            },
            indent=2,
        )

    @staticmethod
    def from_json(s: str) -> "ProfileTable":
        """Inverse of :meth:`to_json`.  Legacy-tolerant: a document
        without the ``schema``/``kind`` envelope (or without the
        kernel/boundary split fields) still loads — missing split
        components degrade exactly like a pre-split in-memory table
        (kernel == total, boundary == 0).  A document from a *newer*
        schema than this code understands is refused rather than
        silently misread."""
        d = json.loads(s)
        schema = d.get("schema", 1)
        if schema > ProfileTable.SCHEMA_VERSION:
            raise ValueError(
                f"profile_table schema {schema} is newer than supported "
                f"({ProfileTable.SCHEMA_VERSION}); upgrade the loader"
            )
        kind = d.get("kind", "profile_table")
        if kind != "profile_table":
            raise ValueError(f"expected a profile_table document, got {kind!r}")

        def by_batch(key):
            raw = d.get(key)
            return (
                None if raw is None else {int(b): raw[b] for b in raw}
            )

        return ProfileTable(
            model_name=d["model"],
            batch_sizes=tuple(int(b) for b in d["batch_sizes"]),
            layer_labels=tuple(d["layer_labels"]),
            times=by_batch("times"),
            kernel_times=by_batch("kernel_times"),
            h2d_times=by_batch("h2d_times"),
            d2h_times=by_batch("d2h_times"),
            segment_times=by_batch("segment_times"),
            provenance=d.get("provenance"),
        )


def _timeit(fn: Callable[[], object], repeats: int) -> float:
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _measure_h2d(x_in: jax.Array, repeats: int) -> float:
    """Host->device upload cost of a layer's operand."""
    x_np = np.asarray(x_in)

    def upload():
        dev = jnp.asarray(x_np)
        jax.block_until_ready(dev)
        return dev

    return _timeit(upload, repeats)


def _measure_d2h(x_out: jax.Array, repeats: int) -> float:
    """Device->host download cost of a layer's result."""
    return _timeit(lambda: np.asarray(x_out), repeats)


def prune_survivors(
    warmups: dict, *, never_prune=CONFIGS, prune_factor: float = 3.0
) -> tuple:
    """Autotune pruning decision: given one-repeat warm-up timings
    (name -> seconds), keep every name in `never_prune` plus any
    variant within ``prune_factor`` x the fastest warm-up.  Dominated
    extended variants are skipped for the full-repeats sweep (and
    dropped from the profile row)."""
    if not warmups:
        return ()
    best = min(warmups.values())
    keep = set(never_prune)
    return tuple(
        name
        for name, t in warmups.items()
        if name in keep or t <= prune_factor * best
    )


def gemm_shape_of(spec: L.LayerSpec, packed: dict, batch: int):
    """The GEMM dispatch shape of a conv/fc layer at `batch` (None for
    elementwise layers) — what variant applicability predicates see."""
    if spec.kind not in ("conv", "fc"):
        return None
    w_words = packed["w_words"]
    n, kw = int(w_words.shape[0]), int(w_words.shape[1])
    if spec.kind == "conv":
        h, w, _ = spec.in_shape
        return GemmShape(b=batch, p=h * w, n=n, kw=kw)
    return GemmShape(b=batch, p=1, n=n, kw=kw)


def _layer_impls(
    spec: L.LayerSpec, packed: dict, candidates: Sequence[str], registry
):
    """Return {config: jitted fn} for one layer, all computing the packed
    reference semantics.  GEMM layers resolve each candidate name to its
    registered builder; elementwise layers share one computation (the
    candidates differ only by the boundary cost the profiler adds — the
    paper's finding that these layers never win on GPU emerges from
    measurement, not fiat)."""
    if spec.kind in ("conv", "fc"):
        w, k_true = packed["w_words"], packed["k_true"]

        def gemm_for(cfg):
            builder = registry.get(cfg).builder
            if spec.kind == "conv":

                @jax.jit
                def f(x):
                    from repro.bnn.layers import extract_patch_words

                    b, h, ww, _ = x.shape
                    p = extract_patch_words(x).reshape(b, h * ww, -1)
                    return builder(p, w, k_true).reshape(b, h, ww, -1)

            else:

                @jax.jit
                def f(x):
                    return builder(x[:, None, :], w, k_true)[:, 0, :]

            return f

        return {cfg: gemm_for(cfg) for cfg in candidates}

    if spec.kind == "mp":
        f = jax.jit(L.maxpool_packed)
    elif spec.kind == "step":
        t, fl = packed["thresh"], packed["flip"]
        f = jax.jit(lambda x: L.step_packed(x, t, fl))
    elif spec.kind == "flat":
        c = spec.in_shape[-1]
        f = jax.jit(lambda x: L.flat_packed(x, c))
    else:  # pragma: no cover
        raise ValueError(spec.kind)
    return {cfg: f for cfg in candidates}


def _capture_layer_inputs(
    model: BNNModel, packed_params: list, x_words: jax.Array
) -> list:
    """Run the packed reference forward, returning each layer's input."""
    xs = []
    x = x_words
    for spec, p in zip(model.specs, packed_params):
        xs.append(x)
        if spec.kind == "conv":
            x = L.conv_packed(x, p["w_words"], p["k_true"])
        elif spec.kind == "mp":
            x = L.maxpool_packed(x)
        elif spec.kind == "step":
            x = L.step_packed(x, p["thresh"], p["flip"])
        elif spec.kind == "flat":
            x = L.flat_packed(x, spec.in_shape[-1])
        elif spec.kind == "fc":
            x = L.fc_packed(x, p["w_words"], p["k_true"])
    return xs


def _analytic_rows(spec, candidates, batch, registry):
    """(row, krow, h2d, d2h) for one layer from the TPU cost model."""
    row, krow = {}, {}
    h2d = d2h = 0.0
    for cfg in candidates:
        kern, th2d, td2h = cm.layer_time_split_tpu(
            spec, cfg, batch, registry=registry
        )
        krow[cfg] = kern / batch
        row[cfg] = (kern + th2d + td2h) / batch
        if not is_host_config(cfg, registry):
            h2d, d2h = th2d / batch, td2h / batch
    return row, krow, h2d, d2h


def _measured_rows(
    spec, packed, candidates, batch, x_in, repeats, prune_factor, registry
):
    """(row, krow, h2d, d2h) for one layer by timing real executables.

    With ``prune_factor`` set, every candidate gets a one-repeat warm-up
    timing first; extended variants dominated by ``prune_factor`` x the
    best warm-up are dropped before the full-repeats sweep.
    """
    impls = _layer_impls(spec, packed, candidates, registry)
    x_out = impls[candidates[0]](x_in)
    h2d = _measure_h2d(x_in, repeats) / batch
    d2h = _measure_d2h(x_out, repeats) / batch
    warmups = {
        cfg: _timeit(lambda f=impls[cfg]: f(x_in), 1) for cfg in candidates
    }
    if prune_factor is not None:
        survivors = prune_survivors(
            warmups, never_prune=CONFIGS, prune_factor=prune_factor
        )
    else:
        survivors = tuple(candidates)
    row, krow = {}, {}
    for cfg in survivors:
        t = warmups[cfg]
        if repeats > 1:
            t = min(t, _timeit(lambda f=impls[cfg]: f(x_in), repeats - 1))
        t /= batch
        krow[cfg] = t
        row[cfg] = t if is_host_config(cfg, registry) else t + h2d + d2h
    return row, krow, h2d, d2h


def _profile(
    model: BNNModel,
    packed_params: list,
    candidates_fn: Callable,
    *,
    batch_sizes: Sequence[int],
    repeats: int,
    seed: int,
    time_source: str,
    prune_factor: float | None,
    registry=None,
) -> ProfileTable:
    """Shared sweep: ``candidates_fn(spec, packed, batch) -> names``
    decides each layer's searchable space."""
    labels = tuple(f"L{s.idx}:{s.notation}" for s in model.specs)
    times: dict = {}
    kernel_times: dict = {}
    h2d_times: dict = {}
    d2h_times: dict = {}
    key = jax.random.PRNGKey(seed)

    for batch in batch_sizes:
        x01 = jax.random.uniform(
            key, (batch, *model.input_hw, model.in_channels)
        )
        x_words = prepare_input_packed(x01)
        layer_inputs = _capture_layer_inputs(model, packed_params, x_words)
        per_layer: list = []
        per_layer_kernel: list = []
        per_layer_h2d: list = []
        per_layer_d2h: list = []
        for spec, packed, x_in in zip(
            model.specs, packed_params, layer_inputs
        ):
            candidates = tuple(candidates_fn(spec, packed, batch))
            if time_source == "analytic":
                row, krow, h2d, d2h = _analytic_rows(
                    spec, candidates, batch, registry
                )
            else:
                row, krow, h2d, d2h = _measured_rows(
                    spec, packed, candidates, batch, x_in, repeats,
                    prune_factor,
                    registry if registry is not None else DEFAULT_REGISTRY,
                )
            per_layer.append(row)
            per_layer_kernel.append(krow)
            per_layer_h2d.append(h2d)
            per_layer_d2h.append(d2h)
        times[batch] = per_layer
        kernel_times[batch] = per_layer_kernel
        h2d_times[batch] = per_layer_h2d
        d2h_times[batch] = per_layer_d2h

    return ProfileTable(
        model.name,
        tuple(batch_sizes),
        labels,
        times,
        kernel_times=kernel_times,
        h2d_times=h2d_times,
        d2h_times=d2h_times,
        provenance=time_source,
    )


def profile_bnn_model(
    model: BNNModel,
    packed_params: list,
    *,
    batch_sizes: Sequence[int] = (1, 2, 4, 8, 16, 32, 64, 128),
    configs: Sequence[str] = CONFIGS,
    repeats: int = 3,
    seed: int = 0,
    time_source: str = "measured",
) -> ProfileTable:
    """The paper's fixed-space sweep: every layer is timed under the
    same candidate list (default CPU + 7 aspect configs)."""
    configs = tuple(configs)
    return _profile(
        model,
        packed_params,
        lambda spec, packed, batch: configs,
        batch_sizes=batch_sizes,
        repeats=repeats,
        seed=seed,
        time_source=time_source,
        prune_factor=None,
    )


def autotune_bnn_model(
    model: BNNModel,
    packed_params: list,
    *,
    registry=None,
    batch_sizes: Sequence[int] = (1, 2, 4, 8, 16, 32, 64, 128),
    repeats: int = 3,
    seed: int = 0,
    time_source: str = "measured",
    prune_factor: float = 3.0,
    platform: str | None = None,
) -> ProfileTable:
    """Registry-driven autotune sweep with variable per-layer spaces.

    GEMM layers are timed under the fixed-8 configs **plus** every
    registered variant whose applicability predicate accepts the
    layer's dispatch shape on `platform`; elementwise layers keep the
    fixed 8 (their candidates share one computation — only placement
    matters).  Measured mode prunes dominated extended variants after
    a one-repeat warm-up (:func:`prune_survivors`); the fixed 8 are
    always fully timed, so any mapping feasible in the paper's space
    remains feasible in the autotuned table.

    ``platform=None`` resolves to the live JAX backend in measured
    mode; in analytic mode it defaults to ``"tpu"`` — the analytic
    sweep executes nothing, it prices the TPU target, so variants
    gated off non-TPU hosts (Pallas tiles) must still be priced.
    """
    reg = registry if registry is not None else DEFAULT_REGISTRY
    if platform is None and time_source == "analytic":
        platform = "tpu"

    def candidates(spec, packed, batch):
        shape = gemm_shape_of(spec, packed, batch)
        if shape is None:
            return CONFIGS
        extra = tuple(
            v.name
            for v in reg.applicable(shape, platform)
            if v.name not in CONFIGS
        )
        return CONFIGS + extra

    return _profile(
        model,
        packed_params,
        candidates,
        batch_sizes=batch_sizes,
        repeats=repeats,
        seed=seed,
        time_source=time_source,
        prune_factor=prune_factor if time_source == "measured" else None,
        registry=reg,
    )


def profile_segment_variants(
    model: BNNModel,
    packed_params: list,
    table: ProfileTable,
    *,
    spans: Sequence[tuple],
    batch_sizes: Sequence[int] | None = None,
    registry=None,
    time_source: str = "measured",
    repeats: int = 3,
    seed: int = 0,
    platform: str | None = None,
) -> ProfileTable:
    """Profile fused whole-segment execution over `spans` and record
    the rows on ``table.segment_times`` (the table is updated in place
    and returned).

    For each ``(start, stop)`` span and each batch size, every
    *segment-scope* registry variant whose applicability predicate
    accepts the span's :class:`~repro.kernels.registry.SegmentShape`
    is timed (measured mode: the real fused executable on this
    backend, same ``_timeit`` discipline as the per-layer sweep) or
    priced (analytic mode: the TPU cost model —
    ``cost_model.fused_segment_kernel_time_tpu`` for single-pass
    fused variants, ``cost_model.xla_segment_kernel_time_tpu``
    otherwise).  Times are kernel-only seconds per example: the
    segment's boundary transfers are unchanged by fusion (same edge
    operands) and stay priced by the per-layer h2d/d2h rows.

    Spans must be device-resident layer runs of the profiled model —
    typically ``core.plan.device_spans(config)``.
    """
    reg = registry if registry is not None else DEFAULT_REGISTRY
    if platform is None and time_source == "analytic":
        platform = "tpu"
    if batch_sizes is None:
        batch_sizes = table.batch_sizes
    from repro.kernels.registry import segment_shape_of

    key = jax.random.PRNGKey(seed)
    for batch in batch_sizes:
        if batch not in table.batch_sizes:
            raise ValueError(
                f"batch {batch} not profiled (have {table.batch_sizes})"
            )
        layer_inputs = None
        if time_source == "measured":
            x01 = jax.random.uniform(
                key, (batch, *model.input_hw, model.in_channels)
            )
            x_words = prepare_input_packed(x01)
            layer_inputs = _capture_layer_inputs(
                model, packed_params, x_words
            )
        for start, stop in spans:
            specs = tuple(model.specs[start:stop])
            pp = list(packed_params[start:stop])
            shape = segment_shape_of(specs, pp, batch)
            row = {}
            for v in reg.applicable_segments(shape, platform):
                if time_source == "analytic":
                    if v.analytic == "fused":
                        t = cm.fused_segment_kernel_time_tpu(specs, batch)
                    else:
                        t = cm.xla_segment_kernel_time_tpu(
                            specs, batch, registry=reg
                        )
                else:
                    fn = v.builder(specs, pp)
                    x_in = layer_inputs[start]
                    t = _timeit(lambda: fn(x_in), repeats)
                row[v.name] = t / batch
            if row:
                table.add_segment_row(batch, start, stop, row)
    return table
