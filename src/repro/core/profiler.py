"""Per-layer latency profiling (paper §III-A, Fig. 4).

For every batch size and every layer, time all 8 implementations:
``CPU`` (host-resident, no boundary cost) and the 7 aspect configs
(kernel time + measured host<->device boundary cost, reproducing the
paper's per-layer H2D/D2H transfers — §IV-A: "data transfer between CPU
and GPU takes place before and after every layer's execution").

Times are stored **seconds per example** so totals are comparable
across batch sizes (the paper profiles the full test set per batch
size; per-example normalization is equivalent).

``time_source='measured'`` times real XLA executables on the host
platform; ``'analytic'`` uses the TPU v5e cost model
(``repro.core.cost_model``) — the dry-run-style path for hardware we
cannot run.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.bnn import layers as L
from repro.bnn.models import BNNModel, prepare_input_packed
from repro.core import cost_model as cm
from repro.core.parallel_config import ASPECT_CONFIGS, CONFIGS, CPU, aspects_of
from repro.kernels.ops import xnor_gemm
from repro.kernels.ref import xnor_gemm_ref
from repro.kernels.variants import xnor_gemm_variant


@dataclasses.dataclass
class ProfileTable:
    model_name: str
    batch_sizes: tuple
    layer_labels: tuple          # e.g. ('L1:C64', 'L2:MP14', ...)
    # times[batch][layer_idx][config] -> seconds per example
    times: dict

    def best_config(self, batch: int, layer: int) -> tuple:
        row = self.times[batch][layer]
        cfg = min(row, key=row.get)
        return cfg, row[cfg]


def _timeit(fn: Callable[[], object], repeats: int) -> float:
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _measure_boundary(x_in: jax.Array, x_out: jax.Array, repeats: int) -> float:
    """Host->device + device->host roundtrip cost for a layer's operand
    and result (the paper's CPU-overhead term for GPU-mapped layers)."""
    x_np = np.asarray(x_in)

    def roundtrip():
        dev = jnp.asarray(x_np)
        jax.block_until_ready(dev)
        return np.asarray(x_out)

    return _timeit(roundtrip, repeats)


def _layer_impls(spec: L.LayerSpec, packed: dict):
    """Return {config: jitted fn} for one layer, all computing the packed
    reference semantics."""
    if spec.kind == "conv":
        w, k_true = packed["w_words"], packed["k_true"]

        def conv_for(cfg):
            aspects = aspects_of(cfg)

            @jax.jit
            def f(x):
                from repro.bnn.layers import extract_patch_words

                b, h, ww, _ = x.shape
                p = extract_patch_words(x).reshape(b, h * ww, -1)
                if cfg == CPU:
                    o = xnor_gemm_ref(p, w, k_true)
                else:
                    o = xnor_gemm_variant(p, w, k_true, frozenset(aspects))
                return o.reshape(b, h, ww, -1)

            return f

        return {cfg: conv_for(cfg) for cfg in CONFIGS}

    if spec.kind == "fc":
        w, k_true = packed["w_words"], packed["k_true"]

        def fc_for(cfg):
            aspects = aspects_of(cfg)

            @jax.jit
            def f(x):
                p = x[:, None, :]
                if cfg == CPU:
                    o = xnor_gemm_ref(p, w, k_true)
                else:
                    o = xnor_gemm_variant(p, w, k_true, frozenset(aspects))
                return o[:, 0, :]

            return f

        return {cfg: fc_for(cfg) for cfg in CONFIGS}

    # mp / step / flat: one computation; parallel configs differ only by
    # the boundary cost the profiler adds (the paper's finding that these
    # layers never win on GPU emerges from measurement, not fiat)
    if spec.kind == "mp":
        f = jax.jit(L.maxpool_packed)
    elif spec.kind == "step":
        t, fl = packed["thresh"], packed["flip"]
        f = jax.jit(lambda x: L.step_packed(x, t, fl))
    elif spec.kind == "flat":
        c = spec.in_shape[-1]
        f = jax.jit(lambda x: L.flat_packed(x, c))
    else:  # pragma: no cover
        raise ValueError(spec.kind)
    return {cfg: f for cfg in CONFIGS}


def _capture_layer_inputs(
    model: BNNModel, packed_params: list, x_words: jax.Array
) -> list:
    """Run the packed reference forward, returning each layer's input."""
    xs = []
    x = x_words
    for spec, p in zip(model.specs, packed_params):
        xs.append(x)
        if spec.kind == "conv":
            x = L.conv_packed(x, p["w_words"], p["k_true"])
        elif spec.kind == "mp":
            x = L.maxpool_packed(x)
        elif spec.kind == "step":
            x = L.step_packed(x, p["thresh"], p["flip"])
        elif spec.kind == "flat":
            x = L.flat_packed(x, spec.in_shape[-1])
        elif spec.kind == "fc":
            x = L.fc_packed(x, p["w_words"], p["k_true"])
    return xs


def profile_bnn_model(
    model: BNNModel,
    packed_params: list,
    *,
    batch_sizes: Sequence[int] = (1, 2, 4, 8, 16, 32, 64, 128),
    configs: Sequence[str] = CONFIGS,
    repeats: int = 3,
    seed: int = 0,
    time_source: str = "measured",
) -> ProfileTable:
    labels = tuple(f"L{s.idx}:{s.notation}" for s in model.specs)
    times: dict = {}
    key = jax.random.PRNGKey(seed)

    for batch in batch_sizes:
        x01 = jax.random.uniform(
            key, (batch, *model.input_hw, model.in_channels)
        )
        x_words = prepare_input_packed(x01)
        layer_inputs = _capture_layer_inputs(model, packed_params, x_words)
        per_layer: list = []
        for spec, packed, x_in in zip(
            model.specs, packed_params, layer_inputs
        ):
            if time_source == "analytic":
                row = {
                    cfg: cm.layer_time_tpu(spec, cfg, batch) / batch
                    for cfg in configs
                }
                per_layer.append(row)
                continue
            impls = _layer_impls(spec, packed)
            x_out = impls[CPU](x_in)
            boundary = _measure_boundary(x_in, x_out, repeats)
            row = {}
            for cfg in configs:
                t = _timeit(lambda f=impls[cfg]: f(x_in), repeats)
                if cfg != CPU:
                    t += boundary
                row[cfg] = t / batch
            per_layer.append(row)
        times[batch] = per_layer

    return ProfileTable(model.name, tuple(batch_sizes), labels, times)
