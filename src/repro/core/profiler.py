"""Per-layer latency profiling (paper §III-A, Fig. 4).

For every batch size and every layer, time all 8 implementations:
``CPU`` (host-resident, no boundary cost) and the 7 aspect configs.

**Kernel/boundary time model.**  Each profiled entry is split into two
independently-stored components:

* ``kernel``  — the layer's compute alone, wherever it is placed;
* ``boundary`` — the host<->device transfer cost of the layer's operand
  (H2D) and result (D2H), measured/modeled **separately** per
  direction and stored per layer in ``h2d_times`` / ``d2h_times``.

The paper-faithful total (``times``) charges non-CPU layers
``kernel + h2d + d2h`` — §IV-A: "data transfer between CPU and GPU
takes place before and after every layer's execution".  The split
exists because the fused executor (``mapped_model.build_mapped_model``
with ``fused=True``) elides the interior transfers between co-placed
device layers; the transfer-aware DP mapper (``mapper`` with
``policy='dp'``) prices exactly that execution: kernel time per layer,
boundary cost only where placement changes host<->device.

Times are stored **seconds per example** so totals are comparable
across batch sizes (the paper profiles the full test set per batch
size; per-example normalization is equivalent).

``time_source='measured'`` times real XLA executables on the host
platform; ``'analytic'`` uses the TPU v5e cost model
(``repro.core.cost_model``) — the dry-run-style path for hardware we
cannot run.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.bnn import layers as L
from repro.bnn.models import BNNModel, prepare_input_packed
from repro.core import cost_model as cm
from repro.core.parallel_config import ASPECT_CONFIGS, CONFIGS, CPU, aspects_of
from repro.kernels.ops import xnor_gemm
from repro.kernels.ref import xnor_gemm_ref
from repro.kernels.variants import xnor_gemm_variant


@dataclasses.dataclass
class ProfileTable:
    model_name: str
    batch_sizes: tuple
    layer_labels: tuple          # e.g. ('L1:C64', 'L2:MP14', ...)
    # times[batch][layer_idx][config] -> seconds per example, paper
    # semantics: kernel + full per-layer boundary for non-CPU configs
    times: dict
    # kernel_times[batch][layer_idx][config] -> kernel-only s/example
    kernel_times: dict | None = None
    # h2d_times/d2h_times[batch][layer_idx] -> boundary s/example for
    # the layer's operand upload / result download (config-independent)
    h2d_times: dict | None = None
    d2h_times: dict | None = None

    def best_config(self, batch: int, layer: int) -> tuple:
        row = self.times[batch][layer]
        cfg = min(row, key=row.get)
        return cfg, row[cfg]

    # -- split accessors (legacy tables without the split degrade to
    #    kernel == total, boundary == 0, under which the DP mapper
    #    reproduces the greedy mapping exactly) ----------------------
    def kernel_time(self, batch: int, layer: int, config: str) -> float:
        if self.kernel_times is not None:
            return self.kernel_times[batch][layer][config]
        return self.times[batch][layer][config]

    def h2d(self, batch: int, layer: int) -> float:
        if self.h2d_times is None:
            return 0.0
        return self.h2d_times[batch][layer]

    def d2h(self, batch: int, layer: int) -> float:
        if self.d2h_times is None:
            return 0.0
        return self.d2h_times[batch][layer]

    def boundary_time(self, batch: int, layer: int, config: str) -> float:
        """Full per-layer roundtrip charged under paper semantics."""
        if config == CPU:
            return 0.0
        return self.h2d(batch, layer) + self.d2h(batch, layer)


def _timeit(fn: Callable[[], object], repeats: int) -> float:
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _measure_h2d(x_in: jax.Array, repeats: int) -> float:
    """Host->device upload cost of a layer's operand."""
    x_np = np.asarray(x_in)

    def upload():
        dev = jnp.asarray(x_np)
        jax.block_until_ready(dev)
        return dev

    return _timeit(upload, repeats)


def _measure_d2h(x_out: jax.Array, repeats: int) -> float:
    """Device->host download cost of a layer's result."""
    return _timeit(lambda: np.asarray(x_out), repeats)


def _layer_impls(spec: L.LayerSpec, packed: dict):
    """Return {config: jitted fn} for one layer, all computing the packed
    reference semantics."""
    if spec.kind == "conv":
        w, k_true = packed["w_words"], packed["k_true"]

        def conv_for(cfg):
            aspects = aspects_of(cfg)

            @jax.jit
            def f(x):
                from repro.bnn.layers import extract_patch_words

                b, h, ww, _ = x.shape
                p = extract_patch_words(x).reshape(b, h * ww, -1)
                if cfg == CPU:
                    o = xnor_gemm_ref(p, w, k_true)
                else:
                    o = xnor_gemm_variant(p, w, k_true, frozenset(aspects))
                return o.reshape(b, h, ww, -1)

            return f

        return {cfg: conv_for(cfg) for cfg in CONFIGS}

    if spec.kind == "fc":
        w, k_true = packed["w_words"], packed["k_true"]

        def fc_for(cfg):
            aspects = aspects_of(cfg)

            @jax.jit
            def f(x):
                p = x[:, None, :]
                if cfg == CPU:
                    o = xnor_gemm_ref(p, w, k_true)
                else:
                    o = xnor_gemm_variant(p, w, k_true, frozenset(aspects))
                return o[:, 0, :]

            return f

        return {cfg: fc_for(cfg) for cfg in CONFIGS}

    # mp / step / flat: one computation; parallel configs differ only by
    # the boundary cost the profiler adds (the paper's finding that these
    # layers never win on GPU emerges from measurement, not fiat)
    if spec.kind == "mp":
        f = jax.jit(L.maxpool_packed)
    elif spec.kind == "step":
        t, fl = packed["thresh"], packed["flip"]
        f = jax.jit(lambda x: L.step_packed(x, t, fl))
    elif spec.kind == "flat":
        c = spec.in_shape[-1]
        f = jax.jit(lambda x: L.flat_packed(x, c))
    else:  # pragma: no cover
        raise ValueError(spec.kind)
    return {cfg: f for cfg in CONFIGS}


def _capture_layer_inputs(
    model: BNNModel, packed_params: list, x_words: jax.Array
) -> list:
    """Run the packed reference forward, returning each layer's input."""
    xs = []
    x = x_words
    for spec, p in zip(model.specs, packed_params):
        xs.append(x)
        if spec.kind == "conv":
            x = L.conv_packed(x, p["w_words"], p["k_true"])
        elif spec.kind == "mp":
            x = L.maxpool_packed(x)
        elif spec.kind == "step":
            x = L.step_packed(x, p["thresh"], p["flip"])
        elif spec.kind == "flat":
            x = L.flat_packed(x, spec.in_shape[-1])
        elif spec.kind == "fc":
            x = L.fc_packed(x, p["w_words"], p["k_true"])
    return xs


def profile_bnn_model(
    model: BNNModel,
    packed_params: list,
    *,
    batch_sizes: Sequence[int] = (1, 2, 4, 8, 16, 32, 64, 128),
    configs: Sequence[str] = CONFIGS,
    repeats: int = 3,
    seed: int = 0,
    time_source: str = "measured",
) -> ProfileTable:
    labels = tuple(f"L{s.idx}:{s.notation}" for s in model.specs)
    times: dict = {}
    kernel_times: dict = {}
    h2d_times: dict = {}
    d2h_times: dict = {}
    key = jax.random.PRNGKey(seed)

    for batch in batch_sizes:
        x01 = jax.random.uniform(
            key, (batch, *model.input_hw, model.in_channels)
        )
        x_words = prepare_input_packed(x01)
        layer_inputs = _capture_layer_inputs(model, packed_params, x_words)
        per_layer: list = []
        per_layer_kernel: list = []
        per_layer_h2d: list = []
        per_layer_d2h: list = []
        for spec, packed, x_in in zip(
            model.specs, packed_params, layer_inputs
        ):
            if time_source == "analytic":
                row, krow = {}, {}
                h2d = d2h = 0.0
                for cfg in configs:
                    kern, th2d, td2h = cm.layer_time_split_tpu(
                        spec, cfg, batch
                    )
                    krow[cfg] = kern / batch
                    row[cfg] = (kern + th2d + td2h) / batch
                    if cfg != CPU:
                        h2d, d2h = th2d / batch, td2h / batch
                per_layer.append(row)
                per_layer_kernel.append(krow)
                per_layer_h2d.append(h2d)
                per_layer_d2h.append(d2h)
                continue
            impls = _layer_impls(spec, packed)
            x_out = impls[CPU](x_in)
            h2d = _measure_h2d(x_in, repeats) / batch
            d2h = _measure_d2h(x_out, repeats) / batch
            row, krow = {}, {}
            for cfg in configs:
                t = _timeit(lambda f=impls[cfg]: f(x_in), repeats) / batch
                krow[cfg] = t
                row[cfg] = t if cfg == CPU else t + h2d + d2h
            per_layer.append(row)
            per_layer_kernel.append(krow)
            per_layer_h2d.append(h2d)
            per_layer_d2h.append(d2h)
        times[batch] = per_layer
        kernel_times[batch] = per_layer_kernel
        h2d_times[batch] = per_layer_h2d
        d2h_times[batch] = per_layer_d2h

    return ProfileTable(
        model.name,
        tuple(batch_sizes),
        labels,
        times,
        kernel_times=kernel_times,
        h2d_times=h2d_times,
        d2h_times=d2h_times,
    )
