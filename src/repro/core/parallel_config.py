"""The per-layer implementation space (paper §II-C / §III-B).

The paper fixes 8 implementations per layer: CPU (sequential,
host-placed) and the 7 parallel configurations over the Data (X) /
Window (Y) / Neuron (Z) aspects — ``CONFIGS`` below, the legacy
fixed-8 space every profile row still contains.

Beyond the paper, the space is **open**: any name registered in
:mod:`repro.kernels.registry` (e.g. ``xla_fused``, ``pallas_p64n64``)
is a valid per-layer config.  ``validate``/``aspects_of`` consult the
registry, so mappings over autotuned variable-size config spaces flow
through the same code paths as the fixed-8 ones.
"""

from __future__ import annotations

CPU = "CPU"
ASPECT_CONFIGS = ("X", "Y", "Z", "XY", "XZ", "YZ", "XYZ")
CONFIGS = (CPU,) + ASPECT_CONFIGS

# paper Fig. 5 baselines
NAIVE_GPU = "X"        # "naive": Data-only everywhere
FULL_GPU = "XYZ"       # "fully-parallel": everything, max parallel


def _registry():
    # deferred: kernels.registry pulls in jax; keep this module cheap
    from repro.kernels import registry

    return registry.DEFAULT_REGISTRY


def aspects_of(config: str) -> tuple:
    """'XZ' -> ('X', 'Z'); 'CPU' -> (); registered variants (e.g.
    'pallas_p64n64') -> their declared aspect metadata."""
    if config == CPU:
        return ()
    if config in CONFIGS:
        return tuple(config)
    reg = _registry()
    if config in reg:
        return tuple(reg.get(config).aspects)
    raise ValueError(f"unknown parallel config {config!r}")


def validate(config: str) -> str:
    """Accept the fixed-8 names and any registered kernel variant."""
    if config in CONFIGS or config in _registry():
        return config
    raise ValueError(f"unknown parallel config {config!r}")


def is_host_config(config: str, registry=None) -> bool:
    """True iff `config` is host-placed (no boundary cost).  The single
    placement authority: ``CPU`` plus any registered variant declaring
    ``placement="host"``; every other *registered* name is
    device-placed.  Unknown names raise (a typo priced as "device"
    would silently corrupt mappings).  Pass `registry` to resolve
    against a custom registry (profiling sweeps); mapping, serving and
    execution resolve against the default registry, so variants used
    beyond profiling must be registered globally."""
    if config == CPU:
        return True
    if config in CONFIGS:
        return False
    reg = registry if registry is not None else _registry()
    if config in reg:
        return reg.placement_of(config) == "host"
    if registry is not None and config in _registry():
        return _registry().placement_of(config) == "host"
    raise ValueError(f"unknown parallel config {config!r}")
