"""The per-layer implementation space (paper §II-C / §III-B).

8 implementations per layer: CPU (sequential, host-placed) and the 7
parallel configurations over the Data (X) / Window (Y) / Neuron (Z)
aspects.
"""

from __future__ import annotations

CPU = "CPU"
ASPECT_CONFIGS = ("X", "Y", "Z", "XY", "XZ", "YZ", "XYZ")
CONFIGS = (CPU,) + ASPECT_CONFIGS

# paper Fig. 5 baselines
NAIVE_GPU = "X"        # "naive": Data-only everywhere
FULL_GPU = "XYZ"       # "fully-parallel": everything, max parallel


def aspects_of(config: str) -> tuple:
    """'XZ' -> ('X', 'Z'); 'CPU' -> ()."""
    if config == CPU:
        return ()
    return tuple(config)


def validate(config: str) -> str:
    if config not in CONFIGS:
        raise ValueError(f"unknown parallel config {config!r}")
    return config
