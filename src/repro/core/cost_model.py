"""Analytic TPU v5e cost model for the xnor/popcount kernels.

Used when the mapping target is real TPU hardware this container cannot
time (``time_source='analytic'`` in the profiler), and for per-layer
roofline terms. Mirrors the roofline constants used in
EXPERIMENTS.md §Roofline.

The aspect configuration enters through the *grid order*: aspect
(parallel) dims are outermost, non-aspect dims innermost (exactly how
the Pallas kernel builds its grid). HBM traffic per operand follows the
classic loop-nest reuse model: a block is (re)loaded once per iteration
of every grid dim at or outside the innermost dim its index depends on.
The parallel-vs-sequential split also sets the core-parallelism factor:
grid iterations on parallel dims spread across ``TENSOR_CORES``.
"""

from __future__ import annotations

import dataclasses
import math

from repro.bnn.layers import LayerSpec
from repro.core.parallel_config import CONFIGS, CPU, aspects_of

# --- TPU v5e hardware constants (per chip) --------------------------------
PEAK_BF16_FLOPS = 197e12          # MXU
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s
VPU_INT_OPS = 4e12                # int32 vector ops/s (VPU, est.)
VMEM_BYTES = 128 * 1024 * 1024    # ~128 MiB v5e VMEM
TENSOR_CORES = 1                  # v5e: single core per chip
DISPATCH_OVERHEAD = 3e-6          # per kernel launch, seconds
HOST_LINK_BW = 16e9               # host<->HBM (PCIe-ish), bytes/s
HOST_LATENCY = 20e-6              # per host<->device boundary crossing
# host CPU executing the layer itself (the paper's CPU device)
CPU_BW = 50e9
CPU_INT_OPS = 2e11

P_BLK = 128
N_BLK = 128


@dataclasses.dataclass(frozen=True)
class GemmDims:
    b: int      # batch (X axis)
    p: int      # windows per image (Y axis)
    n: int      # output neurons (Z axis)
    kw: int     # packed reduction words

    @property
    def a_bytes(self):
        return self.b * self.p * self.kw * 4

    @property
    def w_bytes(self):
        return self.n * self.kw * 4

    @property
    def o_bytes(self):
        return self.b * self.p * self.n * 4

    @property
    def vpu_ops(self):
        # xor + not + popcount + add per word pair
        return 4 * self.b * self.p * self.n * self.kw


def gemm_dims_for(spec: LayerSpec, batch: int) -> GemmDims | None:
    if spec.kind == "conv":
        h, w, cin = spec.in_shape
        return GemmDims(
            b=batch, p=h * w, n=spec.units, kw=9 * math.ceil(cin / 32)
        )
    if spec.kind == "fc":
        return GemmDims(
            b=batch, p=1, n=spec.units, kw=math.ceil(spec.in_shape[0] / 32)
        )
    return None


def variant_analytics(config: str, registry=None) -> tuple:
    """(p_blk, n_blk, kind) pricing metadata for `config`.

    Fixed-8 names price under the model-default blocks; registered
    kernel variants (``repro.kernels.registry``) carry their own tile
    sizes and traffic kind (``"tiled"`` loop-nest reuse, ``"fused"``
    single pass, ``"host"`` CPU-side).  `registry` overrides the
    default registry for custom profiling sweeps.
    """
    if config == CPU:
        return P_BLK, N_BLK, "host"
    if config in CONFIGS:
        return P_BLK, N_BLK, "tiled"
    if registry is None:
        from repro.kernels.registry import DEFAULT_REGISTRY

        registry = DEFAULT_REGISTRY
    v = registry.get(config)
    return v.p_blk or P_BLK, v.n_blk or N_BLK, v.analytic


def _aspects_of(config: str, registry=None) -> tuple:
    if registry is not None and config not in CONFIGS and config in registry:
        return tuple(registry.get(config).aspects)
    return aspects_of(config)


def _grid(dims: GemmDims, config: str, registry=None):
    """(ordered axis names, sizes, parallel flags) as the kernel builds
    them: aspects outermost; block sizes from the variant's metadata."""
    aspects = set(_aspects_of(config, registry))
    p_blk, n_blk, _ = variant_analytics(config, registry)
    sizes = {
        "X": dims.b,
        "Y": math.ceil(dims.p / min(p_blk, dims.p)),
        "Z": math.ceil(dims.n / min(n_blk, dims.n)),
    }
    order = [a for a in ("X", "Y", "Z") if a in aspects] + [
        a for a in ("X", "Y", "Z") if a not in aspects
    ]
    return order, sizes, aspects


def gemm_hbm_traffic(dims: GemmDims, config: str, registry=None) -> float:
    """Bytes moved HBM<->VMEM under the loop-nest reuse model."""
    order, sizes, _ = _grid(dims, config, registry)
    blk_p, blk_n, _ = variant_analytics(config, registry)
    p_blk, n_blk = min(blk_p, dims.p), min(blk_n, dims.n)
    deps = {"a": {"X", "Y"}, "w": {"Z"}, "o": {"X", "Y", "Z"}}
    block_bytes = {
        "a": p_blk * dims.kw * 4,
        "w": n_blk * dims.kw * 4,
        "o": p_blk * n_blk * 4,
    }
    total = 0.0
    for t, dep in deps.items():
        depth = max(order.index(d) for d in dep)
        loads = 1
        for d in order[: depth + 1]:
            loads *= sizes[d]
        total += loads * block_bytes[t]
    return total


def gemm_kernel_time_tpu(dims: GemmDims, config: str, registry=None) -> float:
    """Kernel-only seconds for one xnor-GEMM dispatch under `config` —
    no host<->device transfer term.

    compute and memory terms overlap (max), parallel aspect dims spread
    over TENSOR_CORES, sequential dims serialize dispatch-free.
    """
    _, _, kind = variant_analytics(config, registry)
    if kind == "host":
        bytes_ = dims.a_bytes + dims.w_bytes + dims.o_bytes
        return max(bytes_ / CPU_BW, dims.vpu_ops / CPU_INT_OPS)
    order, sizes, aspects = _grid(dims, config, registry)
    par = 1
    for a in aspects:
        par *= sizes[a]
    core_par = min(TENSOR_CORES, max(par, 1))
    compute = dims.vpu_ops / (VPU_INT_OPS * core_par)
    if kind == "fused":
        # single fused dispatch: each operand crosses HBM exactly once
        traffic = dims.a_bytes + dims.w_bytes + dims.o_bytes
    else:
        traffic = gemm_hbm_traffic(dims, config, registry)
    memory = traffic / HBM_BW
    return max(compute, memory) + DISPATCH_OVERHEAD


def gemm_transfer_times_tpu(dims: GemmDims) -> tuple:
    """(h2d, d2h) boundary seconds: operand upload / result download."""
    h2d = HOST_LATENCY + dims.a_bytes / HOST_LINK_BW
    d2h = HOST_LATENCY + dims.o_bytes / HOST_LINK_BW
    return h2d, d2h


def _is_host(config: str, registry=None) -> bool:
    from repro.core.parallel_config import is_host_config

    return is_host_config(config, registry)


def _split(kernel: float, transfers: tuple, config: str, registry=None) -> tuple:
    """The single placement-charging rule: host placements have no
    boundary cost, device placements carry the layer's (h2d, d2h)."""
    if _is_host(config, registry):
        return kernel, 0.0, 0.0
    h2d, d2h = transfers
    return kernel, h2d, d2h


def gemm_time_tpu(dims: GemmDims, config: str) -> float:
    """Paper-faithful per-dispatch seconds: kernel plus the full
    per-layer H2D+D2H boundary for device placements (§IV-A)."""
    return sum(
        _split(
            gemm_kernel_time_tpu(dims, config),
            gemm_transfer_times_tpu(dims),
            config,
        )
    )


def elementwise_kernel_time_tpu(
    spec: LayerSpec, config: str, batch: int, registry=None
) -> float:
    """mp / step / flat layers: pure memory-bound, kernel term only."""
    import numpy as np

    elems = batch * int(np.prod(spec.in_shape))
    bytes_ = elems * 4 * 2
    if _is_host(config, registry):
        return bytes_ / CPU_BW
    return bytes_ / HBM_BW + DISPATCH_OVERHEAD


def elementwise_transfer_times_tpu(spec: LayerSpec, batch: int) -> tuple:
    """(h2d, d2h) for an elementwise layer (operand in, result out)."""
    import numpy as np

    elems = batch * int(np.prod(spec.in_shape))
    h2d = HOST_LATENCY + elems * 4 / HOST_LINK_BW
    d2h = HOST_LATENCY + elems * 4 / HOST_LINK_BW
    return h2d, d2h


def elementwise_time_tpu(spec: LayerSpec, config: str, batch: int) -> float:
    return sum(
        _split(
            elementwise_kernel_time_tpu(spec, config, batch),
            elementwise_transfer_times_tpu(spec, batch),
            config,
        )
    )


def layer_time_split_tpu(
    spec: LayerSpec, config: str, batch: int, registry=None
) -> tuple:
    """(kernel_s, h2d_s, d2h_s) for one layer at `batch`.

    The transfer terms are placement costs of the layer's operand and
    result, independent of which aspect config runs the kernel; they are
    charged (or elided) by the mapper, not folded into the kernel time.
    CPU placement reports zero transfer.
    """
    dims = gemm_dims_for(spec, batch)
    if dims is None:
        return _split(
            elementwise_kernel_time_tpu(spec, config, batch, registry),
            elementwise_transfer_times_tpu(spec, batch),
            config,
            registry,
        )
    return _split(
        gemm_kernel_time_tpu(dims, config, registry),
        gemm_transfer_times_tpu(dims),
        config,
        registry,
    )


def layer_time_tpu(spec: LayerSpec, config: str, batch: int) -> float:
    kern, h2d, d2h = layer_time_split_tpu(spec, config, batch)
    return kern + h2d + d2h


def fused_segment_kernel_time_tpu(specs, batch: int) -> float:
    """Kernel-only seconds for a whole device segment executed as
    **one** fused dispatch (``kernels.segment_fused.seg_pallas``-style):
    interior activations live in VMEM, so HBM traffic is a single pass
    over the segment's edge activations (in their edge encodings) plus
    every parameter array — intermediate results contribute compute
    but zero HBM bytes — and exactly one dispatch overhead.

    Compared with the per-layer sum this drops (a) each interior
    layer's unpacked activation write + read, (b) all but one dispatch
    overhead; compute is unchanged.  The fused price is therefore
    <= the per-layer kernel sum by construction, which is what lets
    the DP/selector prefer fused execution wherever it is applicable.
    """
    from repro.kernels.segment_fused import (
        encoded_shape,
        infer_in_encoding,
        segment_out_encoding,
    )

    specs = tuple(specs)
    in_enc = infer_in_encoding(specs)
    out_enc = segment_out_encoding(specs, in_enc)

    compute_ops = 0.0
    param_bytes = 0.0
    for spec in specs:
        dims = gemm_dims_for(spec, batch)
        if dims is None:
            # elementwise work still runs on the VPU, just without the
            # HBM round-trip
            import numpy as np

            compute_ops += 2 * batch * int(np.prod(spec.in_shape))
            if spec.kind == "step":
                param_bytes += spec.units * 4 * 2    # thresh + flip
        else:
            compute_ops += dims.vpu_ops
            param_bytes += dims.w_bytes

    def _edge_bytes(shape, enc) -> float:
        n = 1
        for d in encoded_shape(shape, enc):
            n *= d
        return batch * n * 4

    traffic = (
        _edge_bytes(specs[0].in_shape, in_enc)
        + _edge_bytes(specs[-1].out_shape, out_enc)
        + param_bytes
    )
    core_par = min(TENSOR_CORES, max(batch, 1))
    compute = compute_ops / (VPU_INT_OPS * core_par)
    return max(compute, traffic / HBM_BW) + DISPATCH_OVERHEAD


def xla_segment_kernel_time_tpu(specs, batch: int, registry=None) -> float:
    """Kernel-only seconds for a segment jitted as one XLA executable
    (``seg_xla``): per-layer single-pass traffic (XLA materializes the
    GEMM outputs but fuses the elementwise tails) with one dispatch
    for the whole chain.  Sits between the per-layer sum and the fully
    fused price — elementwise layers fuse into their producers (no
    separate traffic term), GEMM activations still cross HBM."""
    total = 0.0
    for spec in specs:
        dims = gemm_dims_for(spec, batch)
        if dims is None:
            continue                    # fused into the producer GEMM
        total += gemm_kernel_time_tpu(dims, "xla_fused", registry)
        total -= DISPATCH_OVERHEAD
    return total + DISPATCH_OVERHEAD


def plan_node_times(plan) -> tuple:
    """Seconds per plan node — the IR's own kernel/boundary
    annotations (``core.plan.build_plan`` attributes them with the
    same charging rule as :func:`segment_times_from_split`: transfers
    only at placement changes, encoding conversions folded into the
    op that performs them, fused nodes priced at their profiled fused
    time)."""
    return tuple(n.kernel_s + n.boundary_s for n in plan.nodes)


def segment_times_from_split(
    segments, kernels, boundaries
) -> tuple:
    """Seconds per segment for a configuration's kernel/boundary split
    — the per-segment generalization of the host/device stage split.

    ``segments`` is any sequence of objects with ``start``/``stop``/
    ``on_device`` (``repro.core.mapper.Segment`` duck-typed, so this
    module stays import-free of the mapper); ``kernels``/``boundaries``
    are the per-layer attributions.  Pricing matches the segment
    executor: a device segment charges boundary only on its edge layers
    (for ``policy="dp"`` attributions interior boundaries are zero
    anyway; for greedy ones the interior roundtrips the executor elides
    are dropped here too), host segments charge every layer's stored
    boundary (zero for CPU placements by construction).

    These predictions are what the adaptive runtime's
    ``DriftDetector`` compares live telemetry against
    (``repro.adapt``), and what ``EfficientConfiguration.stage_times``
    aggregates into the two pipeline stages.
    """
    out = []
    for seg in segments:
        t = 0.0
        for i in range(seg.start, seg.stop):
            t += kernels[i]
            if seg.on_device:
                if i in (seg.start, seg.stop - 1):
                    t += boundaries[i]
            else:
                t += boundaries[i]
        out.append(t)
    return tuple(out)


def contention_inflation(
    co_runner_share: float, gamma: float = 1.0, *, law=None
) -> float:
    """Kernel-time inflation factor for a tenant whose co-runners
    occupy ``co_runner_share`` of a processor's time.

    Processor-sharing model: a co-runner that demands *s* seconds of a
    processor per second of wall clock steals ``s`` of every second,
    stretching this tenant's kernels on that processor by ``1 + s``
    (``gamma`` scales the coupling — <1 models partial overlap, e.g. a
    device whose queues interleave better than a timesliced host).
    Linear in the share, so inflation is monotone: adding co-runner
    load never makes a placement look faster — the property the fleet
    mapper's descent relies on (``repro.fleet.scheduler``).

    ``law`` swaps the assumed linear model for a **calibrated** one —
    any object with ``inflation(share) -> factor`` honoring the
    fitted-law contract (``repro.estimator.interference``: fixed
    point 1 at share 0, >= 1, monotone non-decreasing), typically a
    ``FittedInterference`` recovered from ledger traces.  When given,
    ``gamma`` is ignored.
    """
    if law is not None:
        return float(law.inflation(max(0.0, co_runner_share)))
    if gamma < 0.0:
        raise ValueError("gamma must be non-negative")
    return 1.0 + gamma * max(0.0, co_runner_share)


def inflate_profile(
    table,
    *,
    host_factor: float = 1.0,
    device_factor: float = 1.0,
    registry=None,
):
    """A contention-inflated copy of a ``ProfileTable``: kernel times
    of host-placed configs scale by ``host_factor``, device-placed
    kernels *and* the h2d/d2h boundary rows by ``device_factor`` (the
    transfer link is device-side occupancy — a contended device delays
    its uploads too).  Totals are rebuilt under paper semantics
    (device rows carry the full roundtrip).  Factors of 1.0 share the
    original rows per batch rather than copying.

    This is the per-tenant view ``repro.fleet.scheduler.map_fleet``
    re-runs the DP mapper against: the same table, repriced as if the
    tenant's co-runners were already resident.
    """
    from repro.core.profiler import ProfileTable

    if host_factor <= 0.0 or device_factor <= 0.0:
        raise ValueError("inflation factors must be positive")
    if host_factor == 1.0 and device_factor == 1.0:
        return table

    times: dict = {}
    kernels: dict = {}
    h2d: dict = {}
    d2h: dict = {}
    for b in table.batch_sizes:
        times[b], kernels[b] = [], []
        h2d[b] = [table.h2d(b, i) * device_factor
                  for i in range(len(table.layer_labels))]
        d2h[b] = [table.d2h(b, i) * device_factor
                  for i in range(len(table.layer_labels))]
        for i in range(len(table.layer_labels)):
            krow, trow = {}, {}
            for cfg in table.configs_for(b, i):
                host = _is_host(cfg, registry)
                k = table.kernel_time(b, i, cfg) * (
                    host_factor if host else device_factor
                )
                krow[cfg] = k
                trow[cfg] = k if host else k + h2d[b][i] + d2h[b][i]
            kernels[b].append(krow)
            times[b].append(trow)
    return ProfileTable(
        model_name=table.model_name,
        batch_sizes=table.batch_sizes,
        layer_labels=table.layer_labels,
        times=times,
        kernel_times=kernels,
        h2d_times=h2d,
        d2h_times=d2h,
    )


def pipeline_makespan(
    host_s: float, device_s: float, n_microbatches: int
) -> float:
    """Makespan of a two-stage software pipeline over a micro-batch
    stream (the serving runtime in ``repro.serving.pipeline``).

    Stage H (host segments, ``host_s`` seconds per micro-batch) and
    stage D (device segments plus boundary transfers, ``device_s``)
    overlap across micro-batches: while micro-batch *i* occupies the
    device, micro-batch *i+1* runs its host segments.  The classic
    fill-drain formula::

        makespan = host_s + device_s + (n - 1) * max(host_s, device_s)

    For n == 1 this is the serial latency; the steady-state rate is one
    micro-batch per max(host_s, device_s), which is what
    ``EfficientConfiguration.pipelined_expected_time`` reports per
    example.
    """
    if n_microbatches <= 0:
        return 0.0
    return (
        host_s
        + device_s
        + (n_microbatches - 1) * max(host_s, device_s)
    )
