"""HEP-BNN core — the paper's primary contribution.

* :mod:`parallel_config` — the per-layer implementation space: the
  paper's fixed 8 (CPU + 7 X/Y/Z aspect configurations) plus any name
  registered in the open kernel-variant registry
  (:mod:`repro.kernels.registry`).
* :mod:`profiler` — per-layer latency profiling across implementations
  and batch sizes, including host<->device boundary costs; the
  registry-driven ``autotune_bnn_model`` sweep produces variable-size
  per-layer config spaces with warm-up pruning.
* :mod:`mapper` — layer-to-implementation mapping: the paper's greedy
  Algorithm 1 (``policy="greedy"``) and the transfer-aware Viterbi DP
  (``policy="dp"``) -> EfficientConfiguration, whose ``segments()``
  splits the mapping into the same-placement runs the serving runtime
  (:mod:`repro.serving`) executes.
* :mod:`mapped_model` — builds the executable model from an
  EfficientConfiguration (the JAX analogue of the paper's generated
  CUDA/C++ code): fused and paper-faithful whole-model drivers plus
  ``build_segment_fns`` for the segment pipeline.
* :mod:`cost_model` — analytic TPU v5e cost model (roofline terms per
  layer x config) used when the target hardware is not the host.
* :mod:`hep_shard` — the paper's algorithm lifted to multi-pod scale:
  per-layer-class sharding-scheme selection driven by compiled dry-run
  roofline costs.
"""

from repro.core.parallel_config import (
    CONFIGS,
    ASPECT_CONFIGS,
    aspects_of,
    is_host_config,
)
from repro.core.mapper import (
    EfficientConfiguration,
    Segment,
    configuration_from_mapping,
    map_efficient_configuration,
    price_mapping,
    segments_of,
    uniform_total,
)
from repro.core.profiler import (
    ProfileTable,
    autotune_bnn_model,
    profile_bnn_model,
)
from repro.core.mapped_model import build_mapped_model, build_segment_fns
