"""Build the executable model from an EfficientConfiguration — the JAX
analogue of the paper's generated CUDA/C++ (§III-E).

Two build modes:

* ``fused=True`` (beyond-paper): one jitted function; layer boundaries
  between same-placement layers carry no host roundtrip — the
  optimization the paper names as future work ("data transfer ...
  takes place before and after every layer's execution ... can be
  adapted in future works").
* ``fused=False`` (paper-faithful): a Python driver that executes each
  layer's jitted implementation separately with an explicit host
  roundtrip around every non-CPU layer, reproducing the cost structure
  the profiler measured.

The faithful driver honors the mapping policy's transfer semantics:
for a ``policy="dp"`` configuration (or with ``elide_transfers=True``)
it keeps the activation on the device across consecutive non-CPU
layers and only crosses the host boundary where the placement changes
— exactly the cost model the DP mapper optimizes.

A third consumer is the serving runtime: :func:`build_segment_fns`
compiles one jitted callable per *segment* of the configuration
(``EfficientConfiguration.segments()`` — maximal same-placement layer
runs), which ``repro.serving.pipeline.SegmentPipeline`` executes as a
two-stage host/device software pipeline behind the micro-batching
front end in ``repro.serving.engine.ServingEngine``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.bnn import layers as L
from repro.bnn.models import BNNModel
from repro.core.mapper import EfficientConfiguration
from repro.core.parallel_config import is_host_config
from repro.kernels.registry import DEFAULT_REGISTRY


def _layer_fn(spec, packed, config: str, registry=None) -> Callable:
    """The layer's computation under `config`, resolved through the
    kernel-variant registry — any registered name (fixed-8 aspect
    config, ``xla_fused``, a Pallas tile variant, ...) is executable.
    `registry` overrides the default resolver (matching a custom
    registry passed to ``autotune_bnn_model``)."""
    reg = registry if registry is not None else DEFAULT_REGISTRY
    if spec.kind == "conv":
        w, k_true = packed["w_words"], packed["k_true"]
        builder = reg.get(config).builder

        def f(x):
            b, h, ww, _ = x.shape
            p = L.extract_patch_words(x).reshape(b, h * ww, -1)
            return builder(p, w, k_true).reshape(b, h, ww, -1)

        return f
    if spec.kind == "fc":
        w, k_true = packed["w_words"], packed["k_true"]
        builder = reg.get(config).builder

        def f(x):
            return builder(x[:, None, :], w, k_true)[:, 0, :]

        return f
    if spec.kind == "mp":
        return L.maxpool_packed
    if spec.kind == "step":
        t, fl = packed["thresh"], packed["flip"]
        return lambda x: L.step_packed(x, t, fl)
    if spec.kind == "flat":
        c = spec.in_shape[-1]
        return lambda x: L.flat_packed(x, c)
    raise ValueError(spec.kind)


def _layer_fns(
    model: BNNModel,
    packed_params: list,
    config: EfficientConfiguration,
    registry=None,
) -> list:
    """Per-layer callables under the mapping — the single source both
    the whole-model drivers and the segment builder compose from."""
    return [
        _layer_fn(spec, packed, cfg, registry)
        for spec, packed, cfg in zip(
            model.specs, packed_params, config.layer_configs
        )
    ]


def build_mapped_model(
    model: BNNModel,
    packed_params: list,
    config: EfficientConfiguration,
    *,
    fused: bool = True,
    elide_transfers: bool | None = None,
    registry=None,
) -> Callable:
    """Returns fn(packed_input_words) -> int32 class scores, executing
    each layer with its mapped implementation.

    ``elide_transfers`` applies to the faithful (``fused=False``)
    driver only: ``True`` crosses the host boundary solely where
    consecutive layers change placement, ``False`` round-trips around
    every non-CPU layer (paper §IV-A).  ``None`` follows the mapping
    policy — DP configurations were priced under elision.
    """
    fns = _layer_fns(model, packed_params, config, registry)

    if fused:
        @jax.jit
        def run(x_words):
            x = x_words
            for f in fns:
                x = f(x)
            return x

        return run

    if elide_transfers is None:
        elide_transfers = getattr(config, "policy", "greedy") == "dp"

    jitted = [jax.jit(f) for f in fns]
    cfgs = config.layer_configs

    def run_faithful(x_words):
        x = np.asarray(x_words)  # input starts on host
        for i, (f, cfg) in enumerate(zip(jitted, cfgs)):
            xd = jnp.asarray(x)
            out = f(xd)
            jax.block_until_ready(out)
            if is_host_config(cfg, registry):
                x = out
            elif (
                elide_transfers
                and i + 1 < len(cfgs)
                and not is_host_config(cfgs[i + 1], registry)
            ):
                # co-placed successor: stay resident on the device
                x = out
            else:
                # device layers round-trip through the host (§IV-A)
                x = np.asarray(out)
        return np.asarray(x)

    return run_faithful


def build_segment_fns(
    model: BNNModel,
    packed_params: list,
    config: EfficientConfiguration,
    registry=None,
) -> list:
    """One jitted callable per segment of `config`, in execution order.

    Returns ``[(Segment, fn), ...]`` where each fn composes the
    segment's layer implementations into a single XLA executable —
    interior layer boundaries carry no host roundtrip, matching the
    elision the DP mapper priced.  All arithmetic is integer/bool, so
    composition is bit-exact versus per-layer execution.
    """
    fns = _layer_fns(model, packed_params, config, registry)

    def segment_fn(seg):
        seg_fns = fns[seg.start : seg.stop]

        @jax.jit
        def run(x):
            for f in seg_fns:
                x = f(x)
            return x

        return run

    return [(seg, segment_fn(seg)) for seg in config.segments()]
