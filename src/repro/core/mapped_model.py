"""Build executables from an EfficientConfiguration — the JAX analogue
of the paper's generated CUDA/C++ (§III-E), refactored around the
:mod:`repro.core.plan` IR.

There is **one** executor.  Every execution style is a plan shape, not
a separate driver:

    config --build_plan(mode)--> SegmentPlan --build_node_fns--> fns
                                                     |
                                              run_plan(fns)

* ``build_mapped_model(fused=True)`` — the ``"whole"`` plan: one node
  spanning the network, compiled as a single jitted function (layer
  boundaries carry no host roundtrip — the optimization the paper
  names as future work).
* ``build_mapped_model(fused=False)`` — per-layer plan nodes executed
  by the Python driver with an explicit sync per node: mode
  ``"layers"`` crosses the host boundary only at placement changes
  (the elision the DP priced), mode ``"roundtrip"`` round-trips around
  every device layer (paper §IV-A).
* ``build_segment_fns`` — the ``"segments"`` plan: one executable per
  same-placement segment, consumed by the serving pipeline
  (``repro.serving.pipeline.SegmentPipeline``).

A plan node with a ``fused_variant`` resolves to a *segment-scope*
kernel from the variant registry (``repro.kernels.segment_fused``):
the whole node runs as one fused dispatch with activations staying
bit-packed between its layers.  Nodes without one compose their
layers' per-layer implementations under a single jit — bit-exact
either way, since all arithmetic is integer/bool.
"""

from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from repro.bnn import layers as L
from repro.bnn.models import BNNModel
from repro.core.mapper import EfficientConfiguration
from repro.core.plan import SegmentPlan, build_plan
from repro.kernels.registry import DEFAULT_REGISTRY, SCOPE_SEGMENT


def _layer_fn(spec, packed, config: str, registry=None) -> Callable:
    """The layer's computation under `config`, resolved through the
    kernel-variant registry — any registered name (fixed-8 aspect
    config, ``xla_fused``, a Pallas tile variant, ...) is executable.
    `registry` overrides the default resolver (matching a custom
    registry passed to ``autotune_bnn_model``)."""
    reg = registry if registry is not None else DEFAULT_REGISTRY
    if spec.kind == "conv":
        w, k_true = packed["w_words"], packed["k_true"]
        builder = reg.get(config).builder

        def f(x):
            b, h, ww, _ = x.shape
            p = L.extract_patch_words(x).reshape(b, h * ww, -1)
            return builder(p, w, k_true).reshape(b, h, ww, -1)

        return f
    if spec.kind == "fc":
        w, k_true = packed["w_words"], packed["k_true"]
        builder = reg.get(config).builder

        def f(x):
            return builder(x[:, None, :], w, k_true)[:, 0, :]

        return f
    if spec.kind == "mp":
        return L.maxpool_packed
    if spec.kind == "step":
        t, fl = packed["thresh"], packed["flip"]
        return lambda x: L.step_packed(x, t, fl)
    if spec.kind == "flat":
        c = spec.in_shape[-1]
        return lambda x: L.flat_packed(x, c)
    raise ValueError(spec.kind)


def _layer_fns(
    model: BNNModel,
    packed_params: list,
    config: EfficientConfiguration,
    registry=None,
) -> list:
    """Per-layer callables under the mapping — what plan nodes without
    a fused variant compose from."""
    return [
        _layer_fn(spec, packed, cfg, registry)
        for spec, packed, cfg in zip(
            model.specs, packed_params, config.layer_configs
        )
    ]


def build_node_fns(
    model: BNNModel,
    packed_params: list,
    config: EfficientConfiguration,
    plan: SegmentPlan,
    registry=None,
) -> list:
    """One jitted callable per plan node, in execution order:
    ``[(PlanNode, fn), ...]``.

    A node carrying a ``fused_variant`` resolves that segment-scope
    variant's builder over the node's layer slice (one fused dispatch,
    activations bit-packed between the node's layers); any other node
    jits the composition of its layers' per-layer implementations.
    """
    reg = registry if registry is not None else DEFAULT_REGISTRY
    fns = _layer_fns(model, packed_params, config, registry)
    out = []
    for node in plan.nodes:
        if node.fused_variant is not None:
            variant = reg.get(node.fused_variant)
            if variant.scope != SCOPE_SEGMENT:
                raise ValueError(
                    f"plan node [{node.start}:{node.stop}] names "
                    f"{node.fused_variant!r} as fused variant, but its "
                    f"registry scope is {variant.scope!r}"
                )
            fn = variant.builder(
                tuple(model.specs[node.start:node.stop]),
                list(packed_params[node.start:node.stop]),
                node.in_encoding,
            )
        else:
            fn = _compose(fns[node.start:node.stop])
        out.append((node, fn))
    return out


def _compose(layer_fns) -> Callable:
    layer_fns = tuple(layer_fns)

    @jax.jit
    def fn(x):
        for f in layer_fns:
            x = f(x)
        return x

    return fn


def run_plan(node_fns, *, device=None) -> Callable:
    """The plan interpreter: ``fn(x_words) -> np.ndarray`` walking the
    nodes with the transfer/sync structure the plan encodes — H2D
    (``jax.device_put``) before a ``transfer_in`` node, a blocking
    sync after every node (the per-node cost structure the profiler
    measured), D2H (``np.asarray``) after a ``transfer_out`` node.
    Between co-placed nodes the activation stays where it is."""
    dev = device if device is not None else jax.devices()[0]

    def run(x_words):
        x = np.asarray(x_words)          # input starts on the host
        for node, fn in node_fns:
            if node.transfer_in and not isinstance(x, jax.Array):
                x = jax.device_put(x, dev)
            out = fn(x)
            jax.block_until_ready(out)
            x = np.asarray(out) if node.transfer_out else out
        return np.asarray(x)

    return run


def build_mapped_model(
    model: BNNModel,
    packed_params: list,
    config: EfficientConfiguration,
    *,
    fused: bool = True,
    elide_transfers: bool | None = None,
    registry=None,
) -> Callable:
    """Returns fn(packed_input_words) -> int32 class scores, executing
    each layer with its mapped implementation.

    ``fused=True`` lowers the ``"whole"`` plan and returns its single
    jitted node directly — one XLA executable, no interior host
    roundtrips.

    ``elide_transfers`` applies to the faithful (``fused=False``)
    driver only: ``True`` (plan mode ``"layers"``) crosses the host
    boundary solely where consecutive layers change placement,
    ``False`` (mode ``"roundtrip"``) round-trips around every non-CPU
    layer (paper §IV-A).  ``None`` follows the mapping policy — DP
    configurations were priced under elision.
    """
    if fused:
        plan = build_plan(config, mode="whole")
        [(node, fn)] = build_node_fns(
            model, packed_params, config, plan, registry
        )
        return fn

    if elide_transfers is None:
        elide_transfers = getattr(config, "policy", "greedy") == "dp"
    plan = build_plan(
        config, mode="layers" if elide_transfers else "roundtrip"
    )
    node_fns = build_node_fns(model, packed_params, config, plan, registry)
    return run_plan(node_fns)


def build_segment_fns(
    model: BNNModel,
    packed_params: list,
    config: EfficientConfiguration,
    registry=None,
) -> list:
    """One executable per segment of `config`, in execution order —
    the ``"segments"`` plan's node functions.

    Returns ``[(PlanNode, fn), ...]``; ``PlanNode`` duck-types
    ``mapper.Segment`` so existing consumers (the serving pipeline,
    telemetry observers, the fleet ledger) are unchanged.  Device
    segments selected for fusion (``config.fused_segments``) execute
    as one fused kernel with activations bit-packed end to end;
    everything else composes the per-layer implementations under one
    jit.  All arithmetic is integer/bool, so both forms are bit-exact
    versus per-layer execution.
    """
    plan = build_plan(config, mode="segments")
    return build_node_fns(model, packed_params, config, plan, registry)
