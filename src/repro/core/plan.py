"""Segment execution plan IR — the typed object every executor runs.

HEP-BNN's unit of reasoning is the *same-placement segment*
(``mapper.segments_of``): the mapper prices boundary transfers only
where placement changes, and the serving pipeline moves activations
only at segment edges.  This module makes that schedule an explicit,
inspectable IR instead of a convention each driver re-implements:

    EfficientConfiguration --build_plan()--> SegmentPlan
                                                |
              mapped_model.build_node_fns / run_plan (one executor)
                                                |
            serving.SegmentPipeline  /  benchmarks  /  tests

A :class:`SegmentPlan` is a sequence of :class:`PlanNode`\\ s.  Each
node carries

* ``placement`` + ``transfer_in``/``transfer_out`` — where the node
  runs and whether the activation crosses the host<->device boundary
  at its edges.  Transfers appear **only at placement changes** (plus
  the paper's per-layer roundtrip mode), never inside a node.
* ``ops`` — the node's :class:`LayerOp`\\ s, each annotated with its
  activation *encoding* on entry and exit: ``"packed"`` (int32
  bitplane words, 32 binary activations per word) or ``"unpacked"``
  (one int32 pre-activation per element).  Encodings are derived from
  the layer kinds (conv/fc consume packed and produce unpacked
  pre-activations; step thresholds unpacked back to packed; mp
  preserves; flat reshapes packed), and :func:`build_plan` *proves*
  the chain is consistent: adjacent ops always agree, so no executor
  ever packs/unpacks between layers — an encoding conversion happens
  exactly once, inside the op that changes it.  A layer sequence whose
  encodings cannot chain raises :class:`PlanError` instead of
  executing garbage.
* ``kernel_s`` / ``boundary_s`` — the priced cost of the node, the
  same attribution ``cost_model.segment_times_from_split`` charges
  (boundary only on device-segment edge layers).
* ``fused_variant`` — optionally, the name of a *segment-scope* kernel
  variant (``repro.kernels.segment_fused``) that executes the whole
  node as one fused kernel with activations staying bit-packed in
  on-chip memory; ``None`` composes the per-layer implementations
  under one jit.

Build modes (``build_plan(config, mode=...)``) reproduce every
pre-existing driver as a plan shape rather than separate code paths:

* ``"segments"`` (default) — one node per same-placement segment; the
  schedule the DP priced and the serving pipeline runs.
* ``"layers"`` — one node per layer, transfers only at placement
  changes (the faithful driver with elision).
* ``"roundtrip"`` — one node per layer, device nodes transfer on both
  sides (paper §IV-A's per-layer roundtrip execution model).
* ``"whole"`` — a single node spanning the network (the fully fused
  jit; transfers are XLA's concern).

Fused-variant selection (:func:`select_fused_segments`) compares each
device segment's per-layer kernel sum against the profiled
segment-variant times in the :class:`ProfileTable` and records the
winners on ``EfficientConfiguration.fused_segments`` — taking the
minimum, so a fused plan is never priced worse than per-layer
execution (the per-layer composition is always a candidate).
"""

from __future__ import annotations

import dataclasses
import json

from repro.core.mapper import (
    DEVICE,
    HOST,
    EfficientConfiguration,
)

PACKED = "packed"        # int32 bitplane words, 32 activations/word
UNPACKED = "unpacked"    # one int32 pre-activation per element

MODES = ("segments", "layers", "roundtrip", "whole")


class PlanError(ValueError):
    """The layer sequence (or plan input) cannot form a valid plan —
    e.g. adjacent layers whose activation encodings cannot chain."""


def kind_of_label(label: str) -> str:
    """Layer kind from a profile label (``"L3:MP14" -> "mp"``).  The
    token after ``Li:`` is the paper's notation; prefix-matched with
    the longer tokens first so ``FLAT``/``FC`` never read as conv."""
    token = label.split(":", 1)[-1]
    for prefix, kind in (
        ("MP", "mp"), ("FLAT", "flat"), ("FC", "fc"),
        ("C", "conv"), ("S", "step"),
    ):
        if token.startswith(prefix):
            return kind
    raise PlanError(f"unrecognized layer label {label!r}")


# (in_encoding, out_encoding) demanded/produced by each kind; mp is
# absent because it preserves whatever encoding flows in
_KIND_ENCODINGS = {
    "conv": (PACKED, UNPACKED),
    "fc": (PACKED, UNPACKED),
    "step": (UNPACKED, PACKED),
    "flat": (PACKED, PACKED),
}


def layer_encodings(kinds) -> tuple:
    """Per-layer (in_encoding, out_encoding) for a kind sequence,
    chained from the packed network input (``prepare_input_packed``).
    Raises :class:`PlanError` where a layer's required input encoding
    does not match its predecessor's output — such a sequence has no
    bit-exact executor (feeding unpacked pre-activations to a packed
    GEMM computes garbage), so it must not silently build."""
    out = []
    cur = PACKED
    for i, kind in enumerate(kinds):
        if kind == "mp":
            out.append((cur, cur))
            continue
        if kind not in _KIND_ENCODINGS:
            raise PlanError(f"unknown layer kind {kind!r} at layer {i}")
        need, prod = _KIND_ENCODINGS[kind]
        if cur != need:
            raise PlanError(
                f"encoding mismatch at layer {i} ({kind}): requires "
                f"{need} input but predecessor produces {cur}; insert "
                f"a step layer (unpacked->packed) to rebinarize"
            )
        out.append((need, prod))
        cur = prod
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class LayerOp:
    """One layer inside a plan node."""

    index: int            # layer index in the model
    kind: str             # conv | mp | step | flat | fc
    config: str           # kernel-variant / aspect-config name
    in_encoding: str      # PACKED or UNPACKED
    out_encoding: str

    @property
    def converts(self) -> bool:
        """True when this op changes the activation encoding — the
        (only) place a pack/unpack cost is ever paid."""
        return self.in_encoding != self.out_encoding


@dataclasses.dataclass(frozen=True)
class PlanNode:
    """A schedulable unit: layers [start, stop) on one placement.

    Duck-types ``mapper.Segment`` (``start``/``stop``/``placement``/
    ``configs``/``on_device``/``__len__``), so every segment consumer
    — telemetry observers, the drift detector, the fleet ledger —
    works on plan nodes unchanged.
    """

    start: int
    stop: int
    placement: str        # mapper.HOST or mapper.DEVICE
    ops: tuple            # LayerOp per layer in [start, stop)
    transfer_in: bool     # H2D before the node runs
    transfer_out: bool    # D2H after the node runs
    kernel_s: float = 0.0      # priced kernel seconds/example
    boundary_s: float = 0.0    # priced transfer seconds/example
    fused_variant: str | None = None   # segment-scope kernel, or None

    def __len__(self) -> int:
        return self.stop - self.start

    @property
    def on_device(self) -> bool:
        return self.placement == DEVICE

    @property
    def configs(self) -> tuple:
        return tuple(op.config for op in self.ops)

    @property
    def in_encoding(self) -> str:
        return self.ops[0].in_encoding

    @property
    def out_encoding(self) -> str:
        return self.ops[-1].out_encoding

    @property
    def time_s(self) -> float:
        return self.kernel_s + self.boundary_s


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    model_name: str
    batch: int
    policy: str
    mode: str             # one of MODES
    nodes: tuple          # PlanNode, in execution order

    @property
    def n_layers(self) -> int:
        return self.nodes[-1].stop if self.nodes else 0

    @property
    def expected_time_per_example(self) -> float:
        return sum(n.time_s for n in self.nodes)

    def node_times(self) -> tuple:
        return tuple(n.time_s for n in self.nodes)

    def stage_times(self) -> tuple:
        """(host_s, device_s) per example over the plan's nodes."""
        host = device = 0.0
        for n in self.nodes:
            if n.on_device:
                device += n.time_s
            else:
                host += n.time_s
        return host, device

    def ops(self) -> tuple:
        """All LayerOps in layer order."""
        return tuple(op for n in self.nodes for op in n.ops)

    def to_json(self) -> str:
        return json.dumps(
            {
                "model": self.model_name,
                "batch": self.batch,
                "policy": self.policy,
                "mode": self.mode,
                "nodes": [
                    {
                        "start": n.start,
                        "stop": n.stop,
                        "placement": n.placement,
                        "transfer_in": n.transfer_in,
                        "transfer_out": n.transfer_out,
                        "kernel_s": n.kernel_s,
                        "boundary_s": n.boundary_s,
                        "fused_variant": n.fused_variant,
                        "ops": [
                            {
                                "index": op.index,
                                "kind": op.kind,
                                "config": op.config,
                                "in": op.in_encoding,
                                "out": op.out_encoding,
                            }
                            for op in n.ops
                        ],
                    }
                    for n in self.nodes
                ],
            },
            indent=2,
        )

    @staticmethod
    def from_json(s: str) -> "SegmentPlan":
        d = json.loads(s)
        nodes = tuple(
            PlanNode(
                start=nd["start"],
                stop=nd["stop"],
                placement=nd["placement"],
                ops=tuple(
                    LayerOp(
                        index=op["index"],
                        kind=op["kind"],
                        config=op["config"],
                        in_encoding=op["in"],
                        out_encoding=op["out"],
                    )
                    for op in nd["ops"]
                ),
                transfer_in=nd["transfer_in"],
                transfer_out=nd["transfer_out"],
                kernel_s=nd["kernel_s"],
                boundary_s=nd["boundary_s"],
                fused_variant=nd.get("fused_variant"),
            )
            for nd in d["nodes"]
        )
        return SegmentPlan(
            model_name=d["model"],
            batch=d["batch"],
            policy=d["policy"],
            mode=d["mode"],
            nodes=nodes,
        )


def encoding_conversions(plan: SegmentPlan) -> tuple:
    """Where the plan changes activation encoding: one
    ``(layer_index, from, to)`` per converting op.  Conversions live
    *inside* ops — never between them — so this is also exactly the
    set of pack/unpack costs the plan charges (each op's conversion is
    folded into its kernel time, priced once)."""
    return tuple(
        (op.index, op.in_encoding, op.out_encoding)
        for op in plan.ops()
        if op.converts
    )


def boundary_encoding_changes(plan: SegmentPlan) -> tuple:
    """Encoding changes at op *boundaries* — adjacent ops whose
    encodings disagree.  Always ``()`` for a plan built by
    :func:`build_plan` (the chain-consistency invariant: co-placed
    adjacent layers never unpack/repack between them); exposed so
    tests can assert it on arbitrary plans."""
    ops = plan.ops()
    return tuple(
        (a.index, a.out_encoding, b.in_encoding)
        for a, b in zip(ops, ops[1:])
        if a.out_encoding != b.in_encoding
    )


def _node_boundary(boundaries, start, stop, on_device) -> float:
    """The transfer seconds a segment-shaped node charges: device
    nodes pay only their edge layers' attributions (interior
    roundtrips are elided by construction — the single charging rule
    of ``cost_model.segment_times_from_split``), host nodes pay every
    layer's stored boundary (zero for CPU placements)."""
    if on_device:
        edges = {start, stop - 1}
        return sum(boundaries[i] for i in edges)
    return sum(boundaries[start:stop])


def build_plan(
    config: EfficientConfiguration, *, mode: str = "segments"
) -> SegmentPlan:
    """Lower an ``EfficientConfiguration`` to a :class:`SegmentPlan`.

    The plan is the *single* description of execution: which layers
    run where, where the activation crosses the host<->device
    boundary, what encoding it has at every point, and what each node
    is priced at.  ``config.fused_segments`` entries matching a device
    node's span (``"segments"`` mode) set that node's
    ``fused_variant`` and replace its kernel price with the profiled
    fused time.
    """
    if mode not in MODES:
        raise PlanError(f"unknown plan mode {mode!r}; expected {MODES}")
    labels = config.layer_labels
    n = len(labels)
    kinds = tuple(kind_of_label(x) for x in labels)
    encs = layer_encodings(kinds)
    kernels = config.per_layer_kernel_times or config.per_layer_times
    boundaries = config.per_layer_boundary_times or (0.0,) * n
    ops = tuple(
        LayerOp(
            index=i,
            kind=kinds[i],
            config=config.layer_configs[i],
            in_encoding=encs[i][0],
            out_encoding=encs[i][1],
        )
        for i in range(n)
    )
    fused = {
        (int(s), int(e)): (name, float(t))
        for s, e, name, t in getattr(config, "fused_segments", ())
    }

    nodes = []
    if mode == "whole":
        on_device = any(
            seg.on_device for seg in config.segments()
        )
        nodes.append(
            PlanNode(
                start=0,
                stop=n,
                placement=DEVICE if on_device else HOST,
                ops=ops,
                transfer_in=False,
                transfer_out=False,
                kernel_s=sum(kernels),
                boundary_s=sum(boundaries),
            )
        )
    elif mode == "segments":
        for seg in config.segments():
            variant, kern = None, sum(kernels[seg.start:seg.stop])
            if seg.on_device and (seg.start, seg.stop) in fused:
                variant, kern = fused[(seg.start, seg.stop)]
            nodes.append(
                PlanNode(
                    start=seg.start,
                    stop=seg.stop,
                    placement=seg.placement,
                    ops=ops[seg.start:seg.stop],
                    transfer_in=seg.on_device,
                    transfer_out=seg.on_device,
                    kernel_s=kern,
                    boundary_s=_node_boundary(
                        boundaries, seg.start, seg.stop, seg.on_device
                    ),
                    fused_variant=variant,
                )
            )
    else:  # per-layer nodes: "layers" (elided) or "roundtrip" (§IV-A)
        placements = [
            seg.placement
            for seg in config.segments()
            for _ in range(len(seg))
        ]
        for i in range(n):
            dev = placements[i] == DEVICE
            if mode == "roundtrip":
                t_in = t_out = dev
            else:
                t_in = dev and (i == 0 or placements[i - 1] == HOST)
                t_out = dev and (
                    i == n - 1 or placements[i + 1] == HOST
                )
            nodes.append(
                PlanNode(
                    start=i,
                    stop=i + 1,
                    placement=placements[i],
                    ops=(ops[i],),
                    transfer_in=t_in,
                    transfer_out=t_out,
                    kernel_s=kernels[i],
                    boundary_s=boundaries[i],
                )
            )

    plan = SegmentPlan(
        model_name=config.model_name,
        batch=config.proper_batch_size,
        policy=config.policy,
        mode=mode,
        nodes=tuple(nodes),
    )
    assert boundary_encoding_changes(plan) == (), (
        "plan invariant violated: encoding change between adjacent ops"
    )
    return plan


def device_spans(config: EfficientConfiguration) -> tuple:
    """(start, stop) of every device-placed segment — the fusion
    candidates :func:`select_fused_segments` prices."""
    return tuple(
        (seg.start, seg.stop)
        for seg in config.segments()
        if seg.on_device
    )


def select_fused_segments(
    config: EfficientConfiguration,
    table,
    *,
    registry=None,
) -> EfficientConfiguration:
    """Pick, per device segment, the cheapest execution the table
    knows: the per-layer kernel composition (always a candidate) or a
    profiled segment-scope variant.  Returns a configuration whose
    ``fused_segments`` records each strict winner — so the fused
    plan's priced time is **<=** the per-layer plan's (min over a
    superset that contains the per-layer option).

    Only variants present in `registry` (default: the process-wide
    ``DEFAULT_REGISTRY``) are eligible — a table profiled under a
    richer registry never selects a variant the executor can't build.
    """
    if registry is None:
        from repro.kernels.registry import DEFAULT_REGISTRY

        registry = DEFAULT_REGISTRY
    batch = config.proper_batch_size
    kernels = config.per_layer_kernel_times or config.per_layer_times
    chosen = []
    for start, stop in device_spans(config):
        per_layer = sum(kernels[start:stop])
        best_name, best_t = None, per_layer
        for name in table.segment_variants_for(batch, start, stop):
            if name not in registry:
                continue
            t = table.segment_time(batch, start, stop, name)
            if t < best_t:
                best_name, best_t = name, t
        if best_name is not None:
            chosen.append((start, stop, best_name, best_t))
    return dataclasses.replace(config, fused_segments=tuple(chosen))


def fuse_mapping(
    model,
    packed_params,
    table,
    config: EfficientConfiguration,
    *,
    registry=None,
    time_source: str = "measured",
    repeats: int = 3,
    platform: str | None = None,
) -> EfficientConfiguration:
    """Profile every applicable segment-scope variant over `config`'s
    device segments (``profiler.profile_segment_variants``) and select
    the winners — the one-call path from a mapped configuration to a
    fused one.  The table is updated in place with the segment rows,
    so saving it persists the fused profile.

    Canonical spelling of the legacy ``fuse_configuration`` (part of
    the ``repro.api`` verb set)."""
    from repro.core.profiler import profile_segment_variants

    profile_segment_variants(
        model,
        packed_params,
        table,
        spans=device_spans(config),
        batch_sizes=(config.proper_batch_size,),
        registry=registry,
        time_source=time_source,
        repeats=repeats,
        platform=platform,
    )
    return select_fused_segments(config, table, registry=registry)


def fuse_configuration(
    model,
    packed_params,
    table,
    config: EfficientConfiguration,
    **kwargs,
) -> EfficientConfiguration:
    """Deprecated spelling of :func:`repro.api.fuse_mapping` — kept
    importable; warns once per call site and delegates."""
    from repro._compat import warn_deprecated

    warn_deprecated("fuse_configuration", "fuse_mapping")
    from repro import api

    return api.fuse_mapping(model, packed_params, table, config, **kwargs)
