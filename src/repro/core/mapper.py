"""Algorithm 1 — the greedy layer-to-device mapping (paper §III-B).

Faithful transcription: for each batch size, for each layer, choose the
implementation with minimum inference time (kernel + boundary); the
batch size whose summed per-layer minima is smallest becomes the
*proper batch size*, and the per-layer argmins at that batch size form
the *Efficient Configuration*.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Sequence

from repro.core.parallel_config import CONFIGS, validate
from repro.core.profiler import ProfileTable


@dataclasses.dataclass(frozen=True)
class EfficientConfiguration:
    model_name: str
    proper_batch_size: int
    layer_labels: tuple
    layer_configs: tuple          # config per layer, paper Tables IV/V
    expected_time_per_example: float
    per_layer_times: tuple        # seconds/example at the proper batch

    def to_json(self) -> str:
        return json.dumps(
            {
                "model": self.model_name,
                "proper_batch_size": self.proper_batch_size,
                "layers": [
                    {"layer": l, "config": c, "time_per_example": t}
                    for l, c, t in zip(
                        self.layer_labels,
                        self.layer_configs,
                        self.per_layer_times,
                    )
                ],
                "expected_time_per_example": self.expected_time_per_example,
            },
            indent=2,
        )

    @staticmethod
    def from_json(s: str) -> "EfficientConfiguration":
        d = json.loads(s)
        layers = d["layers"]
        return EfficientConfiguration(
            model_name=d["model"],
            proper_batch_size=d["proper_batch_size"],
            layer_labels=tuple(x["layer"] for x in layers),
            layer_configs=tuple(x["config"] for x in layers),
            expected_time_per_example=d["expected_time_per_example"],
            per_layer_times=tuple(
                x["time_per_example"] for x in layers
            ),
        )


def map_efficient_configuration(
    table: ProfileTable, *, configs: Sequence[str] = CONFIGS
) -> EfficientConfiguration:
    """Algorithm 1, lines 1-27."""
    result_time = float("inf")          # line 2
    proper_batch = None                 # line 1
    best_mapping: list = []
    best_times: list = []

    for batch in table.batch_sizes:     # line 3
        sum_min_time = 0.0              # line 4
        mapping, mins = [], []
        for layer_idx in range(len(table.layer_labels)):  # line 5
            row = table.times[batch][layer_idx]
            min_time = float("inf")     # line 6
            chosen = None
            for impl in configs:        # line 7
                t = row[impl]           # lines 8-9 (profiled)
                if t < min_time:        # line 11
                    min_time = t
                    chosen = impl       # line 13 (MAP impl to batch)
            sum_min_time += min_time    # line 16
            mapping.append(chosen)
            mins.append(min_time)
        if sum_min_time < result_time:  # line 18
            result_time = sum_min_time  # line 19
            proper_batch = batch        # line 20
            best_mapping, best_times = mapping, mins

    return EfficientConfiguration(     # lines 23-27
        model_name=table.model_name,
        proper_batch_size=int(proper_batch),
        layer_labels=table.layer_labels,
        layer_configs=tuple(validate(c) for c in best_mapping),
        expected_time_per_example=result_time,
        per_layer_times=tuple(best_times),
    )


def uniform_total(table: ProfileTable, config: str, batch: int) -> float:
    """Seconds/example when every layer uses `config` at `batch`
    (the paper's naive-X / full-XYZ / CPU-only baselines, Fig. 5)."""
    validate(config)
    return sum(
        table.times[batch][i][config]
        for i in range(len(table.layer_labels))
    )


def best_uniform(table: ProfileTable, config: str) -> tuple:
    """(batch, seconds/example) of the best batch size for a uniform
    config — the strongest version of each baseline."""
    cand = [
        (uniform_total(table, config, b), b) for b in table.batch_sizes
    ]
    t, b = min(cand)
    return b, t
