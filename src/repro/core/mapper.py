"""Layer-to-device mapping: Algorithm 1 plus a transfer-aware DP.

Two selectable policies, same output type:

``policy="greedy"`` — Algorithm 1 (paper §III-B), faithful
transcription: for each batch size, for each layer, choose the
implementation with minimum inference time (kernel + full per-layer
boundary); the batch size whose summed per-layer minima is smallest
becomes the *proper batch size*, and the per-layer argmins at that
batch size form the *Efficient Configuration*.  This prices the
paper's execution model where "data transfer between CPU and GPU takes
place before and after every layer's execution" (§IV-A).

``policy="dp"`` — transfer-aware dynamic program (Viterbi over
layers x per-layer candidate sets, run per batch size) pricing the
**fused** executor
(``mapped_model.build_mapped_model``), which elides host<->device
roundtrips between co-placed layers — the optimization the paper names
as future work.  Recurrence, with ``place(c) in {host, device}``
(``CPU`` is host, every aspect config is device)::

    dp[0][c]  = kernel(0, c) + (h2d(0) if place(c) == device)
    dp[i][c]  = kernel(i, c) + min_c' ( dp[i-1][c'] + edge(i, c', c) )
    edge(i, c', c) = h2d(i)     if host -> device
                   = d2h(i-1)   if device -> host
                   = 0          if placement unchanged
    answer    = min_c ( dp[L-1][c] + (d2h(L-1) if place(c) == device) )

Node cost is the kernel time alone; boundary cost is charged only where
the placement changes (the model starts and ends on the host).  Because
the DP minimizes the fused cost exactly, its expected time is provably
<= the greedy mapping's under the split cost model: the greedy
mapping is one feasible DP path, and its fused cost never exceeds its
paper cost (eliding transfers only removes non-negative terms).

On a legacy ``ProfileTable`` without the kernel/boundary split, every
boundary reads as zero and the DP degenerates to the greedy per-layer
argmin — the two policies agree.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Sequence

from repro.core.parallel_config import is_host_config, validate
from repro.core.profiler import ProfileTable

POLICIES = ("greedy", "dp")

HOST = "host"
DEVICE = "device"


@dataclasses.dataclass(frozen=True)
class Segment:
    """A maximal run of consecutive layers with the same placement.

    Segments are the unit of execution in the serving runtime
    (``repro.serving``): the activation crosses the host<->device
    boundary exactly once between adjacent segments, which is the same
    set of crossings the DP mapper charges boundary cost for.
    """

    start: int            # first layer index, inclusive
    stop: int             # one past the last layer index
    placement: str        # HOST or DEVICE
    configs: tuple        # per-layer configs for layers [start, stop)

    def __len__(self) -> int:
        return self.stop - self.start

    @property
    def on_device(self) -> bool:
        return self.placement == DEVICE


def placement_of(config: str) -> str:
    """CPU (and any registered host variant) is host-placed; every
    other config — aspect or registered device variant — runs on the
    device."""
    return HOST if is_host_config(config) else DEVICE


def _candidates_for(
    table: ProfileTable, batch: int, layer: int, configs
) -> tuple:
    """The configs a policy may choose for (batch, layer): the table
    row's own (variable-size) space, optionally restricted to
    `configs`.  Restriction silently drops names the row lacks (e.g.
    autotune-pruned variants) but never yields an empty space."""
    row = table.configs_for(batch, layer)
    if configs is None:
        return row
    cand = tuple(c for c in configs if c in set(row))
    if not cand:
        raise ValueError(
            f"none of {tuple(configs)} profiled for layer {layer} "
            f"at batch {batch} (row has {row})"
        )
    return cand


def segments_of(layer_configs: Sequence[str]) -> tuple:
    """Split a per-layer config sequence into maximal same-placement
    runs.  Segment boundaries are exactly the host<->device placement
    changes — the points where the DP mapper charges an edge cost and
    where the fused/serving executors move the activation."""
    segs: list = []
    start = 0
    for i in range(1, len(layer_configs) + 1):
        if i == len(layer_configs) or (
            placement_of(layer_configs[i])
            != placement_of(layer_configs[start])
        ):
            segs.append(
                Segment(
                    start=start,
                    stop=i,
                    placement=placement_of(layer_configs[start]),
                    configs=tuple(layer_configs[start:i]),
                )
            )
            start = i
    return tuple(segs)


@dataclasses.dataclass(frozen=True)
class EfficientConfiguration:
    model_name: str
    proper_batch_size: int
    layer_labels: tuple
    layer_configs: tuple          # config per layer, paper Tables IV/V
    expected_time_per_example: float
    per_layer_times: tuple        # seconds/example at the proper batch
    policy: str = "greedy"        # mapping policy that produced this
    # kernel/boundary breakdown: per_layer_times[i] ==
    # per_layer_kernel_times[i] + per_layer_boundary_times[i]; boundary
    # is the transfer cost *charged by the policy* (full roundtrip per
    # non-CPU layer for greedy, placement-change edges only for dp)
    per_layer_kernel_times: tuple = ()
    per_layer_boundary_times: tuple = ()
    # the searchable space the mapping was chosen from: one tuple of
    # candidate variant names per layer, variable-size per layer for
    # autotuned tables.  () on legacy configurations (fixed-8 implied).
    config_space: tuple = ()
    # fused-segment selections: (start, stop, variant_name, kernel
    # s/example) per device segment whose profiled segment-scope
    # variant beat the per-layer kernel sum
    # (``core.plan.select_fused_segments``).  () = per-layer execution
    # everywhere (legacy and default).  The per-layer attribution
    # fields above are untouched by fusion — they remain the
    # per-layer price; the fused price lives on the plan's nodes.
    fused_segments: tuple = ()

    def segments(self) -> tuple:
        """Maximal same-placement layer runs (:func:`segments_of`) —
        the schedule the serving runtime executes."""
        return segments_of(self.layer_configs)

    def segment_expected_times(self) -> tuple:
        """Seconds/example per segment under the segment executor
        (``cost_model.segment_times_from_split``), aligned with
        :meth:`segments` — the per-segment predictions the adaptive
        runtime's drift detector compares live telemetry against.

        Requires the kernel/boundary split; a legacy configuration
        without it attributes everything to per_layer_times with zero
        boundary, which is still a valid split for the estimate.
        """
        from repro.core.cost_model import segment_times_from_split

        kernels = self.per_layer_kernel_times or self.per_layer_times
        boundaries = self.per_layer_boundary_times or (0.0,) * len(
            self.per_layer_times
        )
        return segment_times_from_split(self.segments(), kernels, boundaries)

    def stage_times(self) -> tuple:
        """(host_s, device_s) per example: total time this
        configuration spends in host-placed vs device-placed segments,
        boundary charges counted on the device side (they serialize
        with device execution, not with host compute).

        Prices the *segment* executor, which crosses the boundary only
        at segment edges — so boundary charges on interior layers of a
        device segment are dropped.  For ``policy="dp"`` attributions
        they are zero anyway and the split is exact; for greedy
        configurations (full per-layer roundtrips) the edge layers'
        charges remain a modest upper bound (an entry layer's stored
        boundary includes a d2h the segment executor elides, and vice
        versa at exit).
        """
        host = device = 0.0
        for seg, t in zip(self.segments(), self.segment_expected_times()):
            if seg.on_device:
                device += t
            else:
                host += t
        return host, device

    def placement_shares(self) -> tuple:
        """(host_share, device_share): the fraction of this
        configuration's serial execution time spent on each processor
        (``stage_times`` normalized; sums to 1).  This is a tenant's
        *demand* profile — the occupancy it asks of each processor per
        example served — and is what the fleet mapper
        (``repro.fleet.scheduler``) charges co-tenants as contention
        when no measured ledger shares are available.  A configuration
        with zero total time reports (0, 0)."""
        host, device = self.stage_times()
        total = host + device
        if total <= 0.0:
            return 0.0, 0.0
        return host / total, device / total

    def pipelined_expected_time(self, n_microbatches: int) -> float:
        """Expected seconds/example of the two-stage segment pipeline
        over ``n_microbatches`` micro-batches of the proper batch size
        (``repro.core.cost_model.pipeline_makespan``).  With one
        micro-batch this equals ``expected_time_per_example`` for a
        DP configuration (for greedy it is lower: the segment executor
        elides the interior roundtrips greedy priced); as the stream
        grows it approaches max(host, device) per micro-batch — the
        steady-state rate the serving runtime targets."""
        from repro.core.cost_model import pipeline_makespan

        host, device = self.stage_times()
        return pipeline_makespan(host, device, n_microbatches) / max(
            n_microbatches, 1
        )

    def to_json(self) -> str:
        layers = []
        for i, (label, c, t) in enumerate(
            zip(self.layer_labels, self.layer_configs, self.per_layer_times)
        ):
            entry = {"layer": label, "config": c, "time_per_example": t}
            if self.per_layer_kernel_times:
                entry["kernel_time_per_example"] = (
                    self.per_layer_kernel_times[i]
                )
                entry["boundary_time_per_example"] = (
                    self.per_layer_boundary_times[i]
                )
            if self.config_space:
                entry["candidates"] = list(self.config_space[i])
            layers.append(entry)
        doc = {
            "model": self.model_name,
            "proper_batch_size": self.proper_batch_size,
            "policy": self.policy,
            "layers": layers,
            "expected_time_per_example": self.expected_time_per_example,
        }
        if self.fused_segments:
            doc["fused_segments"] = [
                {
                    "start": s,
                    "stop": e,
                    "variant": name,
                    "kernel_time_per_example": t,
                }
                for s, e, name, t in self.fused_segments
            ]
        return json.dumps(doc, indent=2)

    @staticmethod
    def from_json(s: str) -> "EfficientConfiguration":
        """Inverse of :meth:`to_json`; tolerates legacy JSON written
        before the policy, kernel/boundary, and variable-size
        config-space (``candidates``) fields existed."""
        d = json.loads(s)
        layers = d["layers"]
        has_split = layers and "kernel_time_per_example" in layers[0]
        has_space = layers and "candidates" in layers[0]
        return EfficientConfiguration(
            model_name=d["model"],
            proper_batch_size=d["proper_batch_size"],
            layer_labels=tuple(x["layer"] for x in layers),
            layer_configs=tuple(x["config"] for x in layers),
            expected_time_per_example=d["expected_time_per_example"],
            per_layer_times=tuple(
                x["time_per_example"] for x in layers
            ),
            policy=d.get("policy", "greedy"),
            per_layer_kernel_times=tuple(
                x["kernel_time_per_example"] for x in layers
            ) if has_split else (),
            per_layer_boundary_times=tuple(
                x["boundary_time_per_example"] for x in layers
            ) if has_split else (),
            config_space=tuple(
                tuple(x["candidates"]) for x in layers
            ) if has_space else (),
            fused_segments=tuple(
                (
                    int(f["start"]),
                    int(f["stop"]),
                    f["variant"],
                    float(f["kernel_time_per_example"]),
                )
                for f in d.get("fused_segments", ())
            ),
        )


def _greedy_for_batch(
    table: ProfileTable, batch: int, configs
) -> tuple:
    """Algorithm 1 inner loop: (total, mapping).  The per-layer
    implementation space is the table row's own — variable-size for
    autotuned tables."""
    total = 0.0                         # line 4
    mapping = []
    for layer_idx in range(len(table.layer_labels)):  # line 5
        row = table.times[batch][layer_idx]
        min_time = float("inf")         # line 6
        chosen = None
        for impl in _candidates_for(table, batch, layer_idx, configs):
            t = row[impl]               # lines 8-9 (profiled)
            if t < min_time:            # line 11
                min_time = t
                chosen = impl           # line 13 (MAP impl to batch)
        total += min_time               # line 16
        mapping.append(chosen)
    return total, mapping


def _dp_for_batch(
    table: ProfileTable, batch: int, configs
) -> tuple:
    """Viterbi over layers x per-layer candidate sets under the fused
    cost model — the candidate sets may differ in size per layer
    (autotuned tables).

    Returns (total, mapping); per-layer attribution is derived from the
    mapping afterwards so kernel and edge charges stay auditable.
    """
    n_layers = len(table.layer_labels)
    cands0 = _candidates_for(table, batch, 0, configs)
    # dp cost of a prefix ending with layer i mapped to config c, the
    # activation resident at place(c); back[i][c] = best predecessor
    prev = {
        c: table.kernel_time(batch, 0, c)
        + (0.0 if is_host_config(c) else table.h2d(batch, 0))
        for c in cands0
    }
    back: list = [{c: None for c in cands0}]
    for i in range(1, n_layers):
        cur, bk = {}, {}
        d2h_prev = table.d2h(batch, i - 1)
        h2d_here = table.h2d(batch, i)
        for c in _candidates_for(table, batch, i, configs):
            dev = not is_host_config(c)
            kern = table.kernel_time(batch, i, c)
            best_cost, best_prev = float("inf"), None
            for cp, pcost in prev.items():
                if (not is_host_config(cp)) == dev:
                    edge = 0.0
                elif dev:               # host -> device: upload operand
                    edge = h2d_here
                else:                   # device -> host: download result
                    edge = d2h_prev
                cost = pcost + edge + kern
                if cost < best_cost:
                    best_cost, best_prev = cost, cp
            cur[c], bk[c] = best_cost, best_prev
        prev = cur
        back.append(bk)

    # the network's output must land back on the host
    total, last = float("inf"), None
    for c, cost in prev.items():
        if not is_host_config(c):
            cost += table.d2h(batch, n_layers - 1)
        if cost < total:
            total, last = cost, c
    mapping = [last]
    for i in range(n_layers - 1, 0, -1):
        mapping.append(back[i][mapping[-1]])
    mapping.reverse()
    return total, mapping


def attribute_fused_costs(
    table: ProfileTable, batch: int, mapping: Sequence[str]
) -> tuple:
    """(kernel, boundary) per layer for a mapping priced under the
    fused/segment executor: h2d charged to the layer entering the
    device, d2h to the layer leaving it."""
    n_layers = len(mapping)
    kernels, boundaries = [], []
    for i, c in enumerate(mapping):
        kernels.append(table.kernel_time(batch, i, c))
        b = 0.0
        if not is_host_config(c):
            entered = i == 0 or is_host_config(mapping[i - 1])
            left = i == n_layers - 1 or is_host_config(mapping[i + 1])
            if entered:
                b += table.h2d(batch, i)
            if left:
                b += table.d2h(batch, i)
        boundaries.append(b)
    return tuple(kernels), tuple(boundaries)


def map_efficient_configuration(
    table: ProfileTable,
    *,
    configs: Sequence[str] | None = None,
    policy: str = "greedy",
    batch_sizes: Sequence[int] | None = None,
) -> EfficientConfiguration:
    """Map every layer to an implementation and pick the proper batch.

    ``policy="greedy"`` is Algorithm 1 lines 1-27; ``policy="dp"`` is
    the transfer-aware Viterbi (module docstring).  Both sweep all
    profiled batch sizes and return the best.

    ``configs=None`` (default) searches each layer's full profiled
    space — the table row's own, variable-size keys, so autotuned
    tables are searched in their entirety.  Passing an explicit list
    restricts the search (e.g. ``configs=CONFIGS`` prices the paper's
    fixed-8 space on an autotuned table for apples-to-apples
    comparison).

    ``batch_sizes=None`` sweeps every profiled batch size; an explicit
    subset restricts the sweep — the adaptive runtime remaps at the
    batch size the engine is already serving, so the swapped-in
    configuration keeps the batcher's padding targets valid.
    """
    if policy not in POLICIES:
        raise ValueError(
            f"unknown mapping policy {policy!r}; expected one of {POLICIES}"
        )
    if batch_sizes is None:
        batch_sizes = table.batch_sizes
    else:
        missing = tuple(
            b for b in batch_sizes if b not in table.batch_sizes
        )
        if missing:
            raise ValueError(
                f"batch sizes {missing} not profiled "
                f"(have {table.batch_sizes})"
            )
        if not batch_sizes:
            raise ValueError("batch_sizes must be non-empty when given")
    result_time = float("inf")          # line 2
    proper_batch = None                 # line 1
    best_mapping: list = []

    for batch in batch_sizes:           # line 3
        if policy == "greedy":
            total, mapping = _greedy_for_batch(table, batch, configs)
        else:
            total, mapping = _dp_for_batch(table, batch, configs)
        if total < result_time:         # line 18
            result_time = total         # line 19
            proper_batch = batch        # line 20
            best_mapping = mapping

    proper_batch = int(proper_batch)
    if policy == "greedy":
        kernels = tuple(
            table.kernel_time(proper_batch, i, c)
            for i, c in enumerate(best_mapping)
        )
        boundaries = tuple(
            table.boundary_time(proper_batch, i, c)
            for i, c in enumerate(best_mapping)
        )
    else:
        kernels, boundaries = attribute_fused_costs(
            table, proper_batch, best_mapping
        )

    return EfficientConfiguration(     # lines 23-27
        model_name=table.model_name,
        proper_batch_size=proper_batch,
        layer_labels=table.layer_labels,
        layer_configs=tuple(validate(c) for c in best_mapping),
        expected_time_per_example=result_time,
        per_layer_times=tuple(
            k + b for k, b in zip(kernels, boundaries)
        ),
        policy=policy,
        per_layer_kernel_times=kernels,
        per_layer_boundary_times=boundaries,
        config_space=tuple(
            _candidates_for(table, proper_batch, i, configs)
            for i in range(len(table.layer_labels))
        ),
    )


def price_mapping(
    table: ProfileTable,
    batch: int,
    mapping: Sequence[str],
) -> EfficientConfiguration:
    """Price an explicit per-layer mapping at `batch` under the fused
    cost model and wrap it as an EfficientConfiguration.

    For pinning a schedule by hand — serving experiments on a forced
    mixed host/device split, ablations, regression fixtures — rather
    than letting a policy choose one.  The result carries
    ``policy="dp"`` semantics: boundary cost only at placement
    changes, so ``segments()`` / the serving pipeline execute exactly
    what was priced.

    Canonical spelling of the legacy ``configuration_from_mapping``
    (part of the ``repro.api`` verb set).
    """
    if batch not in table.batch_sizes:
        raise ValueError(
            f"batch {batch} not profiled (have {table.batch_sizes})"
        )
    if len(mapping) != len(table.layer_labels):
        raise ValueError(
            f"mapping covers {len(mapping)} layers, model has "
            f"{len(table.layer_labels)}"
        )
    mapping = tuple(validate(c) for c in mapping)
    kernels, boundaries = attribute_fused_costs(table, batch, mapping)
    return EfficientConfiguration(
        model_name=table.model_name,
        proper_batch_size=int(batch),
        layer_labels=table.layer_labels,
        layer_configs=mapping,
        expected_time_per_example=sum(kernels) + sum(boundaries),
        per_layer_times=tuple(
            k + b for k, b in zip(kernels, boundaries)
        ),
        policy="dp",
        per_layer_kernel_times=kernels,
        per_layer_boundary_times=boundaries,
    )


def configuration_from_mapping(
    table: ProfileTable,
    batch: int,
    mapping: Sequence[str],
) -> EfficientConfiguration:
    """Deprecated spelling of :func:`repro.api.price_mapping` — kept
    importable; warns once per call site and delegates."""
    from repro._compat import warn_deprecated

    warn_deprecated("configuration_from_mapping", "price_mapping")
    from repro import api

    return api.price_mapping(table, batch, mapping)


def uniform_total(table: ProfileTable, config: str, batch: int) -> float:
    """Seconds/example when every layer uses `config` at `batch`
    (the paper's naive-X / full-XYZ / CPU-only baselines, Fig. 5)."""
    validate(config)
    return sum(
        table.times[batch][i][config]
        for i in range(len(table.layer_labels))
    )


def best_uniform(table: ProfileTable, config: str) -> tuple:
    """(batch, seconds/example) of the best batch size for a uniform
    config — the strongest version of each baseline."""
    cand = [
        (uniform_total(table, config, b), b) for b in table.batch_sizes
    ]
    t, b = min(cand)
    return b, t
