"""Drift-triggered remapping: fold live telemetry back into the
profile, re-run the DP mapper, hot-swap the result.

The closed loop (docs/ARCHITECTURE.md §9)::

    SegmentPipeline --observer--> SegmentTelemetry
                                        |
                                  DriftDetector      (sustained dev.?)
                                        |
    ProfileTable  --fold_observed--> corrected table (drifted layers'
                                        |             rows only)
                                  DP mapper          (same registry
                                        |             candidate sets)
    ServingEngine <--swap_configuration-+            (batch boundary,
                                                      journaled)

:func:`fold_observed` is the measurement-to-model bridge: a drifted
segment's observed/predicted ratio scales the kernel times of *that
segment's layers* for every candidate config with the drifted
placement — contention is a property of the processor, not of one
kernel, so every same-placed candidate of the affected layers is
repriced and the DP can route around the contended processor (or stay,
if it is still cheapest).  Un-drifted layers' rows are untouched.

:class:`RemapController` owns the loop.  Remapping re-solves at the
batch size the engine is serving (``batch_sizes=(proper,)``), so the
batcher's padding targets stay valid across swaps; each remap appends
a :class:`SwapRecord` to :attr:`RemapController.journal` — every
mapping the engine ever served is auditable back to the telemetry that
evicted its predecessor.  When a :class:`~repro.store.ProfileStore` is
attached, the new *mapping* is persisted on every swap, so the next
process on this platform warm-starts from the adapted mapping; the
corrected table is deliberately session-local (it encodes observed —
possibly transient — conditions, and an abandoned placement's rows
could never be re-observed to recover, so persisting them would let a
contention episode poison warm starts forever).
"""

from __future__ import annotations

import dataclasses
import time

from repro.core.mapper import (
    EfficientConfiguration,
    map_efficient_configuration,
    price_mapping,
)
from repro.core.parallel_config import is_host_config
from repro.core.profiler import ProfileTable
from repro.adapt.drift import DriftDetector


def fold_observed(
    table: ProfileTable,
    config: EfficientConfiguration,
    reports,
    *,
    min_factor: float = 1e-3,
) -> ProfileTable:
    """A corrected copy of `table`: for each drifted segment, scale the
    kernel times of its layers' same-placement candidate rows by the
    observed/predicted ratio (clamped below by ``min_factor``), at
    every profiled batch size; totals are rebuilt as kernel plus the
    unchanged boundary.  Rows of un-drifted layers are shared, not
    copied — only the drifted layers' rows change."""
    factors: dict[int, float] = {}          # layer index -> scale
    placements: dict[int, bool] = {}        # layer index -> host?
    segments = config.segments()
    for rep in reports:
        seg = segments[rep.segment_index]
        f = max(rep.ratio, min_factor)
        for i in range(seg.start, seg.stop):
            factors[i] = f
            placements[i] = not seg.on_device
    if not factors:
        return table

    times: dict = {}
    kernels: dict = {}
    for b in table.batch_sizes:
        times[b], kernels[b] = [], []
        for i in range(len(table.layer_labels)):
            if i not in factors:
                times[b].append(table.times[b][i])
                kernels[b].append(
                    table.kernel_times[b][i]
                    if table.kernel_times is not None
                    else table.times[b][i]
                )
                continue
            f, host_drifted = factors[i], placements[i]
            krow, trow = {}, {}
            for cfg in table.configs_for(b, i):
                k = table.kernel_time(b, i, cfg)
                if is_host_config(cfg) == host_drifted:
                    k *= f
                krow[cfg] = k
                trow[cfg] = k + table.boundary_time(b, i, cfg)
            kernels[b].append(krow)
            times[b].append(trow)
    return ProfileTable(
        model_name=table.model_name,
        batch_sizes=table.batch_sizes,
        layer_labels=table.layer_labels,
        times=times,
        kernel_times=kernels,
        h2d_times=table.h2d_times,
        d2h_times=table.d2h_times,
    )


@dataclasses.dataclass(frozen=True)
class SwapRecord:
    """One journal entry: why a mapping was evicted and what replaced
    it.  ``new_expected_s <= old_expected_s`` always holds on the
    corrected table (the old mapping is a feasible DP path)."""

    at_step: int                  # engine.steps when the swap fired
    requested_t: float
    applied_immediately: bool     # False: deferred to the batch boundary
    changed: bool                 # mapping differs (vs. reprice-only)
    reports: tuple                # the DriftReports that triggered it
    old_configs: tuple
    new_configs: tuple
    old_expected_s: float         # old mapping priced on corrected table
    new_expected_s: float
    telemetry: dict               # SegmentTelemetry.snapshot() at swap
    # which engine this record belongs to: "" for a single-tenant
    # process (legacy records), the tenant id when several engines'
    # controllers journal in one process (repro.fleet)
    tenant: str = ""

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["reports"] = [dataclasses.asdict(r) for r in self.reports]
        return d


class RemapController:
    """Owns the telemetry -> drift -> remap -> swap loop for one
    engine.  Drive it with :meth:`step` (delegates to the engine, then
    checks drift) or call :meth:`maybe_remap` from your own loop."""

    def __init__(
        self,
        engine,
        table: ProfileTable,
        *,
        telemetry=None,
        detector: DriftDetector | None = None,
        policy: str = "dp",
        configs=None,
        store=None,
        max_remaps: int | None = None,
        clock=time.monotonic,
        tenant: str | None = None,
    ):
        """``tenant`` namespaces this controller's journal records —
        required (in spirit) when several engines' controllers share a
        process, or two fleets' ``SwapRecord``s are ambiguous.  It
        defaults to the telemetry's own tenant id, so naming the
        telemetry once (``SegmentTelemetry(tenant=...)``) names the
        whole loop."""
        telemetry = telemetry if telemetry is not None else engine.telemetry
        if telemetry is None:
            raise ValueError(
                "RemapController needs telemetry — construct the engine "
                "with telemetry=SegmentTelemetry(...) or pass one here"
            )
        self.engine = engine
        self.table = table
        self.telemetry = telemetry
        self.detector = detector if detector is not None else DriftDetector()
        self.policy = policy
        self.configs = configs
        self.store = store
        self.max_remaps = max_remaps
        self._clock = clock
        self.tenant = (
            tenant if tenant is not None
            else getattr(telemetry, "tenant", "")
        )
        self.journal: list = []

    def step(self, *, force: bool = False) -> int:
        """One serve-then-adapt cycle: engine step, then a drift check
        at the batch boundary.  Returns requests completed."""
        done = self.engine.step(force=force)
        if done:
            self.maybe_remap()
        return done

    def maybe_remap(self) -> SwapRecord | None:
        """Check drift; on sustained deviation, correct the profile,
        re-map at the serving batch size, and hot-swap.  Returns the
        journal entry, or None when nothing drifted (or the remap
        budget is exhausted)."""
        if self.max_remaps is not None and len(self.journal) >= self.max_remaps:
            return None
        old = self.engine.config
        reports = self.detector.check(old, self.telemetry)
        if not reports:
            return None

        corrected = fold_observed(self.table, old, reports)
        batch = old.proper_batch_size
        new = map_efficient_configuration(
            corrected,
            policy=self.policy,
            configs=self.configs,
            batch_sizes=(batch,),
        )
        old_on_corrected = price_mapping(
            corrected, batch, old.layer_configs
        )
        record = SwapRecord(
            at_step=self.engine.steps,
            requested_t=self._clock(),
            applied_immediately=self.engine.swap_configuration(new),
            changed=new.layer_configs != old.layer_configs,
            reports=reports,
            old_configs=old.layer_configs,
            new_configs=new.layer_configs,
            old_expected_s=old_on_corrected.expected_time_per_example,
            new_expected_s=new.expected_time_per_example,
            telemetry=self.telemetry.snapshot(),
            tenant=self.tenant,
        )
        self.table = corrected
        # stale segment indices + a moved baseline: start sampling anew
        self.telemetry.reset()
        self.journal.append(record)
        if self.store is not None:
            # persist the remapped configuration, NOT the corrected
            # table: corrections encode this session's observed
            # conditions — possibly a transient contention episode —
            # and rows of a placement the remap abandoned can never be
            # re-observed to recover.  The factory profile on disk
            # stays authoritative, so a poisoned row cannot outlive
            # the episode that caused it: the next process warm-starts
            # the adapted mapping and re-learns corrections live.
            self.store.save_mapping(new)
        return record
