"""Online per-segment latency telemetry for the serving runtime.

:class:`SegmentTelemetry` is the observer the ``SegmentPipeline``
drivers call once per (micro-batch, segment) execution
(``observer(seg_index, segment, seconds, batch)``).  Observations are
aggregated to **one window sample per (engine step, segment)** — the
step's best per-example time — flushed when the next step begins
(:meth:`sample`) or at any read: a step that drains a large backlog
contributes exactly one sample, so the drift detector's
``min_samples`` hysteresis counts *steps*, and one stalled wave-train
— however many micro-batches it carried — can never fake a sustained
regime change.  It keeps, per segment index of the *currently served*
configuration:

* an EWMA of per-example seconds (smoothed trend for reporting and
  journals — one slow batch moves it by ``alpha``, never to the raw
  outlier);
* a bounded sliding window of raw per-example samples: quantiles and
  recent median for reporting, and the **recent floor** (min of the
  last k) the drift detector keys on — best-of-N semantics, immune to
  any run of fewer than k slow batches.

Overhead is engineered to be near zero when it matters:

* ``enabled=False`` (or ``sample_every=0``) makes :meth:`sample`
  return ``None`` and the engine passes no observer — the pipeline
  runs its exact un-instrumented code path;
* ``sample_every=k`` instruments only every k-th engine step, because
  observing a pipelined wave must sync device segments to read true
  wall times (see ``repro.serving.pipeline``) — sampling keeps the
  steady-state overlap while still feeding the EWMA.

Segment indices are only meaningful against one configuration, so a
hot swap must :meth:`reset` the telemetry (the ``RemapController``
does; the stats also record the placement observed, and ``reset``
clears the sampling phase so the first post-swap steps are observed).
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque


@dataclasses.dataclass
class SegmentStats:
    """Running statistics for one segment (per-example seconds)."""

    placement: str
    alpha: float
    window: deque
    ewma: float = math.nan
    count: int = 0

    def observe(self, s_per_example: float) -> None:
        self.count += 1
        self.window.append(s_per_example)
        if math.isnan(self.ewma):
            self.ewma = s_per_example
        else:
            self.ewma += self.alpha * (s_per_example - self.ewma)

    def quantile(self, q: float) -> float:
        if not self.window:
            return math.nan
        xs = sorted(self.window)
        idx = min(len(xs) - 1, max(0, round(q * (len(xs) - 1))))
        return xs[idx]

    def recent_median(self, k: int) -> float:
        """Median of the last `k` samples (robust trend, reporting)."""
        if not self.window:
            return math.nan
        xs = sorted(list(self.window)[-k:])
        mid = len(xs) // 2
        if len(xs) % 2:
            return xs[mid]
        return 0.5 * (xs[mid - 1] + xs[mid])

    def recent_floor(self, k: int) -> float:
        """Minimum of the last `k` samples — the drift detector's
        signal, matching the profiler's best-of-N semantics: genuine
        contention lifts even the best observation, while a transient
        stall (however long its spike) leaves the floor untouched, so
        no run of k-1 slow batches can fake a regime change."""
        if not self.window:
            return math.nan
        return min(list(self.window)[-k:])


class SegmentTelemetry:
    """Sampling observer over the serving pipeline's segments."""

    def __init__(
        self,
        *,
        alpha: float = 0.25,
        window: int = 64,
        sample_every: int = 1,
        warmup: int = 1,
        enabled: bool = True,
        tenant: str = "",
    ):
        """``tenant`` names the engine this telemetry instruments.
        Two engines co-served in one process (``repro.fleet``) each
        carry their own telemetry; the tenant id rides in
        :meth:`snapshot` (and from there in every ``SwapRecord``), so
        journal entries are attributable when N remap loops share a
        process."""
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        if window <= 0:
            raise ValueError("window must be positive")
        if sample_every < 0:
            raise ValueError("sample_every must be >= 0 (0 disables)")
        if warmup < 0:
            raise ValueError("warmup must be >= 0")
        self.alpha = alpha
        self.window = window
        self.sample_every = sample_every
        self.warmup = warmup
        self.enabled = enabled
        self.tenant = tenant
        self._stats: dict[int, SegmentStats] = {}
        # per-step aggregation buffer: one engine step may drain many
        # micro-batches, and each contributes an observation per
        # segment — flushed as ONE window sample (the step's best) so
        # the drift hysteresis counts *steps*, and a single stalled
        # wave-train can never fill the floor window by itself
        self._pending: dict[int, tuple] = {}   # idx -> (placement, s_ex)
        self._step = 0

    # -- engine-facing ----------------------------------------------
    def sample(self):
        """The observer for this engine step, or ``None`` when this
        step is not sampled.  Called once per non-empty step.

        The first ``warmup`` steps after construction or :meth:`reset`
        are never sampled: a hot swap resets telemetry, and the next
        step pays the new pipeline's XLA compiles — folding a compile
        into the EWMA would poison the drift baseline and trigger a
        spurious re-remap."""
        if not self.enabled or self.sample_every == 0:
            return None
        self.flush()                 # close out the previous step
        self._step += 1
        if self._step <= self.warmup:
            return None
        if (self._step - self.warmup - 1) % self.sample_every:
            return None
        return self.on_segment

    def on_segment(self, seg_index, segment, seconds, batch) -> None:
        s_ex = seconds / max(int(batch), 1)
        prev = self._pending.get(seg_index)
        if prev is None or s_ex < prev[1]:
            self._pending[seg_index] = (segment.placement, s_ex)

    def flush(self) -> None:
        """Fold the current step's per-segment aggregates (each step's
        best observation per segment) into the windows.  Called
        automatically at the next :meth:`sample` / read; direct
        feeders (tests, offline replay) call it to delimit steps."""
        for seg_index, (placement, s_ex) in self._pending.items():
            stats = self._stats.get(seg_index)
            if stats is None:
                stats = self._stats[seg_index] = SegmentStats(
                    placement=placement,
                    alpha=self.alpha,
                    window=deque(maxlen=self.window),
                )
            stats.observe(s_ex)
        self._pending.clear()

    # -- consumer-facing --------------------------------------------
    def stats(self) -> dict:
        """{segment_index: SegmentStats}, live (not a copy)."""
        self.flush()
        return self._stats

    def observed(self, seg_index: int) -> SegmentStats | None:
        self.flush()
        return self._stats.get(seg_index)

    def live_s_per_example(
        self, n_segments: int, *, min_count: int = 1
    ) -> float | None:
        """Live per-example seconds for one full step: the summed
        per-segment EWMAs over the served configuration's
        ``n_segments`` segments — what ``FleetRouter`` admission
        prefers over the profiled estimate once telemetry is warm.
        Returns ``None`` while cold: any segment unobserved, below
        ``min_count`` samples, or ``n_segments <= 0`` (a partial sum
        would systematically under-estimate the step and over-admit)."""
        self.flush()
        if n_segments <= 0:
            return None
        total = 0.0
        for i in range(n_segments):
            stats = self._stats.get(i)
            if (
                stats is None
                or stats.count < min_count
                or math.isnan(stats.ewma)
            ):
                return None
            total += stats.ewma
        return total

    def reset(self) -> None:
        """Drop all samples and the sampling phase — required after a
        configuration swap (segment indices re-key) and after a profile
        correction (the comparison baseline moved)."""
        self._stats.clear()
        self._pending.clear()
        self._step = 0

    def snapshot(self) -> dict:
        """Plain-dict summary for logs / the swap journal.  Segment
        entries are keyed by index; a non-empty :attr:`tenant` adds a
        ``"tenant"`` entry so multi-engine journals stay
        attributable."""
        self.flush()
        out: dict = {
            i: {
                "placement": s.placement,
                "count": s.count,
                "ewma_s": s.ewma,
                "p50_s": s.quantile(0.5),
                "p95_s": s.quantile(0.95),
            }
            for i, s in sorted(self._stats.items())
        }
        if self.tenant:
            out["tenant"] = self.tenant
        return out
