"""Adaptive runtime: online segment telemetry + drift-triggered
remapping for the serving engine.

The offline pipeline (profile -> map -> serve) assumes serving
conditions match profiling conditions; contention at serve time breaks
that.  This package closes the loop:

* :mod:`telemetry` — :class:`SegmentTelemetry`: sampling observer over
  ``SegmentPipeline`` recording per-segment EWMA + window quantiles,
  zero overhead when disabled;
* :mod:`drift` — :class:`DriftDetector`: sustained relative deviation
  of observed vs predicted segment times (threshold + min-sample
  hysteresis);
* :mod:`controller` — :class:`RemapController` / :func:`fold_observed`
  / :class:`SwapRecord`: fold observations into a corrected
  ProfileTable (drifted layers only), re-run the DP mapper, hot-swap
  at a batch boundary with a full audit journal; persistence via
  :class:`repro.store.ProfileStore`.

See docs/ARCHITECTURE.md §9 and benchmarks/adapt_bench.py.
"""

from repro.adapt.controller import RemapController, SwapRecord, fold_observed
from repro.adapt.drift import DriftDetector, DriftReport
from repro.adapt.telemetry import SegmentStats, SegmentTelemetry
