"""Drift detection: observed segment latencies vs the profile the DP
priced.

The mapper chose its configuration by minimizing predicted times from
a :class:`~repro.core.profiler.ProfileTable`; serving conditions
(CPU/GPU contention, thermal throttling, co-tenant load) can move the
real numbers.  :class:`DriftDetector` compares the telemetry EWMA of
each segment against the configuration's own prediction
(``EfficientConfiguration.segment_expected_times``) and flags a
segment as *drifted* only when the deviation is

* **large** — relative error beyond ``rel_threshold`` — and
* **sustained** — the deviation statistic is the **floor (minimum) of
  the last ``min_samples`` samples** (at least that many must exist),
  matching the best-of-N semantics the profiler priced the table
  under: genuine contention lifts even the best observation, so the
  floor crosses the threshold within ``min_samples`` batches of onset
  — while a transient stall, even one spanning ``min_samples - 1``
  consecutive batches, leaves the floor at the true cost.  One slow
  batch (or several) can never trigger a remap by construction — and
* **material** — the segment's share of the configuration's expected
  time is at least ``min_share``, taking the *larger* of its predicted
  and observed cost (a segment priced as negligible but observed as
  expensive is exactly the contention case), so noise on a segment
  that is negligible both ways never forces a re-solve.

``direction="slow"`` (default) reacts only to segments *slower* than
predicted — the contention case the remap can route around.
``"both"`` also reports faster-than-predicted segments, which a
controller may fold back to tighten the profile.
"""

from __future__ import annotations

import dataclasses

from repro.core.mapper import EfficientConfiguration

DIRECTIONS = ("slow", "both")


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """One drifted segment: the evidence a remap decision cites."""

    segment_index: int
    placement: str
    predicted_s: float        # per-example, from the configuration
    observed_s: float         # per-example recent-floor from telemetry
    samples: int

    @property
    def ratio(self) -> float:
        """observed / predicted (> 1 means slower than priced)."""
        if self.predicted_s <= 0.0:
            return float("inf")
        return self.observed_s / self.predicted_s


class DriftDetector:
    def __init__(
        self,
        *,
        rel_threshold: float = 0.5,
        min_samples: int = 8,
        min_share: float = 0.01,
        direction: str = "slow",
    ):
        if rel_threshold <= 0.0:
            raise ValueError("rel_threshold must be positive")
        if min_samples < 1:
            raise ValueError("min_samples must be >= 1")
        if direction not in DIRECTIONS:
            raise ValueError(
                f"direction must be one of {DIRECTIONS}, got {direction!r}"
            )
        self.rel_threshold = rel_threshold
        self.min_samples = min_samples
        self.min_share = min_share
        self.direction = direction

    def check(
        self, config: EfficientConfiguration, telemetry
    ) -> tuple:
        """Drifted segments of `config` given `telemetry`, as a tuple
        of :class:`DriftReport` (empty: no sustained deviation)."""
        predicted = config.segment_expected_times()
        total = sum(predicted)
        segments = config.segments()
        reports = []
        for idx, (seg, pred) in enumerate(zip(segments, predicted)):
            stats = telemetry.observed(idx)
            # gate on samples actually *retained*, not the lifetime
            # count: with a telemetry window shorter than min_samples,
            # recent_floor would min over fewer samples than the
            # hysteresis contract promises and a short stall could
            # fake a sustained regime change
            if stats is None or len(stats.window) < self.min_samples:
                continue
            obs = stats.recent_floor(self.min_samples)
            if total > 0.0 and max(pred, obs) / total < self.min_share:
                continue
            hi = pred * (1.0 + self.rel_threshold)
            lo = pred / (1.0 + self.rel_threshold)
            slow = obs > hi
            fast = obs < lo and self.direction == "both"
            if not (slow or fast):
                continue
            reports.append(
                DriftReport(
                    segment_index=idx,
                    placement=seg.placement,
                    predicted_s=pred,
                    observed_s=obs,
                    samples=stats.count,
                )
            )
        return tuple(reports)
