"""Serving runtime for HEP-mapped BNNs — the inference stack the
paper's cost model assumes.

* :mod:`pipeline` — :class:`SegmentPipeline`: executes the mapper's
  segments (maximal same-placement layer runs) as a two-stage software
  pipeline, overlapping the host segments of micro-batch *i+1* with
  the device segments of micro-batch *i*.
* :mod:`batcher` — :class:`MicroBatcher`: dynamic request coalescing
  with max-batch / max-wait knobs and padding to profiled batch sizes
  so the ProfileTable entries stay valid.
* :mod:`engine` — :class:`ServingEngine`: the front end gluing the
  two together behind ``submit()`` / ``step()``, with atomic
  batch-boundary configuration hot-swap (``swap_configuration``) and
  an optional telemetry observer — the attachment points the adaptive
  runtime (``repro.adapt``) drives.
"""

from repro.serving.batcher import MicroBatch, MicroBatcher, Request, pad_to
from repro.serving.engine import ServingEngine
from repro.serving.pipeline import SegmentPipeline, canonical_mixed_mapping
