"""Segment-pipelined execution of a mapped BNN.

The mapper's :meth:`EfficientConfiguration.segments` splits the layer
sequence into maximal same-placement runs; adjacent segments alternate
host <-> device, so execution is a chain

    [host seg] -> H2D -> [device seg] -> D2H -> [host seg] -> ...

:class:`SegmentPipeline` runs a *stream* of micro-batches through that
chain as a software pipeline: micro-batch ``i`` enters at wave ``i``
and advances one segment per wave, so in any wave at most one
micro-batch occupies each segment.  Within a wave, device segments are
dispatched first (JAX async dispatch returns immediately) and host
segments run afterwards on the Python thread — overlapping the host
work of micro-batch *i+1* with the in-flight device work of
micro-batch *i*.  H2D uploads are double-buffered: micro-batch
*i+1*'s input is staged with :func:`jax.device_put` while wave *i* is
still executing, and the D2H sync for a device segment's output is
deferred one full wave, so the download price is paid only after the
device had a wave's worth of time to finish.

Placement is modeled the same way as the faithful
``mapped_model`` driver: "host" activations are materialized
``numpy`` arrays, "device" activations are JAX arrays left to XLA's
asynchronous runtime.  On a CPU-only container both ultimately
execute on the XLA host device, but the sync structure — where the
Python thread blocks, where transfers are staged — is exactly the one
the cost model prices, and it is the structure that generalizes to a
real accelerator backend.

All arithmetic is int32/bool, so pipelined, serial, and fused
execution are bit-exact for the same inputs.

**Telemetry hook.**  Both drivers accept an ``observer`` — a callable
``observer(seg_index, segment, seconds, batch)`` fired once per
(micro-batch, segment) execution with the segment's wall time for a
``batch``-row micro-batch.  With ``observer=None`` (the default) the
drivers are exactly the un-instrumented code paths — zero overhead.
When observing, the pipelined driver must block on each device
segment's output to read a true wall time, which serializes that
wave's device/host overlap; the adaptive runtime
(``repro.adapt.SegmentTelemetry``) therefore *samples* — it hands an
observer to only every k-th step — so steady-state throughput keeps
the overlap.
"""

from __future__ import annotations

import time
from typing import Callable, Sequence

import jax
import numpy as np

from repro.bnn.models import BNNModel
from repro.core.mapped_model import build_node_fns
from repro.core.mapper import EfficientConfiguration
from repro.core.parallel_config import CPU, FULL_GPU
from repro.core.plan import SegmentPlan, build_plan


def canonical_mixed_mapping(model: BNNModel) -> tuple:
    """The canonical mixed host/device split for serving experiments:
    GEMM layers (conv/fc) on the device, elementwise layers on the
    host — guarantees alternating segments so the two-stage pipeline
    has work to overlap.  Shared by benchmarks and tests so they
    exercise the same schedule."""
    return tuple(
        FULL_GPU if s.kind in ("conv", "fc") else CPU
        for s in model.specs
    )


class SegmentPipeline:
    """Compiled executables for a ``"segments"``-mode
    :class:`~repro.core.plan.SegmentPlan`, plus serial and pipelined
    drivers over its nodes.

    The pipeline schedules **plan nodes**: the plan (built once from
    the configuration, or passed in pre-built) fixes each node's
    placement, boundary transfers and fused-variant choice; the
    drivers below only decide *when* each node runs and where the
    Python thread blocks.  Plan nodes duck-type ``mapper.Segment``,
    so observers and telemetry consumers see the same interface as
    before the IR existed.
    """

    def __init__(
        self,
        model: BNNModel,
        packed_params: list,
        config: EfficientConfiguration,
        *,
        device=None,
        plan: SegmentPlan | None = None,
        registry=None,
    ):
        self.config = config
        if plan is None:
            plan = build_plan(config, mode="segments")
        elif plan.mode != "segments":
            raise ValueError(
                f"SegmentPipeline schedules 'segments'-mode plans, "
                f"got mode {plan.mode!r}"
            )
        self.plan = plan
        self.segment_fns = build_node_fns(
            model, packed_params, config, plan, registry
        )
        self.device = device if device is not None else jax.devices()[0]

    @property
    def segments(self) -> tuple:
        return tuple(seg for seg, _ in self.segment_fns)

    # -- serial reference: one micro-batch at a time, Python thread
    #    blocks at every segment boundary (no overlap) ---------------
    def run_serial(self, x_words, *, observer: Callable | None = None):
        x = np.asarray(x_words)
        batch = x.shape[0]
        for s, (seg, fn) in enumerate(self.segment_fns):
            t0 = time.perf_counter() if observer is not None else 0.0
            if seg.on_device:
                out = fn(jax.device_put(x, self.device))
                jax.block_until_ready(out)
                x = np.asarray(out)          # D2H before the next segment
            else:
                out = fn(x)
                jax.block_until_ready(out)
                x = out
            if observer is not None:
                observer(s, seg, time.perf_counter() - t0, batch)
        return np.asarray(x)

    # -- pipelined driver over a micro-batch stream ------------------
    def run_pipelined(
        self,
        inputs: Sequence,
        *,
        on_complete: Callable | None = None,
        observer: Callable | None = None,
    ) -> list:
        """Run `inputs` (a list of micro-batch word arrays) through the
        segment chain with a one-segment-per-wave skew.

        ``on_complete(i, out)`` fires as soon as micro-batch ``i``'s
        output is materialized on the host — the per-micro-batch
        completion point for latency measurement.  Returns outputs in
        input order.

        ``observer(seg_index, segment, seconds, batch)`` fires per
        (micro-batch, segment) with the segment's wall time.  Observing
        blocks on device-segment outputs (a true wall time needs a
        sync), trading that wave's overlap for measurement — pass an
        observer only on sampled steps (module docstring).
        """
        segs = self.segment_fns
        k, n = len(segs), len(inputs)
        if n == 0:
            return []
        first_on_device = segs[0][0].on_device
        state: list = [None] * n
        staged: list = [None] * n
        outputs: list = [None] * n

        def stage(i):
            # double-buffered H2D: the upload is issued a wave before
            # micro-batch i first executes
            x = np.asarray(inputs[i])
            staged[i] = (
                jax.device_put(x, self.device) if first_on_device else x
            )

        stage(0)
        for w in range(n + k - 1):
            active = [
                (i, w - i)
                for i in range(max(0, w - k + 1), min(n - 1, w) + 1)
            ]
            if w + 1 < n:
                stage(w + 1)
            # device advances first: async dispatch keeps the device
            # busy while this wave's host segments run below
            for i, s in active:
                seg, fn = segs[s]
                if seg.on_device:
                    x = staged[i] if s == 0 else state[i]
                    staged[i] = None        # keep only ~2 live buffers
                    if not isinstance(x, jax.Array):
                        x = jax.device_put(x, self.device)
                    if observer is None:
                        state[i] = fn(x)
                    else:
                        t0 = time.perf_counter()
                        out = fn(x)
                        jax.block_until_ready(out)
                        observer(
                            s, seg, time.perf_counter() - t0, x.shape[0]
                        )
                        state[i] = out
            # host advances: np.asarray is the deferred D2H sync on the
            # previous wave's device output
            for i, s in active:
                seg, fn = segs[s]
                if not seg.on_device:
                    x = staged[i] if s == 0 else state[i]
                    staged[i] = None
                    if observer is None:
                        state[i] = fn(np.asarray(x))
                    else:
                        # timing includes the deferred D2H sync of the
                        # upstream device output — the host stage pays
                        # it in the un-instrumented driver too
                        t0 = time.perf_counter()
                        xh = np.asarray(x)
                        out = fn(xh)
                        jax.block_until_ready(out)
                        observer(
                            s, seg, time.perf_counter() - t0, xh.shape[0]
                        )
                        state[i] = out
            # completions: micro-batch i leaves the pipeline
            for i, s in active:
                if s == k - 1:
                    outputs[i] = np.asarray(state[i])
                    state[i] = None
                    if on_complete is not None:
                        on_complete(i, outputs[i])
        return outputs
