"""The serving front end: micro-batching + segment pipelining behind
``submit()`` / ``step()``.

    engine = ServingEngine(model, packed, ec,
                           allowed_batch_sizes=table.batch_sizes)
    reqs = [engine.submit(x_words_one_example) for x in traffic]
    engine.step(force=True)          # or step() in a poll loop
    scores = [r.wait() for r in reqs]

``step()`` drains every ready micro-batch from the batcher and runs
them *together* through the segment pipeline, so a burst of traffic is
where the pipelining pays: the host segments of one micro-batch
overlap the device segments of the previous one.  Each request is
completed (result + latency timestamp) the moment its micro-batch's
output materializes, not when the whole wave-train finishes.
"""

from __future__ import annotations

import time
from typing import Sequence

from repro.bnn.models import BNNModel
from repro.core.mapper import EfficientConfiguration
from repro.serving.batcher import MicroBatcher, Request
from repro.serving.pipeline import SegmentPipeline


class ServingEngine:
    def __init__(
        self,
        model: BNNModel,
        packed_params: list,
        config: EfficientConfiguration,
        *,
        max_batch: int | None = None,
        max_wait_s: float = 2e-3,
        allowed_batch_sizes: Sequence[int] | None = None,
        clock=time.monotonic,
        device=None,
    ):
        """``max_batch`` defaults to the mapper's proper batch size —
        the batch the configuration was optimized for.  Pass the
        ProfileTable's ``batch_sizes`` as ``allowed_batch_sizes`` so
        partial batches pad to a profiled size."""
        if max_batch is None:
            max_batch = config.proper_batch_size
        if allowed_batch_sizes is None:
            allowed_batch_sizes = (max_batch,)
        self.config = config
        self.pipeline = SegmentPipeline(
            model, packed_params, config, device=device
        )
        self.batcher = MicroBatcher(
            max_batch=max_batch,
            max_wait_s=max_wait_s,
            allowed_batch_sizes=allowed_batch_sizes,
            clock=clock,
        )
        self._clock = clock
        self.served = 0

    def submit(self, x_words_one) -> Request:
        """Enqueue one example (packed words, no batch dim)."""
        return self.batcher.submit(x_words_one)

    def step(self, *, force: bool = False) -> int:
        """Drain ready micro-batches (all pending ones when ``force``)
        and execute them pipelined.  Returns requests completed."""
        batches = self.batcher.drain(force=force)
        if not batches:
            return 0

        def complete(i, out):
            mb = batches[i]
            now = self._clock()
            for j, req in enumerate(mb.requests):
                req.complete(out[j], now)   # pad rows out[n_real:] dropped

        try:
            self.pipeline.run_pipelined(
                [mb.x for mb in batches], on_complete=complete
            )
        except BaseException as e:
            # requests already popped off the queue must not be lost:
            # fail every not-yet-completed one so waiters see the error
            now = self._clock()
            for mb in batches:
                for req in mb.requests:
                    if req.done_t is None:
                        req.fail(e, now)
            raise
        done = sum(mb.n_real for mb in batches)
        self.served += done
        return done
