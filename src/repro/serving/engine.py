"""The serving front end: micro-batching + segment pipelining behind
``submit()`` / ``step()``.

    engine = ServingEngine(model, packed, ec,
                           allowed_batch_sizes=table.batch_sizes)
    reqs = [engine.submit(x_words_one_example) for x in traffic]
    engine.step(force=True)          # or step() in a poll loop
    scores = [r.wait() for r in reqs]

``step()`` drains every ready micro-batch from the batcher and runs
them *together* through the segment pipeline, so a burst of traffic is
where the pipelining pays: the host segments of one micro-batch
overlap the device segments of the previous one.  Each request is
completed (result + latency timestamp) the moment its micro-batch's
output materializes, not when the whole wave-train finishes.
``step(force=True)`` on an idle engine (empty queue) is a guaranteed
no-op: nothing is padded, nothing runs, pending swaps still apply —
the batch boundary exists even when no batch does.

**Hot swap.**  :meth:`swap_configuration` replaces the served
``EfficientConfiguration`` (and its compiled segment pipeline)
*atomically at a batch boundary*: a swap requested while a step is
executing — e.g. from a completion callback, or by a controller
reacting to telemetry mid-wave — is deferred and applied after the
in-flight wave-train retires, so no micro-batch ever sees two
configurations.  The new pipeline is built *before* the old one is
released; a failed build leaves the engine serving the old mapping.
The adaptive loop around this primitive (telemetry -> drift ->
corrected table -> re-mapped configuration) lives in ``repro.adapt``.

**Threading contract.**  ``submit()`` is thread-safe — any number of
client threads may enqueue concurrently (the ``MicroBatcher`` queue is
lock-protected and FIFO by submission order), and ``Request.wait()``
blocks safely on any thread.  ``step()`` / ``swap_configuration()``
are **not** reentrant: drive them from a single dispatch thread (the
pattern ``repro.fleet.FleetRouter`` runs — N client threads
submitting, one router thread stepping).  Two threads stepping one
engine concurrently would interleave two wave-trains through one
pipeline and corrupt the served/steps accounting.
"""

from __future__ import annotations

import time
from typing import Sequence

from repro.bnn.models import BNNModel
from repro.core.mapper import EfficientConfiguration
from repro.serving.batcher import MicroBatcher, Request
from repro.serving.pipeline import SegmentPipeline


def _tee(always, sampled):
    """Compose the always-on observer with a (possibly absent)
    sampled telemetry observer into one pipeline callback."""
    if sampled is None:
        return always

    def observe(seg_index, segment, seconds, batch):
        always(seg_index, segment, seconds, batch)
        sampled(seg_index, segment, seconds, batch)

    return observe


class ServingEngine:
    def __init__(
        self,
        model: BNNModel,
        packed_params: list,
        config: EfficientConfiguration,
        *,
        max_batch: int | None = None,
        max_wait_s: float = 2e-3,
        allowed_batch_sizes: Sequence[int] | None = None,
        clock=time.monotonic,
        device=None,
        telemetry=None,
        observer=None,
    ):
        """``max_batch`` defaults to the mapper's proper batch size —
        the batch the configuration was optimized for.  Pass the
        ProfileTable's ``batch_sizes`` as ``allowed_batch_sizes`` so
        partial batches pad to a profiled size.  ``telemetry``
        (``repro.adapt.SegmentTelemetry``) records per-segment wall
        times on its sampled steps; ``None`` serves un-instrumented.
        ``observer`` is an *always-on* segment observer fired on every
        step (composed with the sampled telemetry observer when both
        are present) — the fleet device-time ledger's feed
        (``DeviceTimeLedger.observer(tenant)``).  An observer forces
        the pipelined driver to sync device segments for true wall
        times, so always-on observation trades overlap for metered
        occupancy (see ``repro.serving.pipeline``)."""
        if max_batch is None:
            max_batch = config.proper_batch_size
        if allowed_batch_sizes is None:
            allowed_batch_sizes = (max_batch,)
        self.model = model
        self.packed_params = packed_params
        self.config = config
        self._device = device
        self.pipeline = self._build_pipeline(config)
        self.batcher = MicroBatcher(
            max_batch=max_batch,
            max_wait_s=max_wait_s,
            allowed_batch_sizes=allowed_batch_sizes,
            clock=clock,
        )
        self._clock = clock
        self.telemetry = telemetry
        self.observer = observer
        self.served = 0
        self.steps = 0               # non-empty steps (batch boundaries)
        self.swaps = 0
        self._in_step = False
        self._pending_swap: EfficientConfiguration | None = None

    def _build_pipeline(self, config: EfficientConfiguration):
        """Compile the segment pipeline for `config`.  Subclass seam:
        benchmarks wrap the returned pipeline's host segments to inject
        synthetic contention (``benchmarks/adapt_bench.py``), and
        ``repro.elastic.ElasticEngine`` compiles each subnet level
        through it (with that level's ``self.model`` /
        ``self.packed_params`` published) so wrappers apply to every
        level."""
        return SegmentPipeline(
            self.model, self.packed_params, config, device=self._device
        )

    def submit(self, x_words_one) -> Request:
        """Enqueue one example (packed words, no batch dim)."""
        return self.batcher.submit(x_words_one)

    # -- configuration hot swap -------------------------------------
    def swap_configuration(self, config: EfficientConfiguration) -> bool:
        """Serve `config` from the next batch boundary on.

        Returns True when the swap applied immediately (engine idle
        between steps) and False when it was deferred to the end of the
        step currently executing — either way, every request completes
        under exactly one configuration.  Only the last swap requested
        during a step wins (remaps supersede each other).

        Swaps must keep the serving batch size: the batcher's
        coalescing/padding targets were sized for it, and a
        configuration priced at another batch would be served (and
        drift-checked) at a batch the mapper never chose.  Re-batching
        is an engine rebuild, not a swap."""
        if config.proper_batch_size != self.config.proper_batch_size:
            raise ValueError(
                f"hot swap must preserve the serving batch size "
                f"(engine serves {self.config.proper_batch_size}, new "
                f"configuration is for {config.proper_batch_size}); "
                "build a new engine to change batch size"
            )
        if self._in_step:
            self._pending_swap = config
            return False
        self._apply_swap(config)
        return True

    def _apply_swap(self, config: EfficientConfiguration) -> None:
        # reprice-only swaps (same mapping, corrected expectations —
        # the controller's calibration case) keep the compiled
        # pipeline: the executables depend only on layer_configs and
        # the fused-segment selections, and a pointless re-jit would
        # stall the serving hot path
        if (
            config.layer_configs != self.config.layer_configs
            or getattr(config, "fused_segments", ())
            != getattr(self.config, "fused_segments", ())
        ):
            # build first, publish second: a failed build
            # (unregistered variant, bad mapping) must leave the old
            # config serving
            self.pipeline = self._build_pipeline(config)
        self.config = config
        self.swaps += 1

    def step(self, *, force: bool = False) -> int:
        """Drain ready micro-batches (all pending ones when ``force``)
        and execute them pipelined.  Returns requests completed.

        An empty queue is a no-op even under ``force`` — the batcher
        never fabricates a zero batch to pad-and-run (regression:
        ``tests/test_adapt.py``), and a pending swap still applies."""
        batches = self.batcher.drain(force=force)
        if not batches:
            self._drain_pending_swap()
            return 0

        def complete(i, out):
            mb = batches[i]
            now = self._clock()
            for j, req in enumerate(mb.requests):
                req.complete(out[j], now)   # pad rows out[n_real:] dropped

        observer = None
        if self.telemetry is not None:
            observer = self.telemetry.sample()
        if self.observer is not None:
            observer = _tee(self.observer, observer)
        self._in_step = True
        try:
            self.pipeline.run_pipelined(
                [mb.x for mb in batches],
                on_complete=complete,
                observer=observer,
            )
        except BaseException as e:
            # requests already popped off the queue must not be lost:
            # fail every not-yet-completed one so waiters see the error.
            # A pending swap stays pending (applied at the next batch
            # boundary) — applying it here could raise a build error
            # that masks the serving failure being diagnosed
            now = self._clock()
            for mb in batches:
                for req in mb.requests:
                    if req.done_t is None:
                        req.fail(e, now)
            raise
        finally:
            self._in_step = False
        done = sum(mb.n_real for mb in batches)
        self.served += done
        self.steps += 1
        # the batch boundary: a swap requested mid-step lands here,
        # after the step's work is fully accounted — a failed pipeline
        # build raises from step() but never corrupts served/steps
        self._drain_pending_swap()
        return done

    def _drain_pending_swap(self) -> None:
        if self._pending_swap is not None:
            config, self._pending_swap = self._pending_swap, None
            self._apply_swap(config)
