"""Dynamic micro-batching for the serving engine.

Single-example requests are coalesced into micro-batches under two
knobs: ``max_batch`` (close a batch as soon as it is full) and
``max_wait_s`` (close a partial batch once its oldest request has
waited long enough).  Partial batches are **padded up to a profiled
batch size** so every micro-batch the pipeline executes is one the
:class:`~repro.core.profiler.ProfileTable` actually measured — the
mapper's expected times (and the proper-batch-size choice itself) stay
valid for the traffic the engine serves.  Pad rows are zeros and their
outputs are discarded before responses complete.

The clock is injectable so coalescing deadlines are deterministic
under test.

**Thread-safety.**  :meth:`MicroBatcher.submit` may be called from
any number of threads concurrently — the queue is lock-protected and
FIFO by submission timestamp (the clock is read under the lock, so
queue order and ``submit_t`` order agree).  ``next_batch``/``drain``
are also lock-safe (two drainers never pop the same request), but the
serving engine's step path is single-threaded by contract — see
``repro.serving.engine``.  The fleet router depends on exactly this
split: client threads submit, one dispatch thread drains.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    """One in-flight example.  ``wait()`` blocks until the engine
    completes it; ``submit_t``/``done_t`` bound its serving latency."""

    x: np.ndarray
    submit_t: float
    result: np.ndarray | None = None
    error: BaseException | None = None
    done_t: float | None = None
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False
    )

    def complete(self, result: np.ndarray, now: float) -> None:
        self.result = result
        self.done_t = now
        self._done.set()

    def fail(self, error: BaseException, now: float) -> None:
        """Terminal error path: a request popped off the queue must
        never be silently dropped — waiters get the exception."""
        self.error = error
        self.done_t = now
        self._done.set()

    def wait(self, timeout: float | None = None) -> np.ndarray:
        if not self._done.wait(timeout):
            raise TimeoutError("request not completed")
        if self.error is not None:
            raise self.error
        return self.result

    @property
    def latency_s(self) -> float:
        if self.done_t is None:
            raise ValueError("request not completed")
        return self.done_t - self.submit_t


@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """`requests` stacked into `x`, zero-padded from ``n_real`` rows up
    to a profiled batch size."""

    requests: tuple
    x: np.ndarray
    n_real: int

    @property
    def padded_size(self) -> int:
        return self.x.shape[0]


def pad_to(n: int, allowed: Sequence[int] | None) -> int:
    """Smallest allowed batch size that fits ``n`` requests (``n``
    itself when ``allowed`` is None — an empty sequence is an error,
    not an absence of constraint)."""
    if n <= 0:
        raise ValueError("cannot pad an empty batch")
    if allowed is None:
        return n
    if not allowed:
        raise ValueError("allowed batch sizes must be non-empty")
    fits = [s for s in allowed if s >= n]
    if not fits:
        raise ValueError(
            f"batch of {n} exceeds every allowed size {tuple(allowed)}"
        )
    return min(fits)


class MicroBatcher:
    """Thread-safe FIFO request queue with deadline-based coalescing."""

    def __init__(
        self,
        *,
        max_batch: int,
        max_wait_s: float = 2e-3,
        allowed_batch_sizes: Sequence[int] | None = None,
        clock=time.monotonic,
    ):
        if max_batch <= 0:
            raise ValueError("max_batch must be positive")
        if allowed_batch_sizes is not None:
            allowed_batch_sizes = tuple(sorted(allowed_batch_sizes))
            if not allowed_batch_sizes:
                raise ValueError(
                    "allowed_batch_sizes must be non-empty when given"
                )
            if max_batch > allowed_batch_sizes[-1]:
                raise ValueError(
                    f"max_batch {max_batch} exceeds the largest profiled "
                    f"batch size {allowed_batch_sizes[-1]}"
                )
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.allowed_batch_sizes = allowed_batch_sizes
        self._clock = clock
        self._lock = threading.Lock()
        self._queue: deque = deque()

    def submit(self, x) -> Request:
        x = np.asarray(x)
        # the clock is read *inside* the lock: two threads racing
        # submit() must enqueue in timestamp order, or ready()'s
        # oldest-request age check could read a non-head timestamp and
        # a batch's coalescing deadline would jitter by the race window
        with self._lock:
            req = Request(x=x, submit_t=self._clock())
            self._queue.append(req)
        return req

    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    def ready(self) -> bool:
        """A batch is ready when it is full, or its oldest request has
        aged past ``max_wait_s``."""
        with self._lock:
            if not self._queue:
                return False
            if len(self._queue) >= self.max_batch:
                return True
            return (
                self._clock() - self._queue[0].submit_t >= self.max_wait_s
            )

    def next_batch(self, *, force: bool = False) -> MicroBatch | None:
        """Pop up to ``max_batch`` requests into a padded MicroBatch;
        None when nothing is ready (``force`` flushes a partial batch
        regardless of its age)."""
        if not force and not self.ready():
            return None
        with self._lock:
            if not self._queue:
                return None
            take = min(len(self._queue), self.max_batch)
            reqs = tuple(self._queue.popleft() for _ in range(take))
        xs = np.stack([r.x for r in reqs])
        target = pad_to(len(reqs), self.allowed_batch_sizes)
        if target > len(reqs):
            pad = np.zeros((target - len(reqs),) + xs.shape[1:], xs.dtype)
            xs = np.concatenate([xs, pad])
        return MicroBatch(requests=reqs, x=xs, n_real=len(reqs))

    def drain(self, *, force: bool = True) -> list:
        """All currently-poppable micro-batches, oldest first."""
        batches = []
        while (mb := self.next_batch(force=force)) is not None:
            batches.append(mb)
        return batches

    def migrate_to(self, other: "MicroBatcher") -> int:
        """Move every *queued* (not yet dispatched) request into
        `other`'s queue, preserving submit-timestamp order against
        requests already waiting there.  The Request objects move
        as-is — callers holding them block on the same event and
        complete on the destination's engine.  Returns requests moved.

        Locks are taken strictly sequentially (drain self fully, then
        lock other), never nested, so concurrent submitters on either
        batcher cannot deadlock against a migration."""
        if other is self:
            return 0
        with self._lock:
            moving = list(self._queue)
            self._queue.clear()
        if not moving:
            return 0
        with other._lock:
            merged = sorted(
                list(other._queue) + moving, key=lambda r: r.submit_t
            )
            other._queue.clear()
            other._queue.extend(merged)
        return len(moving)
