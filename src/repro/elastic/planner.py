"""Per-level planning for a subnet family: profile (or predict), map,
fuse, persist — K resident mappings from one pass.

Each :class:`~repro.elastic.subnet.SubnetLevel` is an ordinary
``BNNModel`` + packed params, so it flows through the exact
profile→map(→fuse) chain every other model uses
(:func:`repro.api.plan_single`).  What this module adds:

* **level-tagged persistence** — narrow levels are named
  ``{base}#L{k}`` so their profiles and mappings land under distinct
  :class:`~repro.store.ProfileStore` keys; all K mappings warm-start
  independently and are resident simultaneously;
* **zero-sweep narrow levels** — with ``estimate=True`` and a store
  that holds a fitted :class:`~repro.estimator.LatencyPredictor`, the
  narrow levels' tables are *predicted* (``provenance="predicted"``,
  zero profiling passes) and only mapped+persisted; level 0 is always
  real (it is the model you already profiled);
* **swap compatibility** — every level must resolve to the same
  proper batch size (the serving engine hot-swaps configurations at
  batch boundaries and refuses a batch-size change mid-flight); the
  planner enforces this up front.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.elastic.subnet import SubnetFamily


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """One :class:`~repro.api.TenantPlan` per subnet level, widest
    first.  ``predicted[k]`` records whether level k's table came from
    the latency predictor (True) or a real profiling sweep."""

    family: SubnetFamily
    levels: tuple            # TenantPlan per level, widest first
    predicted: tuple         # bool per level

    @property
    def base(self):
        return self.levels[0]

    @property
    def configs(self) -> tuple:
        """Per-level EfficientConfigurations, widest first — what an
        elastic engine holds resident."""
        return tuple(tp.config for tp in self.levels)

    @property
    def batch(self) -> int:
        return self.levels[0].config.proper_batch_size

    def __len__(self) -> int:
        return len(self.levels)


def _predict_level(level, store, *, batch_sizes, registry, configs):
    """Predicted ProfileTable for a narrow level, or None when the
    store holds no fitted predictor."""
    if store is None:
        return None
    predictor = store.load_predictor()
    if predictor is None:
        return None
    return predictor.predict_table(
        level.model, batch_sizes, registry=registry, configs=configs
    )


def plan_family(
    family: SubnetFamily,
    *,
    base=None,
    batch_sizes: Sequence[int] = (4,),
    store=None,
    policy: str = "dp",
    configs: Sequence[str] | None = None,
    autotune: bool = False,
    fuse: bool = False,
    repeats: int = 2,
    time_source: str = "measured",
    registry=None,
    estimate: bool = False,
) -> ElasticPlan:
    """Plan every level of `family`; returns an :class:`ElasticPlan`.

    `base` is an already-planned :class:`~repro.api.TenantPlan` for
    the full model (level 0) — pass it to reuse the profile/mapping a
    solo or fleet plan already produced (the elastic serve path does
    this so level 0 keeps its joint contention-priced config); its
    batch sizes override `batch_sizes` so narrow levels price the
    batches the engine will actually run.  ``estimate=True`` prices
    narrow levels through the store's persisted latency predictor
    when one exists (zero extra sweeps), silently falling back to
    real profiling when the store has never been ``refit``.
    """
    from repro.api import TenantPlan, _as_store, map_model, plan_single

    store = _as_store(store)
    if base is not None:
        if base.model is not family.base.model:
            raise ValueError(
                "base TenantPlan was built for a different model than "
                "family level 0"
            )
        batch_sizes = tuple(base.table.batch_sizes)
    levels: list = []
    predicted: list = []
    for lvl in family:
        if lvl.level == 0 and base is not None:
            levels.append(base)
            predicted.append(False)
            continue
        table = None
        if estimate and lvl.level > 0:
            table = _predict_level(
                lvl, store, batch_sizes=batch_sizes,
                registry=registry, configs=configs,
            )
        if table is not None:
            config = map_model(table, policy=policy, configs=configs)
            if store is not None:
                # persist the mapping only: predicted tables must not
                # masquerade as measured profiles under the store key
                store.save_mapping(config)
            levels.append(
                TenantPlan(
                    name=lvl.model.name, model=lvl.model,
                    packed=lvl.packed, table=table, config=config,
                )
            )
            predicted.append(True)
        else:
            levels.append(
                plan_single(
                    lvl.model, lvl.packed, batch_sizes=batch_sizes,
                    store=store, policy=policy, configs=configs,
                    autotune=autotune, fuse=fuse, repeats=repeats,
                    time_source=time_source, registry=registry,
                    name=lvl.model.name,
                )
            )
            predicted.append(False)
    batches = {tp.config.proper_batch_size for tp in levels}
    if len(batches) != 1:
        raise ValueError(
            f"subnet levels resolved to different proper batch sizes "
            f"{sorted(batches)}; hot swaps require one — pass a single "
            "batch in batch_sizes"
        )
    return ElasticPlan(
        family=family, levels=tuple(levels), predicted=tuple(predicted)
    )
