"""Nested-width BNN subnets as prefix views of one packed model.

A binarized model's inference parameters are bit-packed int32 words
(``repro.bnn.binarize``): conv weights are ``(Cout, 9*ceil(Cin/32))``
word matrices, FC weights ``(Dout, ceil(Din/32))``, step layers a
per-channel integer threshold.  Because every hidden width in the
paper models (and anything ``build_model`` produces) is a multiple of
the 32-bit pack width, *narrowing a layer is word slicing*: the first
``C/32`` words of each patch block are exactly what an independently
packed ``C``-channel weight would contain — no tail lanes, no repack,
no weight copy.  That is what makes OFA-style nested subnets nearly
free for BNNs: K width levels share one resident tensor set, and each
narrower level is a prefix view of the wider one.

:class:`ElasticSpec` names the width fractions (widest first, level 0
always the full model); :class:`SubnetFamily` derives one
:class:`BNNModel` + packed-parameter list per level by slicing the
base model's packed tensors.  Slicing is **bit-exact** against
building the same-width model from scratch (slice the latent fp
weights with :func:`slice_params_fp`, quantize with ``pack_params``):
packing is deterministic LSB-first, widths stay word-aligned, so the
prefix words are byte-identical — property-tested in
``tests/test_elastic.py``.

Level naming: level 0 keeps the base model's name (its profile and
mapping are shared with non-elastic deployments of the same model —
latency depends on architecture, not weights); level ``k > 0`` is
named ``{base}#L{k}``, which tags every store key for that level
(``model_signature`` hashes name + per-layer labels) so the K
mappings live side by side in one :class:`~repro.store.ProfileStore`.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.bnn.binarize import PACK_W, packed_len
from repro.bnn.layers import LayerSpec, parse_notation
from repro.bnn.models import BNNModel


@dataclasses.dataclass(frozen=True)
class ElasticSpec:
    """Width fractions of the nested subnet family, widest first.

    ``fractions[0]`` must be 1.0 (level 0 is the full model) and the
    rest strictly decreasing in (0, 1).  Each conv/FC width scales as
    ``max(min_units, int(units * fraction))`` floored to a multiple of
    the 32-bit pack width — the same rule ``build_model(scale=)``
    uses, so a family level has exactly the widths of an
    independently-scaled model.  The final FC always maps to
    ``n_classes`` and is never narrowed.
    """

    fractions: tuple = (1.0, 0.5)
    min_units: int = PACK_W

    def __post_init__(self):
        fr = tuple(float(f) for f in self.fractions)
        object.__setattr__(self, "fractions", fr)
        if not fr or fr[0] != 1.0:
            raise ValueError(
                f"fractions must start at 1.0 (the full model), got {fr}"
            )
        if any(not 0.0 < f <= 1.0 for f in fr):
            raise ValueError(f"fractions must lie in (0, 1], got {fr}")
        if any(b >= a for a, b in zip(fr, fr[1:])):
            raise ValueError(
                f"fractions must be strictly decreasing, got {fr}"
            )
        if self.min_units < PACK_W or self.min_units % PACK_W:
            raise ValueError(
                f"min_units must be a positive multiple of {PACK_W}"
            )

    def width(self, units: int, fraction: float) -> int:
        """`units` scaled by `fraction`, word-aligned, floored at
        ``min_units`` — mirrors ``build_model``'s shrink rule."""
        n = max(self.min_units, int(units * fraction))
        return (n // PACK_W) * PACK_W

    def __len__(self) -> int:
        return len(self.fractions)


@dataclasses.dataclass(frozen=True)
class SubnetLevel:
    """One width level: a full :class:`BNNModel` + packed params whose
    weight words are (for ``level > 0``) prefix slices of the base
    model's."""

    level: int
    fraction: float
    model: BNNModel
    packed: list


def level_name(base_name: str, level: int) -> str:
    """The store-visible model name of a family level — level 0 keeps
    the base name, narrower levels carry the ``#L{k}`` tag that keys
    their profiles/mappings apart."""
    return base_name if level == 0 else f"{base_name}#L{level}"


def _narrow_notation(
    model: BNNModel, fraction: float, spec: ElasticSpec
) -> tuple:
    """Paper-notation tokens for `model` narrowed by `fraction`."""
    last_fc = max(
        i for i, s in enumerate(model.specs) if s.kind == "fc"
    )
    tokens = []
    for i, s in enumerate(model.specs):
        if s.kind == "conv":
            tokens.append(f"C{spec.width(s.units, fraction)}")
        elif s.kind == "fc" and i != last_fc:
            tokens.append(f"FC{spec.width(s.units, fraction)}")
        else:
            # the trailing FC maps to n_classes whatever its token
            # says; MP/S/FLAT carry no width
            tokens.append(s.notation)
    return tuple(tokens)


def _check_sliceable(ws: LayerSpec, ns: LayerSpec) -> None:
    """Raise unless the narrow layer is a word-aligned prefix of the
    wide one (the no-repack invariant)."""
    if ws.kind != ns.kind:
        raise ValueError(
            f"layer {ws.idx}: kind mismatch {ws.kind!r} vs {ns.kind!r}"
        )
    if ws.kind == "conv":
        cin_w, cin_n = ws.in_shape[-1], ns.in_shape[-1]
        if cin_n != cin_w and (cin_w % PACK_W or cin_n % PACK_W):
            raise ValueError(
                f"layer {ws.idx}: conv input channels {cin_w} -> "
                f"{cin_n} are not word-aligned; packed prefix slicing "
                "would cross a tail lane"
            )
        if ns.units > ws.units or cin_n > cin_w:
            raise ValueError(
                f"layer {ws.idx}: narrow conv ({cin_n}->{ns.units}) "
                f"exceeds wide ({cin_w}->{ws.units}); levels must nest"
            )
    elif ws.kind == "fc":
        din_w, din_n = ws.in_shape[0], ns.in_shape[0]
        if din_n != din_w and (din_w % PACK_W or din_n % PACK_W):
            raise ValueError(
                f"layer {ws.idx}: fc input width {din_w} -> {din_n} is "
                "not word-aligned"
            )
        if ns.units > ws.units or din_n > din_w:
            raise ValueError(
                f"layer {ws.idx}: narrow fc exceeds wide; levels must "
                "nest"
            )


def slice_packed(
    wide_specs: Sequence[LayerSpec],
    wide_packed: list,
    narrow_specs: Sequence[LayerSpec],
) -> list:
    """Packed params for `narrow_specs` as prefix views of
    `wide_packed` — zero repacking.

    Conv words ``(Cout, 9*Cw)`` slice as ``[:cout', :, :cw']`` on the
    ``(Cout, 9, Cw)`` view; FC words after a FLAT slice the word
    columns *per spatial position* (the flattened activation packs
    channels innermost, ``Cw`` words per position); FC-after-FC is a
    contiguous column prefix; step thresholds/flips are channel
    prefixes.  Bit-exact vs an independent pack of the sliced fp
    weights because every narrowed axis stays a multiple of 32 (no
    pad lanes inside the slice)."""
    if len(wide_specs) != len(narrow_specs):
        raise ValueError("wide and narrow models must have equal depth")
    out: list = []
    for i, (ws, ns) in enumerate(zip(wide_specs, narrow_specs)):
        _check_sliceable(ws, ns)
        p = wide_packed[i]
        if ws.kind == "conv":
            cin_w, cout_w = ws.in_shape[-1], ws.units
            cin_n, cout_n = ns.in_shape[-1], ns.units
            if (cin_n, cout_n) == (cin_w, cout_w):
                out.append(p)
                continue
            cw_w, cw_n = packed_len(cin_w), packed_len(cin_n)
            w = p["w_words"].reshape(cout_w, 9, cw_w)
            w = w[:cout_n, :, :cw_n].reshape(cout_n, 9 * cw_n)
            out.append({"w_words": w, "k_true": 9 * cin_n})
        elif ws.kind == "fc":
            din_w, dout_w = ws.in_shape[0], ws.units
            din_n, dout_n = ns.in_shape[0], ns.units
            if (din_n, dout_n) == (din_w, dout_w):
                out.append(p)
                continue
            w = p["w_words"]
            if din_n != din_w:
                prev = wide_specs[i - 1] if i else None
                if prev is not None and prev.kind == "flat":
                    # spatially-flattened input: channel words repeat
                    # per position, so the prefix is strided
                    h, wd, c_w = prev.in_shape
                    c_n = narrow_specs[i - 1].in_shape[-1]
                    cw_w, cw_n = packed_len(c_w), packed_len(c_n)
                    w = w.reshape(dout_w, h * wd, cw_w)
                    w = w[:, :, :cw_n].reshape(dout_w, h * wd * cw_n)
                else:
                    w = w[:, : packed_len(din_n)]
            out.append({"w_words": w[:dout_n], "k_true": din_n})
        elif ws.kind == "step":
            if ns.units == ws.units:
                out.append(p)
            else:
                out.append(
                    {
                        "thresh": p["thresh"][: ns.units],
                        "flip": p["flip"][: ns.units],
                    }
                )
        else:   # mp / flat carry no params
            out.append(p)
    return out


def slice_params_fp(
    wide_specs: Sequence[LayerSpec],
    params_fp: list,
    narrow_specs: Sequence[LayerSpec],
) -> list:
    """Latent fp params sliced to `narrow_specs` — the from-scratch
    reference path (``pack_params`` of this equals
    :func:`slice_packed`'s output bit for bit) and the starting point
    for fine-tuning a narrow level on its own."""
    if len(wide_specs) != len(narrow_specs):
        raise ValueError("wide and narrow models must have equal depth")
    out: list = []
    for i, (ws, ns) in enumerate(zip(wide_specs, narrow_specs)):
        _check_sliceable(ws, ns)
        p = params_fp[i]
        if ws.kind == "conv":
            out.append(
                {"w": p["w"][:, :, : ns.in_shape[-1], : ns.units]}
            )
        elif ws.kind == "fc":
            w = p["w"]                       # (Din, Dout)
            din_n = ns.in_shape[0]
            if din_n != ws.in_shape[0]:
                prev = wide_specs[i - 1] if i else None
                if prev is not None and prev.kind == "flat":
                    h, wd, c_w = prev.in_shape
                    c_n = narrow_specs[i - 1].in_shape[-1]
                    w = w.reshape(h * wd, c_w, -1)[:, :c_n, :]
                    w = w.reshape(din_n, -1)
                else:
                    w = w[:din_n, :]
            out.append({"w": w[:, : ns.units]})
        elif ws.kind == "step":
            out.append({k: v[: ns.units] for k, v in p.items()})
        else:
            out.append(p)
    return out


class SubnetFamily:
    """K nested-width subnets derived from one trained, packed BNN.

    ``levels[0]`` *is* the base model (same objects); every narrower
    level's packed tensors are prefix slices of the base packed
    tensors (:func:`slice_packed`).  Levels are strictly distinct —
    two fractions that clamp to identical widths are rejected, so
    per-level store keys (name + layer labels) can never collide.
    """

    def __init__(self, levels: Sequence[SubnetLevel], spec: ElasticSpec):
        self.levels = tuple(levels)
        self.spec = spec

    @classmethod
    def build(
        cls, model: BNNModel, packed: list, spec: ElasticSpec
    ) -> "SubnetFamily":
        """Derive the family from a packed base model.  `packed` is
        ``pack_params(model.specs, trained_params)`` output."""
        if len(packed) != len(model.specs):
            raise ValueError(
                f"packed params ({len(packed)}) do not match model "
                f"depth ({len(model.specs)})"
            )
        levels = [SubnetLevel(0, 1.0, model, list(packed))]
        seen_widths = {tuple(s.units for s in model.specs)}
        for k, frac in enumerate(spec.fractions[1:], start=1):
            notation = _narrow_notation(model, frac, spec)
            specs = tuple(
                parse_notation(
                    notation, model.input_hw, model.in_channels,
                    model.n_classes,
                )
            )
            widths = tuple(s.units for s in specs)
            if widths in seen_widths:
                raise ValueError(
                    f"level {k} (fraction {frac}) resolves to the same "
                    f"widths as a wider level — min_units clamping "
                    "collapsed it; drop the fraction or widen the model"
                )
            seen_widths.add(widths)
            narrow = BNNModel(
                level_name(model.name, k), specs, model.input_hw,
                model.in_channels, model.n_classes,
            )
            levels.append(
                SubnetLevel(
                    k, frac, narrow,
                    slice_packed(model.specs, packed, specs),
                )
            )
        return cls(levels, spec)

    @property
    def base(self) -> SubnetLevel:
        return self.levels[0]

    def level(self, k: int) -> SubnetLevel:
        return self.levels[k]

    def names(self) -> tuple:
        return tuple(lvl.model.name for lvl in self.levels)

    def __len__(self) -> int:
        return len(self.levels)

    def __iter__(self):
        return iter(self.levels)
